"""Verifier mutation corpus: every seeded breakage must be rejected with its
expected rule id, and clean compiles must stay diagnostic-free.

Each test compiles a known-good state with ``verify="off"``, corrupts ONE
artifact (module, fusion plan, schedule solution, shard attrs, cache entry,
or execution plan), and asserts the matching family catches it with the
documented rule id — the verifier's contract is *which* invariant broke,
not just that something did.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompilationState,
    FusedComputation,
    GraphBuilder,
    KernelCache,
    StitchOptions,
    VerificationError,
    compile_module,
    default_pipeline,
    trace,
    verify_execution_plan,
    verify_module,
)
from repro.core.perf_library import PerfLibrary
from repro.core.verify import (
    RULES,
    resolve_verify_mode,
    verify_fusion_groups,
    verify_planned_entries,
    verify_shard_attrs,
)


def _rmsnorm_module():
    def f(b, x, g):
        ms = b.reduce(b.square(x), (1,), "mean")
        inv = b.rsqrt(ms + 1e-6)
        return x * b.broadcast(inv, x.shape, (0,)) * b.broadcast(g, x.shape, (1,))

    return trace(f, ("x", (8, 32), jnp.float32), ("g", (32,), jnp.float32))


def _compiled_state(module=None, **opt_kwargs):
    opts = StitchOptions(
        max_blocks=opt_kwargs.pop("max_blocks", 32), verify="off", **opt_kwargs
    )
    state = CompilationState(
        module=module if module is not None else _rmsnorm_module(),
        options=opts,
        library=PerfLibrary(),
        kernel_cache=KernelCache(),
    )
    default_pipeline().run(state)
    return state


def _by_opcode(module, opcode):
    return next(i for i in module.instructions if i.opcode == opcode)


def _rules(diags):
    return {d.rule for d in diags}


# ----------------------------------------------------------- IR family
def test_clean_module_has_no_diagnostics():
    assert verify_module(_rmsnorm_module()) == []


def test_ir005_shape_corruption():
    m = _rmsnorm_module()
    _by_opcode(m, "reduce").shape = (7,)
    rules = _rules(verify_module(m))
    assert "IR005" in rules


def test_ir006_dtype_corruption():
    m = _rmsnorm_module()
    # an elementwise op must carry its operand's dtype
    ew = next(i for i in m.instructions if i.opcode == "elementwise")
    ew.dtype = np.dtype(np.int32)
    assert "IR006" in _rules(verify_module(m))


def test_ir003_broken_back_edge():
    m = _rmsnorm_module()
    red = _by_opcode(m, "reduce")
    # drop the producer's user back-edge: operand list says A uses B, but
    # B's users no longer name A
    red.operands[0].users.remove(red)
    assert "IR003" in _rules(verify_module(m))


def test_ir002_storage_order_broken():
    m = _rmsnorm_module()
    instrs = m.instructions
    # move the last instruction (the root) to the front: its operands now
    # sit after it in storage order
    instrs.insert(0, instrs.pop())
    assert "IR002" in _rules(verify_module(m))


def test_ir001_dangling_operand():
    m = _rmsnorm_module()
    red = _by_opcode(m, "reduce")
    m.instructions.remove(red.operands[0])
    assert "IR001" in _rules(verify_module(m))


def test_ir004_duplicate_id():
    m = _rmsnorm_module()
    m.instructions[-1].id = m.instructions[0].id
    assert "IR004" in _rules(verify_module(m))


def test_module_verify_raises_verification_error():
    m = _rmsnorm_module()
    _by_opcode(m, "reduce").shape = (7,)
    with pytest.raises(VerificationError) as exc:
        m.verify()
    assert isinstance(exc.value, ValueError)  # pre-existing caller contract
    assert any(d.rule == "IR005" for d in exc.value.diagnostics)


def test_every_diagnostic_rule_is_documented():
    m = _rmsnorm_module()
    _by_opcode(m, "reduce").shape = (7,)
    for d in verify_module(m):
        assert d.rule in RULES


# --------------------------------------------------------- plan family
def _partition(module, members):
    """One fusion of `members`, everything else standalone (coverage-clean)."""
    member_ids = {m.id for m in members}
    standalone = [
        i
        for i in module.instructions
        if i.opcode != "parameter" and i.id not in member_ids
    ]
    return [FusedComputation(members=list(members), name="bad")], standalone


def test_plan001_cycle_through_outside():
    m = _rmsnorm_module()
    square = _by_opcode(m, "elementwise")  # x*x, feeds the reduce chain
    root = m.roots[0]
    fusions, standalone = _partition(m, [square, root])
    assert "PLAN001" in _rules(verify_fusion_groups(fusions, standalone, m))


def test_plan003_collective_in_kernel_body():
    b = GraphBuilder("coll")
    x = b.parameter("x", (8, 8), jnp.float32)
    y = b.square(x)
    ar = b.all_reduce(y, ("data",))
    b.tanh(ar)
    m = b.module
    ar_instr = _by_opcode(m, "all_reduce")
    members = [y.instr, ar_instr]
    fusions, standalone = _partition(m, members)
    assert "PLAN003" in _rules(verify_fusion_groups(fusions, standalone, m))


def test_plan003_library_call_in_kernel_body():
    b = GraphBuilder("lib")
    x = b.parameter("x", (8, 8), jnp.float32)
    w = b.parameter("w", (8, 8), jnp.float32)
    h = b.dot(b.square(x), w)
    b.tanh(h)
    m = b.module
    dot = _by_opcode(m, "dot")
    fusions, standalone = _partition(m, [dot])
    assert "PLAN003" in _rules(verify_fusion_groups(fusions, standalone, m))


def test_plan004_array_constant_in_kernel_body():
    b = GraphBuilder("const")
    x = b.parameter("x", (8,), jnp.float32)
    c = b.constant(np.ones((8,), np.float32))
    y = x + c
    m = b.module
    fusions, standalone = _partition(m, [c.instr, y.instr])
    assert "PLAN004" in _rules(verify_fusion_groups(fusions, standalone, m))


def test_plan002_component_spans_lc_roof():
    b = GraphBuilder("span")
    x = b.parameter("x", (8, 8), jnp.float32)
    w = b.parameter("w", (8, 8), jnp.float32)
    s = b.square(x)
    h = b.dot(s, w)  # LC layer between s and the root
    b.binary("add", s, b.tanh(h))  # root consumes s directly: skip edge
    m = b.module
    root = m.roots[0]
    fusions, standalone = _partition(m, [s.instr, root])
    assert "PLAN002" in _rules(verify_fusion_groups(fusions, standalone, m))


def test_plan009_coverage_gap_and_duplicate():
    m = _rmsnorm_module()
    red = _by_opcode(m, "reduce")
    covered = [
        i
        for i in m.instructions
        if i.opcode != "parameter" and i.id != red.id
    ]
    # gap: reduce covered 0x
    assert "PLAN009" in _rules(verify_fusion_groups([], covered, m))
    # duplicate: reduce covered 2x
    assert "PLAN009" in _rules(
        verify_fusion_groups([], covered + [red, red], m)
    )


def test_plan005_unsound_solution():
    state = _compiled_state()
    planned = [p for p in state.planned if p.is_representative]
    assert planned, "expected at least one planned fusion"
    p = planned[0]
    sol = p.entry.stitched or p.entry.solution
    assert sol is not None
    if p.entry.stitched is not None:
        assignment = p.entry.stitched.phases[0].solution.assignment
    else:
        assignment = sol.assignment
    assignment.pop(next(iter(assignment)))
    assert "PLAN005" in _rules(verify_planned_entries(state))


def test_plan006_memory_over_budget():
    state = _compiled_state()
    state.options.vmem_limit = 16  # nothing fits in 16 bytes
    assert "PLAN006" in _rules(verify_planned_entries(state))


def test_exec005_stale_signature():
    state = _compiled_state()
    p = next(p for p in state.planned if p.raw_signature is not None)
    p.raw_signature = "0" * len(p.raw_signature)
    assert "EXEC005" in _rules(verify_planned_entries(state))


# -------------------------------------------------------- shard family
_MESH = (("model", 2),)


def _sharded_reduce_module():
    b = GraphBuilder("shard")
    x = b.parameter("x", (4, 8), jnp.float32)
    r = b.reduce(b.square(x), (1,), "sum")  # contracts the sharded dim
    b.tanh(r)
    return b.module


def test_plan007_stale_shard_stamp():
    from repro.core.shard import propagate_layouts

    m = _sharded_reduce_module()
    layouts = {"x": (None, ("model",))}
    propagate_layouts(m, _MESH, layouts)
    # corrupt one stamp: claim dim 0 is sharded where dim 1 is
    sq = _by_opcode(m, "elementwise")
    sq.attrs["shard"] = (("model",), None)
    assert "PLAN007" in _rules(verify_shard_attrs(m, _MESH, layouts))


def test_plan007_layout_conflict():
    b = GraphBuilder("conflict")
    x = b.parameter("x", (8, 8), jnp.float32)
    y = b.parameter("y", (8, 8), jnp.float32)
    b.binary("add", x, y)
    m = b.module
    layouts = {"x": (("model",), None), "y": (None, ("model",))}
    assert "PLAN007" in _rules(verify_shard_attrs(m, _MESH, layouts))


def test_plan008_partial_sum_at_root():
    from repro.core.shard import propagate_layouts

    m = _sharded_reduce_module()
    layouts = {"x": (None, ("model",))}
    propagate_layouts(m, _MESH, layouts)  # honest stamps, no collective
    rules = _rules(verify_shard_attrs(m, _MESH, layouts))
    assert "PLAN008" in rules
    assert "PLAN007" not in rules  # the stamps themselves are consistent


# --------------------------------------------------- ExecutionPlan family
def _stacked_module(n=2):
    def f(b, x, *weights):
        gs, Ws = weights[:n], weights[n:]
        for g, W in zip(gs, Ws, strict=False):
            ms = b.reduce(b.square(x), (1,), "mean")
            inv = b.rsqrt(ms + 1e-6)
            normed = (
                x * b.broadcast(inv, x.shape, (0,)) * b.broadcast(g, x.shape, (1,))
            )
            x = x + b.tanh(b.dot(normed, W))
        return x

    specs = [("x", (8, 32), jnp.float32)]
    specs += [(f"g{i}", (32,), jnp.float32) for i in range(n)]
    specs += [(f"W{i}", (32, 32), jnp.float32) for i in range(n)]
    return trace(f, *specs)


def _execution_plan():
    state = _compiled_state(_stacked_module())
    ep = state.executable.execution_plan
    assert verify_execution_plan(ep) == []  # clean before mutation
    return ep


def test_exec001_read_before_write():
    ep = _execution_plan()
    bogus = max(s for st in ep.steps for s in st.arg_slots) + 100
    ep.steps[0].arg_slots = [bogus] + list(ep.steps[0].arg_slots)[1:]
    assert "EXEC001" in _rules(verify_execution_plan(ep))


def test_exec002_use_after_release():
    ep = _execution_plan()
    # find a slot some later step reads, and release it at the first step
    victim = None
    for k in range(len(ep.steps) - 1, 0, -1):
        reads = set(ep.steps[k].arg_slots)
        if reads:
            victim = next(iter(reads))
            break
    assert victim is not None
    ep.steps[0].release = list(ep.steps[0].release) + [victim]
    assert "EXEC002" in _rules(verify_execution_plan(ep))


def test_exec003_release_of_root_slot():
    ep = _execution_plan()
    root_slot = ep._root_binds[0][1]
    ep.steps[-1].release = list(ep.steps[-1].release) + [root_slot]
    assert "EXEC003" in _rules(verify_execution_plan(ep))


def test_exec004_donated_live_slot():
    from repro.core.executor import _JitSegment

    ep = _execution_plan()
    seg = next(s for s in ep._segments if isinstance(s, _JitSegment))
    live = [
        i for i, s in enumerate(seg.in_slots) if s not in seg.released
    ]
    assert live, "expected an in_slot that stays live"
    seg.donate = list(seg.donate) + [live[0]]
    assert "EXEC004" in _rules(verify_execution_plan(ep))


def test_exec004_donated_protected_slot():
    from repro.core.executor import _JitSegment

    ep = _execution_plan()
    param_slots = {slot for _, slot, _, _ in ep._param_binds}
    protected = param_slots - set(ep.donated_param_slots)
    seg = hit = None
    for s in ep._segments:
        if isinstance(s, _JitSegment):
            for i, sl in enumerate(s.in_slots):
                if sl in protected:
                    seg, hit = s, i
                    break
        if seg is not None:
            break
    assert seg is not None, "expected a segment reading a parameter slot"
    seg.donate = list(seg.donate) + [hit]
    assert "EXEC004" in _rules(verify_execution_plan(ep))


# ------------------------------------------------- modes, stats, overhead
def test_verify_off_leaves_no_trace():
    m = _rmsnorm_module()
    comp = compile_module(m, StitchOptions(max_blocks=32, verify="off"))
    assert "verify" not in comp.stats.pass_times
    assert comp.stats.verify_mode == "off"
    assert comp.stats.verify_boundaries == 0


def test_verify_checkpoint_is_default_single_boundary():
    m = _rmsnorm_module()
    comp = compile_module(m, StitchOptions(max_blocks=32))
    assert comp.stats.verify_mode == "checkpoint"
    assert comp.stats.verify_boundaries == 1
    assert "verify" in comp.stats.pass_times


def test_verify_strict_checks_every_boundary():
    m = _rmsnorm_module()
    comp = compile_module(m, StitchOptions(max_blocks=32, verify="strict"))
    assert comp.stats.verify_mode == "strict"
    assert comp.stats.verify_boundaries == 8  # one per default pass
    assert comp.stats.verify_warnings == 0


def test_env_var_overrides_option(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "strict")
    m = _rmsnorm_module()
    comp = compile_module(m, StitchOptions(max_blocks=32, verify="off"))
    assert comp.stats.verify_mode == "strict"
    assert comp.stats.verify_boundaries == 8


def test_bad_env_value_rejected(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "paranoid")
    with pytest.raises(ValueError, match="REPRO_VERIFY"):
        resolve_verify_mode(StitchOptions())


def test_bad_option_value_rejected():
    with pytest.raises(ValueError, match="verify"):
        compile_module(_rmsnorm_module(), StitchOptions(verify="bogus"))


def test_pipeline_raises_on_seeded_corruption():
    """End-to-end: a pass that corrupts the module fails its own boundary."""
    from repro.core.pipeline import FusionPass

    class CorruptingPass(FusionPass):
        def run(self, state):
            super().run(state)
            red = next(
                i for i in state.module.instructions if i.opcode == "reduce"
            )
            red.shape = (7,)

    from repro.core.pipeline import (
        AutotunePass, CodegenPass, FinalizePass, MemoryPass, PassPipeline,
        SchedulePass, ShardingPass, SubModulePass,
    )

    pipe = PassPipeline([
        SubModulePass(), ShardingPass(), CorruptingPass(), SchedulePass(),
        MemoryPass(), CodegenPass(), AutotunePass(), FinalizePass(),
    ])
    state = CompilationState(
        module=_rmsnorm_module(),
        options=StitchOptions(max_blocks=32, verify="strict"),
        library=PerfLibrary(),
        kernel_cache=KernelCache(),
    )
    with pytest.raises(VerificationError) as exc:
        pipe.run(state)
    assert any(d.rule == "IR005" for d in exc.value.diagnostics)
    assert all(d.pass_name == "fusion" for d in exc.value.diagnostics)


# ----------------------------------------------------- property: clean IR
def _random_graph(rng):
    """Seeded random DAG over GraphBuilder — the non-hypothesis twin of
    ``test_core_property.random_module``."""
    b = GraphBuilder("fuzz")
    shape = [(4, 8), (2, 4, 8), (8,)][rng.randint(3)]
    pool = [
        b.parameter(f"p{i}", shape, jnp.float32)
        for i in range(rng.randint(1, 4))
    ]
    for _ in range(rng.randint(3, 18)):
        kind = rng.randint(4)
        x = pool[rng.randint(len(pool))]
        if kind == 0:
            fn = ["exp", "tanh", "abs", "sigmoid", "square"][rng.randint(5)]
            pool.append(b.unary(fn, x))
        elif kind == 1:
            same = [t for t in pool if t.shape == x.shape]
            y = same[rng.randint(len(same))]
            fn = ["add", "mul", "sub", "max", "min"][rng.randint(5)]
            pool.append(b.binary(fn, x, y))
        elif kind == 2:
            pool.append(x * float(rng.uniform(-2, 2)))
        else:
            if x.ndim < 2:
                continue
            dim = rng.randint(x.ndim)
            r = b.reduce(x, (dim,), ["sum", "max", "mean"][rng.randint(3)])
            kept = tuple(i for i in range(x.ndim) if i != dim)
            pool.append(b.broadcast(r, x.shape, kept) + x)
    if all(t.instr.opcode == "parameter" for t in pool):
        b.exp(pool[0])
    return b.module


@pytest.mark.parametrize("planner", ["cost", "greedy"])
def test_random_graphs_compile_clean_under_strict(planner):
    rng = np.random.RandomState(7)
    for _ in range(8):
        comp = compile_module(
            _random_graph(rng),
            StitchOptions(max_blocks=32, planner=planner, verify="strict"),
        )
        assert comp.stats.verify_boundaries == 8
        assert comp.stats.verify_warnings == 0


try:  # the hypothesis variant explores the same space adversarially
    from hypothesis import given, settings

    from test_core_property import random_module

    @given(random_module())
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_graphs_compile_clean_under_strict(module):
        for planner in ("cost", "greedy"):
            comp = compile_module(
                module,
                StitchOptions(max_blocks=32, planner=planner, verify="strict"),
            )
            assert comp.stats.verify_boundaries == 8
            assert comp.stats.verify_warnings == 0
except ImportError:  # pragma: no cover — container without hypothesis
    pass
