"""Cost-guided fusion planner: floor property, oracle parity, adversarial
graphs, planner-aware cache keys, and versioned on-disk tuning records."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import compile_and_compare, make_feeds as _feeds
from repro.core import (
    FusionConfig,
    GraphBuilder,
    KernelCache,
    StitchOptions,
    compile_module,
    deep_fuse,
    reference_execute,
    trace,
)

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from graphs import (  # noqa: E402
    broadcast_towers_graph,
    reduce_towers_graph,
    stacked_transformer_graph,
)


def _kernels(comp):
    return comp.stats.stitched_kernels + comp.stats.standalone_kernels




# ----------------------------------------------------- adversarial graphs
@pytest.mark.parametrize("graph_fn", [reduce_towers_graph, broadcast_towers_graph])
def test_planner_beats_greedy_on_adversarial_graphs(graph_fn):
    """The sink-pack candidate commits the tower union as ONE kernel at
    planning time — the horizontal-merge post-pass has nothing left to do."""
    m = graph_fn()
    greedy = compile_module(m, StitchOptions(max_blocks=64, planner="greedy"))
    cost = compile_module(m, StitchOptions(max_blocks=64, planner="cost"))
    assert _kernels(cost) < _kernels(greedy)
    assert _kernels(cost) == 1
    s = cost.stats
    assert s.planner_mode == "cost"
    assert s.plans_explored > 0
    assert s.planner_packs > 0
    assert s.planner_merges == 0     # packed at plan time, not post-merged
    assert s.launches_saved_vs_greedy > 0
    assert s.launches_saved_vs_unfused > 0
    assert 0 < s.planner_predicted_s < s.greedy_predicted_s


def test_planner_never_emits_more_kernels_than_greedy():
    """Across every benchmark graph the planner's launch count is <= greedy's
    (split candidates are only taken when the model says they pay, and none
    of these graphs rewards paying a launch to split)."""
    from graphs import ALL_GRAPHS

    for name, fn in ALL_GRAPHS.items():
        m = fn()
        greedy = compile_module(m, StitchOptions(max_blocks=64, planner="greedy"))
        cost = compile_module(m, StitchOptions(max_blocks=64, planner="cost"))
        assert _kernels(cost) <= _kernels(greedy), name


# ------------------------------------------------------- oracle parity
@pytest.mark.parametrize("mode", ["greedy", "cost"])
@pytest.mark.parametrize(
    "graph_fn", [reduce_towers_graph, broadcast_towers_graph]
)
def test_planner_modes_match_reference_oracle(graph_fn, mode, rng):
    m = graph_fn()
    compile_and_compare(m, _feeds(m, rng), max_blocks=64, planner=mode)


def test_merged_multi_root_kernel_executes_correctly(rng):
    """The packed ReduceTowers kernel carries one root per tower; every
    tower's scalar must still match the oracle bit-for-tolerance."""
    m = reduce_towers_graph(num_towers=4)
    comp = compile_and_compare(m, _feeds(m, rng), max_blocks=64)
    assert comp.stats.planner_packs > 0
    assert comp.stats.stitched_kernels == 1


# -------------------------------------------------------- floor property
def _random_module(seed: int):
    rng = np.random.RandomState(seed)
    b = GraphBuilder(f"rand{seed}")
    shape = [(4, 8), (2, 4, 8), (8, 16)][seed % 3]
    pool = [b.parameter(f"p{i}", shape, jnp.float32) for i in range(2)]
    for k in range(int(rng.randint(3, 14))):
        kind = rng.choice(["unary", "binary", "reduce_bcast", "scalar"])
        x = pool[rng.randint(len(pool))]
        if kind == "unary":
            pool.append(b.unary(str(rng.choice(["exp", "tanh", "square"])), x))
        elif kind == "binary":
            y = pool[rng.randint(len(pool))]
            if y.shape == x.shape:
                pool.append(x + y)
        elif kind == "scalar":
            pool.append(x * float(rng.uniform(-2, 2)))
        else:
            dim = int(rng.randint(x.ndim))
            r = b.reduce(x, (dim,), "sum")
            kept = tuple(i for i in range(x.ndim) if i != dim)
            pool.append(b.broadcast(r, x.shape, kept) + x)
    return b.module


@pytest.mark.parametrize("seed", range(12))
def test_planner_floor_property(seed):
    """The committed plan's modeled latency never exceeds the greedy plan's:
    greedy is always in the candidate set and merges must strictly pay."""
    m = _random_module(seed)
    plan = deep_fuse(m, FusionConfig(planner="cost"))
    st = plan.planner
    assert st.mode == "cost"
    assert st.predicted_s <= st.greedy_predicted_s + 1e-12
    assert st.planned_kernels == plan.num_kernels


@pytest.mark.parametrize("seed", range(6))
def test_planner_plan_invariants(seed):
    """Planner output obeys the same structural invariants as greedy."""
    m = _random_module(seed + 100)
    plan = deep_fuse(m, FusionConfig(planner="cost"))
    pos = {i.id: k for k, i in enumerate(m.instructions)}
    seen = set()
    for f in plan.fusions:
        for mem in f.members:
            assert mem.id not in seen, "instruction fused twice"
            seen.add(mem.id)
        order = [pos[mem.id] for mem in f.members]
        assert order == sorted(order)
    for s in plan.standalone:
        assert s.id not in seen
        seen.add(s.id)
    uncovered = [
        i
        for i in m.instructions
        if i.id not in seen and i.opcode not in ("parameter", "constant")
    ]
    from repro.core.fusion import constant_like

    assert all(constant_like(i) for i in uncovered)


def test_planner_merges_single_op_towers(rng):
    """Singleton seeds are scored too: N independent single-reduce towers
    are the purest launch-bound missed-merge pathology."""
    b = GraphBuilder("single_op_towers")
    for i in range(4):
        x = b.parameter(f"x{i}", (16, 32), jnp.float32)
        _ = b.reduce(x, (0, 1), "sum")
    m = b.module
    greedy = deep_fuse(m, FusionConfig(planner="greedy"))
    cost = deep_fuse(m, FusionConfig(planner="cost"))
    assert greedy.num_kernels == 4
    assert cost.num_kernels < greedy.num_kernels
    assert cost.planner.packs_taken + cost.planner.merges_taken > 0
    compile_and_compare(m, _feeds(m, rng), max_blocks=64)


def test_planner_respects_injected_consistency_checker():
    """Split, pack, and merge commits all go through the SchdConsistent
    extension point.  Greedy never builds a multi-reduce kernel on
    ReduceTowers (one reduce per tower); a checker refusing them must also
    veto the planner's tower packs and merges, which would otherwise put
    all reduces into one kernel."""

    def at_most_one_reduce(roots, members):
        return sum(1 for mem in members if mem.opcode == "reduce") <= 1

    m = reduce_towers_graph(num_towers=4)
    cost = deep_fuse(
        m, FusionConfig(planner="cost", consistency=at_most_one_reduce)
    )
    for f in cost.fusions:
        n_reduce = sum(1 for mem in f.members if mem.opcode == "reduce")
        assert n_reduce <= 1, f
    assert cost.planner.merges_taken == 0
    assert cost.planner.packs_taken == 0
    # without the checker the same graph packs down to one kernel
    free = deep_fuse(m, FusionConfig(planner="cost"))
    assert free.planner.packs_taken > 0


def test_greedy_mode_reproduces_original_algorithm():
    """planner='greedy' explores nothing and commits one fusion per seed."""
    m = reduce_towers_graph()
    plan = deep_fuse(m, FusionConfig(planner="greedy"))
    st = plan.planner
    assert st.mode == "greedy"
    assert st.plans_explored == st.plans_rejected == 0
    assert st.splits_taken == st.merges_taken == 0
    assert plan.num_kernels == st.greedy_kernels


# ------------------------------------------------- cache interaction
def test_stacked_cache_hit_rate_unchanged_by_planner():
    """The planner must not split the stacked-transformer layer fusions:
    the KernelCache hit rate is identical to greedy's."""
    m1 = stacked_transformer_graph(num_layers=8)
    m2 = stacked_transformer_graph(num_layers=8)
    greedy = compile_module(m1, StitchOptions(max_blocks=32, planner="greedy"))
    cost = compile_module(m2, StitchOptions(max_blocks=32, planner="cost"))
    assert cost.stats.cache_hit_rate == greedy.stats.cache_hit_rate
    assert cost.stats.unique_kernels == greedy.stats.unique_kernels
    assert _kernels(cost) == _kernels(greedy)


def test_cache_not_shared_across_planner_modes(rng):
    """Signatures are salted with the planner mode: a greedy-built entry
    must not serve a cost-guided compile (partitions may differ)."""
    cache = KernelCache()
    m = stacked_transformer_graph(num_layers=3)
    compile_module(
        stacked_transformer_graph(num_layers=3),
        StitchOptions(max_blocks=32, planner="greedy"),
        kernel_cache=cache,
    )
    comp2 = compile_module(
        m, StitchOptions(max_blocks=32, planner="cost"), kernel_cache=cache
    )
    # identical middle layers may still hit EACH OTHER within this compile,
    # but nothing may be served by the greedy-salted entries: the cost
    # compile must tune and emit its own representatives.
    assert comp2.stats.kernels_emitted == comp2.stats.unique_kernels > 0
    feeds = _feeds(m, rng)
    out = comp2(feeds)
    ref = reference_execute(m, feeds)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=2e-5
        )


# ------------------------------------------- versioned on-disk records
def _compile_with_disk(tmp_path, n_layers=3):
    path = str(tmp_path / "kernels.json")
    opts = StitchOptions(max_blocks=32, kernel_cache_path=path)
    compile_module(stacked_transformer_graph(num_layers=n_layers), opts)
    return path, opts


def test_versioned_records_roundtrip(tmp_path):
    path, opts = _compile_with_disk(tmp_path)
    with open(path) as f:
        store = json.load(f)
    assert store, "tuning records must persist"
    from repro.core.signature import SCHEMA_VERSION

    for rec in store.values():
        assert rec["version"] == SCHEMA_VERSION
    comp2 = compile_module(stacked_transformer_graph(num_layers=3), opts)
    assert comp2.stats.tuning_disk_hits == comp2.stats.kernel_cache_misses > 0


def test_stale_version_records_are_discarded(tmp_path, rng):
    path, opts = _compile_with_disk(tmp_path)
    with open(path) as f:
        store = json.load(f)
    for rec in store.values():
        rec["version"] = 1          # a previous schema generation
    with open(path, "w") as f:
        json.dump(store, f)
    comp2 = compile_module(stacked_transformer_graph(num_layers=3), opts)
    assert comp2.stats.tuning_disk_hits == 0      # stale rows never hint
    m = stacked_transformer_graph(num_layers=3)
    feeds = _feeds(m, rng)
    out = compile_module(m, opts)(feeds)
    ref = reference_execute(m, feeds)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=2e-5
        )


def test_corrupt_records_are_discarded_not_raised(tmp_path):
    path, opts = _compile_with_disk(tmp_path)
    with open(path) as f:
        store = json.load(f)
    from repro.core.signature import SCHEMA_VERSION

    for key in store:
        store[key] = {"version": SCHEMA_VERSION, "roots": "garbage"}
    with open(path, "w") as f:
        json.dump(store, f)

    # a cache opened over the corrupt store evicts rows instead of raising
    cache = KernelCache(path)
    assert cache.tuning_hint(next(iter(store))) is None
    assert cache.stale_discards >= 1

    # and a full compile over the corrupt store retunes cleanly (this also
    # rewrites fresh, valid records on save)
    comp2 = compile_module(stacked_transformer_graph(num_layers=3), opts)
    assert comp2.stats.tuning_disk_hits == 0
    assert comp2.stats.stitched_kernels > 0
