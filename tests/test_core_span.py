"""Work/Span analysis properties (paper §3.1)."""
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import GraphBuilder, compute_spans, critical_path_length, layers
from repro.core.span import lc_spans, roof_for, validate_spans


def _chain(n):
    b = GraphBuilder()
    x = b.parameter("x", (4, 4), jnp.float32)
    for _ in range(n):
        x = b.exp(x)
    return b.module


def test_chain_span_equals_length():
    m = _chain(5)
    assert critical_path_length(m) == 5  # param at span 5, root exp at 0


def test_roots_have_span_zero():
    m = _chain(3)
    span = compute_spans(m)
    for r in m.roots:
        assert span[r.id] == 0


def test_same_layer_independent():
    b = GraphBuilder()
    x = b.parameter("x", (4,), jnp.float32)
    a, c = b.exp(x), b.tanh(x)
    _ = a + c
    span = compute_spans(b.module)
    assert span[a.instr.id] == span[c.instr.id] == 1
    validate_spans(b.module, span)


def test_lc_layer_segmentation():
    b = GraphBuilder()
    x = b.parameter("x", (4, 4), jnp.float32)
    y = b.exp(x)
    d = b.dot(y, y)            # library call
    z = b.tanh(d)
    _ = b.reduce(z, (1,), "sum")
    span = compute_spans(b.module)
    lcs = lc_spans(b.module, span)
    assert lcs == [span[d.instr.id]]
    # fusion from span 0 may not cross the dot
    assert roof_for(0, lcs, max(span.values())) == span[d.instr.id]


@st.composite
def random_dag(draw):
    b = GraphBuilder()
    vals = [b.parameter("x", (4, 4), jnp.float32)]
    n = draw(st.integers(2, 18))
    for i in range(n):
        kind = draw(st.sampled_from(["exp", "add", "mul", "tanh"]))
        if kind in ("add", "mul"):
            lhs = vals[draw(st.integers(0, len(vals) - 1))]
            rhs = vals[draw(st.integers(0, len(vals) - 1))]
            vals.append(b.binary(kind, lhs, rhs))
        else:
            vals.append(b.unary(kind, vals[draw(st.integers(0, len(vals) - 1))]))
    return b.module


@given(random_dag())
@settings(max_examples=60, deadline=None)
def test_span_invariants_on_random_dags(module):
    span = compute_spans(module)
    validate_spans(module, span)          # operands strictly deeper than users
    ls = layers(module, span)
    # layers partition the instruction set
    assert sum(len(v) for v in ls.values()) == len(module.instructions)
    # span values are contiguous from 0
    assert sorted(ls) == list(range(max(ls) + 1))
