"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (brief §f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, reduced_config
from repro.data import SyntheticLM
from repro.models import decode_step, forward, init_cache, init_params
from repro.train import AdamWConfig, adamw_init, make_train_step

ALL_ARCHS = sorted(ARCHITECTURES)


def _batch(cfg, B=2, S=16, seed=0):
    data = SyntheticLM(cfg, S, B, seed=seed)
    return {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, 0)
    batch = _batch(cfg)
    logits = forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    batch = _batch(cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(d0, np.float32), np.asarray(d1, np.float32))
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), "NaN in params"


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mamba2-1.3b", "hymba-1.5b", "granite-moe-3b-a800m"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match the full forward at each position —
    the KV-cache/state-consistency invariant of the serve path."""
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, 0)
    B, S = 2, 8
    rng = np.random.RandomState(0)
    toks = rng.randint(0, 200, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    full = forward(params, batch, cfg)                 # (B, S, V)
    cache = init_cache(cfg, B, max_len=32)
    outs = []
    for i in range(S):
        logits, cache = decode_step(
            params, cache, jnp.asarray(toks[:, i]), jnp.asarray(i, jnp.int32), cfg
        )
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)                      # (B, S, V)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3
    )


def test_sliding_window_ring_buffer():
    """hymba decode beyond the window: ring slots recycle, outputs stay
    finite and depend only on the last W tokens."""
    cfg = reduced_config(get_config("hymba-1.5b"))
    assert cfg.sliding_window == 8
    params = init_params(cfg, 0)
    B = 1
    cache = init_cache(cfg, B, max_len=cfg.sliding_window)
    rng = np.random.RandomState(0)
    for i in range(20):          # 2.5x window
        tok = jnp.asarray(rng.randint(0, 200, (B,)).astype(np.int32))
        logits, cache = decode_step(params, cache, tok, jnp.asarray(i, jnp.int32), cfg)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_moe_scatter_matches_dense_when_capacity_ample():
    import dataclasses

    cfg = reduced_config(get_config("granite-moe-3b-a800m"))
    cfg_s = dataclasses.replace(cfg, moe_impl="scatter", moe_capacity_factor=8.0)
    params = init_params(cfg, 0)
    batch = _batch(cfg)
    a = forward(params, batch, cfg)
    b = forward(params, batch, cfg_s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_configs_match_assignment_table():
    """The exact public configs from the assignment block."""
    c = get_config("llama4-scout-17b-a16e")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (48, 5120, 40, 8)
    assert (c.d_ff, c.vocab_size, c.moe_experts, c.moe_top_k) == (8192, 202048, 16, 1)
    c = get_config("granite-moe-3b-a800m")
    assert (c.num_layers, c.d_model, c.moe_experts, c.moe_top_k) == (32, 1536, 40, 8)
    c = get_config("mistral-large-123b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff) == (88, 12288, 96, 28672)
    c = get_config("mamba2-1.3b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (48, 2048, 128)
    c = get_config("hymba-1.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.ssm_state) == (32, 1600, 25, 16)
    c = get_config("whisper-base")
    assert (c.num_layers, c.encoder_layers, c.d_model) == (6, 6, 512)
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("granite-20b").num_kv_heads == 1
    assert get_config("qwen2-vl-2b").mrope


def test_int8_kv_cache_decode_close_to_bf16():
    """int8 KV cache: decode logits stay close to the exact cache path
    (the decode memory-roofline lever, EXPERIMENTS.md §Perf)."""
    import dataclasses

    cfg = reduced_config(get_config("qwen2.5-14b"))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_params(cfg, 0)
    B, S = 2, 8
    rng = np.random.RandomState(0)
    toks = rng.randint(0, 200, (B, S)).astype(np.int32)
    c_a = init_cache(cfg, B, max_len=16)
    c_b = init_cache(cfg8, B, max_len=16)
    assert c_b["k"].dtype == jnp.int8 and "k_scale" in c_b
    for i in range(S):
        la, c_a = decode_step(params, c_a, jnp.asarray(toks[:, i]), jnp.asarray(i), cfg)
        lb, c_b = decode_step(params, c_b, jnp.asarray(toks[:, i]), jnp.asarray(i), cfg8)
    pa = jax.nn.softmax(la, axis=-1)
    pb = jax.nn.softmax(lb, axis=-1)
    # distributions agree closely; argmax identical
    np.testing.assert_allclose(np.asarray(pa), np.asarray(pb), atol=5e-2)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(la), -1), np.argmax(np.asarray(lb), -1)
    )
