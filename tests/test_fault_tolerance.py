"""Checkpoint/restart, failure injection, straggler watchdog, elastic
re-mesh — the large-scale-runnability substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data import SyntheticLM, make_data_iterator
from repro.models import init_params
from repro.train import (
    AdamWConfig,
    FailureInjector,
    StragglerWatchdog,
    Trainer,
    TrainerConfig,
    adamw_init,
    make_train_step,
)


def _mk_trainer(tmp_path, cfg, total_steps=12, injector=None, ckpt_every=4):
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=total_steps)
    tcfg = TrainerConfig(
        total_steps=total_steps, checkpoint_every=ckpt_every, keep_checkpoints=2
    )
    ckpt = CheckpointManager(str(tmp_path / "ckpt"), keep=2)

    def data_factory(start):
        return SyntheticLM(cfg, 16, 4, seed=7).iterate(start)

    return Trainer(cfg, ocfg, tcfg, data_factory, ckpt, failure_injector=injector)


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    mgr = CheckpointManager(str(tmp_path / "c"), keep=2)
    mgr.save(3, params, opt)
    p2, o2, step = mgr.restore(3, params, opt)
    assert step == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2), strict=False):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_garbage_collection(tmp_path):
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    mgr = CheckpointManager(str(tmp_path / "c"), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params, opt)
    assert mgr.available_steps() == [3, 4]


def test_atomic_publish_no_partial_checkpoints(tmp_path):
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    mgr = CheckpointManager(str(tmp_path / "c"), keep=3)
    mgr.save(1, params, opt)
    # a stale tmp dir (simulated crash mid-write) must not be visible
    os.makedirs(str(tmp_path / "c" / ".tmp_step_9"), exist_ok=True)
    assert mgr.available_steps() == [1]


def test_restart_resumes_bit_exact(tmp_path):
    """Kill training mid-run; a fresh Trainer restores the checkpoint and
    the data cursor and ends bit-identical to an uninterrupted run."""
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params0 = init_params(cfg, 0)

    # uninterrupted reference
    t_ref = _mk_trainer(tmp_path / "ref", cfg)
    p_ref, _, _ = t_ref.run(jax.tree.map(jnp.copy, params0))

    # interrupted run: fails at step 6 (after the step-4 checkpoint)
    inj = FailureInjector(fail_at_steps=[6])
    t1 = _mk_trainer(tmp_path / "x", cfg, injector=inj)
    with pytest.raises(RuntimeError, match="injected failure"):
        t1.run(jax.tree.map(jnp.copy, params0))
    # restart — auto-restores step 4 and replays the same data stream
    t2 = _mk_trainer(tmp_path / "x", cfg)
    p2, _, step = t2.run(jax.tree.map(jnp.copy, params0))
    assert step == 12
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2), strict=False):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_per_step():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    a = SyntheticLM(cfg, 16, 4, seed=3).batch_at(11)
    b = SyntheticLM(cfg, 16, 4, seed=3).batch_at(11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg, 16, 4, seed=3).batch_at(12)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_sharding_partitions_batch():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    full = SyntheticLM(cfg, 8, 8, seed=0, shard=0, num_shards=1).batch_at(0)
    s0 = SyntheticLM(cfg, 8, 8, seed=0, shard=0, num_shards=2).batch_at(0)
    assert s0["tokens"].shape[0] == 4


def test_prefetch_iterator_order():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    it = make_data_iterator(cfg, 8, 4, seed=5, start_step=3, prefetch=2)
    first = next(it)
    direct = SyntheticLM(cfg, 8, 4, seed=5).batch_at(3)
    np.testing.assert_array_equal(first["tokens"], direct["tokens"])


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(threshold=3.0)
    for i in range(10):
        assert not wd.observe(i, 0.1)
    assert wd.observe(10, 1.0)          # 10x median
    assert wd.flagged and wd.flagged[0][0] == 10


def test_elastic_remesh_and_reshard():
    from repro.distributed import make_elastic_mesh, reshard_state
    from repro.distributed.elastic import choose_mesh_shape

    assert choose_mesh_shape(512, 16) == (32, 16)
    assert choose_mesh_shape(448, 16) == (28, 16)     # lost 4 hosts of 16
    assert choose_mesh_shape(6, 4) == (2, 3)   # keeps TP degree maximal
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, 0)
    mesh = make_elastic_mesh(jax.devices(), prefer_model=1)
    p2, _ = reshard_state(params, None, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2), strict=False):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
