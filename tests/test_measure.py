"""Measured-cost autotuning: the timing harness, the versioned
MeasuredCostStore, the planner's measured-over-analytic preference, and the
cold-start guarantees (empty / wrong-device / stale-schema stores must fall
back to analytic costs without changing any plan)."""
import json
import os

import numpy as np
import pytest

from conftest import compile_and_compare, make_feeds as _feeds
from repro.core import (
    MeasuredCost,
    MeasuredCostStore,
    StitchOptions,
    compile_module,
    device_fingerprint,
    emit_group,
    measure_callable,
    measure_group,
    measure_kernel,
)
from repro.core.measure import MEASURE_SCHEMA_VERSION
from repro.core.perf_library import JsonStore

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from graphs import (  # noqa: E402
    ALL_GRAPHS,
    reduce_towers_graph,
    stitch_pipeline_graph,
)


def _kernels(comp):
    return comp.stats.stitched_kernels + comp.stats.standalone_kernels


def _fusable_members(module):
    return [
        i
        for i in module.instructions
        if i.opcode not in ("parameter", "constant") and not i.is_library_call
    ]


# ----------------------------------------------------------- store basics
def test_store_roundtrip(tmp_path):
    path = str(tmp_path / "measured.json")
    fp = device_fingerprint()
    s = MeasuredCostStore(path, device_fp=fp)
    assert s.get("sig") is None and s.misses == 1
    s.put("sig", 1.5e-3, model_s=2e-6, repeats=5)
    s.save()

    s2 = MeasuredCostStore(path, device_fp=fp)
    rec = s2.get("sig")
    assert rec == MeasuredCost(cost_s=1.5e-3, model_s=2e-6, repeats=5)
    assert s2.hits == 1 and s2.misses == 0 and len(s2) == 1


def test_stale_schema_version_rows_evicted_not_raised(tmp_path):
    path = str(tmp_path / "measured.json")
    fp = device_fingerprint()
    s = MeasuredCostStore(path, device_fp=fp)
    s.put("sig", 1e-3)
    s.save()
    with open(path) as f:
        rows = json.load(f)
    for rec in rows.values():
        rec["version"] = MEASURE_SCHEMA_VERSION - 1
    with open(path, "w") as f:
        json.dump(rows, f)

    s2 = MeasuredCostStore(path, device_fp=fp)
    assert s2.get("sig") is None
    assert s2.stale_discards == 1 and s2.misses == 1
    assert len(s2) == 0                       # evicted, not just skipped


def test_wrong_device_rows_evicted(tmp_path):
    """A row whose key matches but whose device field disagrees (e.g. the
    file was hand-merged from another machine) is evicted on read."""
    path = str(tmp_path / "measured.json")
    fp = device_fingerprint()
    s = MeasuredCostStore(path, device_fp=fp)
    s.put("sig", 1e-3)
    s.save()
    with open(path) as f:
        rows = json.load(f)
    for rec in rows.values():
        rec["device"] = "0" * 16
    with open(path, "w") as f:
        json.dump(rows, f)

    s2 = MeasuredCostStore(path, device_fp=fp)
    assert s2.get("sig") is None and s2.stale_discards == 1


@pytest.mark.parametrize(
    "payload",
    [
        {"cost_s": "garbage"},
        {"cost_s": 0.0},                      # non-positive time is corrupt
        {"cost_s": float("nan")},
        {},                                   # missing fields entirely
        "not even a dict",
    ],
)
def test_corrupt_rows_evicted_not_raised(tmp_path, payload):
    path = str(tmp_path / "measured.json")
    fp = device_fingerprint()
    s = MeasuredCostStore(path, device_fp=fp)
    s.put("sig", 1e-3)
    s.save()
    with open(path) as f:
        rows = json.load(f)
    key = next(iter(rows))
    if isinstance(payload, dict):
        rows[key] = {
            "version": MEASURE_SCHEMA_VERSION, "device": fp, **payload
        }
    else:
        rows[key] = payload
    with open(path, "w") as f:
        json.dump(rows, f)

    s2 = MeasuredCostStore(path, device_fp=fp)
    assert s2.get("sig") is None
    assert s2.stale_discards == 1


def test_device_fingerprint_varies_with_interpret_flag():
    assert device_fingerprint(interpret=True) != device_fingerprint(
        interpret=False
    )


# ------------------------------------------------- atomic save (crash sim)
def test_atomic_save_survives_crash_mid_write(tmp_path, monkeypatch):
    """A crash mid-``json.dump`` must leave the previous store intact and no
    scratch file behind — the temp-file + ``os.replace`` protocol."""
    path = str(tmp_path / "store.json")
    s = JsonStore(path)
    s.put("k", 1)
    s.save()

    s.put("k2", 2)

    def exploding_dump(obj, f, **kw):
        f.write('{"torn')                     # a torn prefix hits the disk
        raise RuntimeError("simulated crash mid-write")

    monkeypatch.setattr(json, "dump", exploding_dump)
    with pytest.raises(RuntimeError):
        s.save()
    monkeypatch.undo()

    with open(path) as f:
        assert json.load(f) == {"k": 1}       # previous save, not torn bytes
    stray = [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]
    assert stray == []                        # scratch file cleaned up

    s.save()                                  # and a later save recovers
    with open(path) as f:
        assert json.load(f) == {"k": 1, "k2": 2}


def test_atomic_save_ignores_preexisting_partial_tmp(tmp_path):
    """Scratch names are unique (mkstemp): junk left by a crashed writer at
    a guessable ``path + '.tmp'`` can never be replaced over the store."""
    path = str(tmp_path / "store.json")
    with open(path + ".tmp", "w") as f:
        f.write('{"torn')
    s = JsonStore(path)
    s.put("k", 1)
    s.save()
    with open(path) as f:
        assert json.load(f) == {"k": 1}


def test_measured_store_save_is_atomic(tmp_path, monkeypatch):
    """The tuning store rides the same protocol as the kernel cache."""
    path = str(tmp_path / "measured.json")
    fp = device_fingerprint()
    s = MeasuredCostStore(path, device_fp=fp)
    s.put("sig", 1e-3)
    s.save()

    s.put("sig2", 2e-3)

    def exploding_dump(obj, f, **kw):
        raise RuntimeError("simulated crash")

    monkeypatch.setattr(json, "dump", exploding_dump)
    with pytest.raises(RuntimeError):
        s.save()
    monkeypatch.undo()

    s2 = MeasuredCostStore(path, device_fp=fp)
    assert s2.get("sig") is not None          # old store still readable


# ------------------------------------------------------ the timing harness
def test_measure_callable_median_with_warmup():
    calls = []

    def fn(x):
        calls.append(1)
        return x

    t = measure_callable(fn, [np.ones(4)], repeats=3, warmup=2)
    assert t >= 0.0
    assert len(calls) == 5                    # 2 warmup + 3 timed


def test_emit_and_measure_single_schedule_group():
    m = reduce_towers_graph(num_towers=1)
    members = _fusable_members(m)
    kernel = emit_group(members, max_blocks=64)
    assert kernel is not None and not kernel.stitched
    t = measure_kernel(kernel, repeats=2)
    assert t > 0.0
    assert measure_group(members, repeats=1, max_blocks=64) > 0.0


def test_emit_and_measure_stitched_group():
    """StitchPipe's fusable chain has no single consistent schedule — the
    harness must fall back to the multi-phase stitched lowering, so
    stitched-vs-split alternatives are both measurable."""
    m = stitch_pipeline_graph()
    members = _fusable_members(m)
    kernel = emit_group(members, max_blocks=64)
    assert kernel is not None and kernel.stitched
    assert measure_kernel(kernel, repeats=1) > 0.0


def test_measure_group_returns_none_for_infeasible_groups():
    m = stitch_pipeline_graph()
    members = _fusable_members(m)
    # a 1-byte VMEM budget can stage neither scratch nor interface buffers:
    # no lowering exists, exactly the sets the scorer returns None for
    assert emit_group(members, vmem_limit=1) is None
    assert measure_group(members, vmem_limit=1) is None


# ----------------------------------------------------- options / fingerprint
def test_measure_repeats_validated():
    with pytest.raises(ValueError, match="measure_repeats"):
        StitchOptions(measure_repeats=0)


def test_autotune_knobs_salt_options_fingerprint():
    from repro.core.pipeline import _options_fingerprint

    base = StitchOptions(max_blocks=64)
    assert _options_fingerprint(base) != _options_fingerprint(
        StitchOptions(max_blocks=64, autotune=True)
    )
    assert _options_fingerprint(base) != _options_fingerprint(
        StitchOptions(max_blocks=64, measure_repeats=9)
    )
    assert _options_fingerprint(base) != _options_fingerprint(
        StitchOptions(max_blocks=64, tuning_store_path="/tmp/t.json")
    )


# --------------------------------------------------- autotune, end to end
def test_autotune_measures_and_persists(tmp_path):
    path = str(tmp_path / "measured.json")
    opts = StitchOptions(
        max_blocks=64, autotune=True, measure_repeats=2,
        tuning_store_path=path,
    )
    c1 = compile_module(reduce_towers_graph(num_towers=1), opts)
    assert c1.stats.measurements_taken > 0
    assert c1.stats.measured_hits == 0        # cold store
    assert c1.stats.model_error_pct is not None

    with open(path) as f:
        rows = json.load(f)
    assert rows
    fp = device_fingerprint(interpret=opts.interpret)
    for key, rec in rows.items():
        assert key.startswith(fp + "|")
        assert rec["version"] == MEASURE_SCHEMA_VERSION
        assert rec["device"] == fp
        assert rec["cost_s"] > 0.0

    c2 = compile_module(reduce_towers_graph(num_towers=1), opts)
    assert c2.stats.measured_hits > 0         # warm store served the planner


def test_warm_store_flips_plan_decision():
    """THE closed-loop assertion: interpret-mode measurements (milliseconds)
    contradict the analytic model (microseconds) about whether packing two
    towers into one kernel pays.  Cold, the planner trusts the model and
    packs; warm, the store's measured cost of the packed kernel loses to the
    analytic per-tower split costs and the SAME graph re-plans to 2 kernels
    — the store entry provably flipped the decision."""
    opts = StitchOptions(max_blocks=64, autotune=True, measure_repeats=2)
    store = MeasuredCostStore()
    cold = compile_module(reduce_towers_graph(num_towers=2), opts,
                          measured_store=store)
    assert _kernels(cold) == 1                # analytic: packing wins
    assert cold.stats.measurements_taken > 0

    warm = compile_module(reduce_towers_graph(num_towers=2), opts,
                          measured_store=store)
    assert warm.stats.measured_hits > 0
    assert _kernels(warm) == 2                # measured: packing loses

    # and the flipped plan still computes the right answer
    rng = np.random.RandomState(0)
    m = reduce_towers_graph(num_towers=2)
    compile_and_compare(
        m, _feeds(m, rng), max_blocks=64, autotune=True,
    )


def test_read_only_store_reuses_autotuned_measurements(tmp_path):
    """tuning_store_path WITHOUT autotune reads measurements but never takes
    new ones — the measure salt deliberately excludes the autotune knobs."""
    path = str(tmp_path / "measured.json")
    warm_opts = StitchOptions(
        max_blocks=64, autotune=True, measure_repeats=2,
        tuning_store_path=path,
    )
    compile_module(reduce_towers_graph(num_towers=2), warm_opts)

    ro_opts = StitchOptions(max_blocks=64, tuning_store_path=path)
    ro = compile_module(reduce_towers_graph(num_towers=2), ro_opts)
    assert ro.stats.measurements_taken == 0
    assert ro.stats.measured_hits > 0
    assert _kernels(ro) == 2                  # measured costs still flip it


def test_frontend_autotune_kwarg(tmp_path):
    import jax.numpy as jnp
    from repro import stitch

    @stitch(autotune=True)
    def f(x):
        return jnp.tanh(x * 0.5) + x

    x = np.random.RandomState(0).rand(8, 16).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(f(x)), np.tanh(x * 0.5) + x, rtol=2e-5, atol=2e-5
    )
    assert f.options.autotune
    assert f.stats.measurements_taken > 0
    assert f._measured_store is not None and len(f._measured_store) > 0


# ------------------------------------------- cold-start property (10 graphs)
def _plan_shape(comp):
    """Structural view of a compiled plan, independent of the options salt
    (reports carry ``salt + sha256``; the raw hash is the last 64 chars)."""
    return sorted(
        (
            r.num_ops,
            r.blocks,
            round(r.cost_s, 15),
            # root names carry global instruction counters (sub.8 vs sub.50
            # across fresh builds of the same graph): keep the opcode part
            tuple(n.rsplit(".", 1)[0] for n in r.roots),
            r.num_phases,
            r.signature[-64:],
        )
        for r in comp.stats.reports
    ), comp.stats.stitched_kernels, comp.stats.standalone_kernels


def _tampered_store(tmp_path, name, graph_fn, opts, kind: str):
    """A store that LOOKS warm for this graph but must serve nothing:
    empty, wrong-device rows, or stale-schema rows."""
    if kind == "empty":
        return MeasuredCostStore()
    path = str(tmp_path / f"{name}-{kind}.json")
    warm = StitchOptions(
        **{**opts.__dict__, "autotune": True, "measure_repeats": 1,
           "tuning_store_path": path}
    )
    compile_module(graph_fn(), warm)
    with open(path) as f:
        rows = json.load(f)
    assert rows
    for rec in rows.values():
        if kind == "stale_version":
            rec["version"] = MEASURE_SCHEMA_VERSION - 1
        elif kind == "device_mismatch":
            rec["device"] = "0" * 16
    with open(path, "w") as f:
        json.dump(rows, f)
    store = MeasuredCostStore(
        path, device_fp=device_fingerprint(interpret=opts.interpret)
    )
    return store


@pytest.mark.parametrize("planner", ["greedy", "cost"])
def test_cold_start_plans_identical_to_analytic(tmp_path, planner):
    """Empty store, DeviceSpec-fingerprint mismatch, and schema-version bump
    must all degrade to pure analytic planning: on every bench graph, both
    planner modes, the plan is structurally identical to a no-store compile
    and no measurement ever serves (measured_hits == 0)."""
    for name, graph_fn in ALL_GRAPHS.items():
        opts = StitchOptions(max_blocks=64, planner=planner)
        ref = compile_module(graph_fn(), opts)
        ref_shape = _plan_shape(ref)
        for kind in ("empty", "device_mismatch", "stale_version"):
            store = _tampered_store(tmp_path, name, graph_fn, opts, kind)
            comp = compile_module(graph_fn(), opts, measured_store=store)
            assert comp.stats.measured_hits == 0, (name, kind)
            assert comp.stats.measurements_taken == 0, (name, kind)
            assert _plan_shape(comp) == ref_shape, (name, kind)
