import os
import sys

# Sharded-compile tests need a real multi-device mesh; jax locks the device
# count on first init, so the flag must be set before `import jax` (the same
# idiom as launch/dryrun.py, which sets its own 512-way count per-process).
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import numpy as np
import pytest

import jax

jax.config.update("jax_platform_name", "cpu")


def compile_and_compare(module, feeds, rtol=2e-5, atol=2e-5, **opt_kwargs):
    """Compile a StitchIR module and assert stitched == reference."""
    from repro.core import StitchOptions, compile_module, reference_execute

    opts = StitchOptions(max_blocks=opt_kwargs.pop("max_blocks", 32), **opt_kwargs)
    compiled = compile_module(module, opts)
    ref = reference_execute(module, feeds)
    out = compiled(feeds)
    assert set(out) == set(ref)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out[k], dtype=np.float64),
            np.asarray(ref[k], dtype=np.float64),
            rtol=rtol,
            atol=atol,
            err_msg=f"root {k} diverged",
        )
    return compiled


sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from graphs import random_feeds as make_feeds  # noqa: E402,F401  (canonical copy)


@pytest.fixture
def rng():
    return np.random.RandomState(0)
