"""Traced ExecutionPlan replay: jit/eager oracle parity, dispatch
accounting, buffer-release correctness, and feed validation."""
import os
import sys

import numpy as np
import pytest

from conftest import make_feeds as _feeds
from repro.core import GraphBuilder, StitchOptions, compile_module, trace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from graphs import ALL_GRAPHS  # noqa: E402

OPTS = StitchOptions(max_blocks=64)


# ------------------------------------------------------- oracle parity
@pytest.mark.parametrize("name", sorted(ALL_GRAPHS))
def test_jit_replay_bit_identical_to_eager(name, rng):
    """The acceptance bar: traced replay == eager loop, bit for bit, on
    every benchmark graph (segment boundaries at layout-hazardous library
    calls + optimization barriers make this hold by construction)."""
    module = ALL_GRAPHS[name]()
    comp = compile_module(module, OPTS)
    feeds = _feeds(module, rng)
    eager = comp.executable.execute_eager(feeds)
    traced = comp.executable.jit_execute(feeds)
    traced2 = comp.executable.jit_execute(feeds)   # steady-state call
    assert set(eager) == set(traced)
    for k in eager:
        e = np.asarray(eager[k])
        assert np.array_equal(e, np.asarray(traced[k]), equal_nan=True), (
            f"{name}/{k}: traced replay diverged from the eager oracle"
        )
        assert np.array_equal(e, np.asarray(traced2[k]), equal_nan=True), (
            f"{name}/{k}: second traced call diverged (donation reuse?)"
        )


def test_dispatch_accounting_and_reduction():
    """Traced replay must never dispatch more than eager, and graphs that
    fuse to one kernel must replay as ONE dispatch."""
    for name, fn in ALL_GRAPHS.items():
        comp = compile_module(fn(), OPTS)
        s = comp.stats
        assert 1 <= s.traced_dispatches_per_call <= max(
            1, s.eager_dispatches_per_call
        )
        assert s.replay_dispatch_reduction >= 0
        if s.eager_dispatches_per_call == 1:
            assert s.traced_dispatches_per_call == 1
    # the multi-step graphs are where the launch win lives
    comp = compile_module(ALL_GRAPHS["BiRNN"](), OPTS)
    s = comp.stats
    assert s.traced_dispatches_per_call < s.eager_dispatches_per_call


def test_default_call_routes_through_traced_replay(rng):
    module = ALL_GRAPHS["Stacked"]()
    comp = compile_module(module, OPTS)
    assert comp.stats.replay_mode == "jit"
    comp(_feeds(module, rng))
    st = comp.executable.launch_stats()
    assert st.traced_calls == 1 and st.eager_calls == 0
    assert st.jit_traces >= 1


def test_jit_replay_disabled_keeps_eager_loop(rng):
    module = ALL_GRAPHS["Stacked"]()
    comp = compile_module(
        module, StitchOptions(max_blocks=64, jit_replay=False)
    )
    assert comp.stats.replay_mode == "eager"
    comp(_feeds(module, rng))
    st = comp.executable.launch_stats()
    assert st.eager_calls == 1 and st.traced_calls == 0
    assert st.jit_traces == 0


def test_steady_state_traces_once(rng):
    """Retracing on every call would re-pay compilation: segment traces
    must not grow after the first call."""
    module = ALL_GRAPHS["RNN"]()
    comp = compile_module(module, OPTS)
    feeds = _feeds(module, rng)
    comp(feeds)
    first = comp.executable.launch_stats().jit_traces
    comp(feeds)
    comp(feeds)
    assert comp.executable.launch_stats().jit_traces == first


def test_donation_covers_only_runtime_owned_intermediates():
    """Dead-after-segment intermediates are donated; parameter and
    folded-constant buffers never are (the caller / the template still
    holds them — donating one would invalidate it for the next call)."""
    comp = compile_module(ALL_GRAPHS["Stacked"](), OPTS)
    assert comp.stats.donated_buffers > 0
    ep = comp.executable.execution_plan
    template_slots = {
        s for s, v in enumerate(ep._template) if v is not None
    }
    param_slots = {slot for _, slot, _, _ in ep._param_binds}
    for seg in ep._segments:
        for i in seg.donate:
            slot = seg.in_slots[i]
            assert slot in seg.released, "donated input must be dead after"
            assert slot not in template_slots
            assert slot not in param_slots


def test_repeated_calls_with_jax_array_feeds(rng):
    """Steady-state serving pattern: device-resident feeds reused across
    calls must survive donation (regression: donated param buffers used to
    be deleted out from under the caller)."""
    import jax.numpy as jnp

    module = ALL_GRAPHS["Stacked"]()
    comp = compile_module(module, OPTS)
    feeds = {k: jnp.asarray(v) for k, v in _feeds(module, rng).items()}
    out1 = comp(feeds)
    out2 = comp(feeds)                 # same jax arrays, second call
    for k in out1:
        assert np.array_equal(np.asarray(out1[k]), np.asarray(out2[k]))


# ----------------------------------------------------- release behavior
def _leaked_slots(ep):
    root_slots = {s for _, s in ep._root_binds}
    released = [s for step in ep.steps for s in step.release]
    assert len(released) == len(set(released)), "slot released twice"
    written = set()
    for step in ep.steps:
        written.update(
            step.out_slots if hasattr(step, "out_slots") else [step.out_slot]
        )
    return written - set(released) - root_slots


def test_no_leaked_slots_on_benchmark_graphs():
    """Every slot a step writes is either a module root or released at
    some step — nothing may sit in the buffer table for the whole run."""
    for name, fn in ALL_GRAPHS.items():
        comp = compile_module(fn(), OPTS)
        leaked = _leaked_slots(comp.executable.execution_plan)
        assert not leaked, f"{name}: slots never released: {leaked}"


class _FakeKernel:
    """Stand-in for a deduped/packed StitchedKernel whose output list is a
    superset of what this instance's consumers read."""

    def __init__(self, inputs, outputs, fn):
        self.inputs = inputs
        self.outputs = outputs
        self._fn = fn

    def __call__(self, *args):
        return self._fn(*args)


def test_dead_kernel_output_released_at_producing_step(rng):
    """Buffer-leak regression (ISSUE satellite): a multi-output kernel
    slot with no reader is never in ``last_read``; it must be released at
    the step that produces it, not held for the whole run."""
    import jax.numpy as jnp

    from repro.core.executor import ExecutionPlan, _KernelStep
    from repro.core.fusion import FusedComputation, FusionPlan

    b = GraphBuilder("dead_out")
    x = b.parameter("x", (8, 8), np.float32)
    a = b.tanh(x)
    e = b.exp(a)
    g = e + a                      # the only sink
    module = b.module
    f1 = FusedComputation([a.instr, e.instr], name="k1")
    f2 = FusedComputation([g.instr], name="k2")
    kernels = {
        # k1 emits BOTH values; k2 recomputes exp(a) internally (as a
        # packed/replicated kernel would) so e's slot has no reader
        "k1": _FakeKernel(
            [x.instr], [a.instr, e.instr],
            lambda xv: (jnp.tanh(xv), jnp.exp(jnp.tanh(xv))),
        ),
        "k2": _FakeKernel(
            [a.instr], [g.instr], lambda av: (jnp.exp(av) + av,)
        ),
    }
    plan = FusionPlan([f1, f2], [], module)
    ep = ExecutionPlan(module, plan, kernels)

    e_slot = next(
        s
        for step in ep.steps
        if type(step) is _KernelStep and len(step.out_slots) == 2
        for s in step.out_slots[1:]
    )
    producer = next(
        step
        for step in ep.steps
        if type(step) is _KernelStep and e_slot in step.out_slots
    )
    assert e_slot in producer.release, (
        "dead multi-output kernel slot must be freed at its producing step"
    )
    assert not _leaked_slots(ep)
    # the plan still computes the module, and both replay modes agree
    feeds = {"x": rng.randn(8, 8).astype(np.float32)}
    ref = np.exp(np.tanh(feeds["x"])) + np.tanh(feeds["x"])
    eager = ep.execute(feeds)
    traced = ep.jit_execute(feeds)
    (key,) = eager.keys()
    np.testing.assert_allclose(
        np.asarray(eager[key]), ref, rtol=1e-5, atol=1e-6
    )
    assert np.array_equal(np.asarray(eager[key]), np.asarray(traced[key]))


def test_eager_release_drops_buffers(rng):
    """The eager loop must end with only root slots populated (observed
    through a probe subclass of list used as the buffer table)."""
    module = ALL_GRAPHS["Stacked"]()
    comp = compile_module(module, OPTS)
    ep = comp.executable.execution_plan
    feeds = _feeds(module, rng)
    ep.execute(feeds)  # warm
    # replicate execute() with a final-buffer snapshot
    buf = list(ep._template)
    for (name, slot, dtype, shape), v in zip(
        ep._param_binds, ep._bind_feeds(feeds)
    , strict=False):
        buf[slot] = v
    from repro.core.executor import _KernelStep
    from repro.core.ir import apply_op

    for step in ep.steps:
        if type(step) is _KernelStep:
            outs = step.kernel(*[buf[s] for s in step.arg_slots])
            for s, o in zip(step.out_slots, outs, strict=False):
                buf[s] = o
        else:
            buf[step.out_slot] = apply_op(
                step.instr, *[buf[s] for s in step.arg_slots]
            )
        for s in step.release:
            buf[s] = None
    root_slots = {s for _, s in ep._root_binds}
    template_slots = {s for s, v in enumerate(ep._template) if v is not None}
    live = {s for s, v in enumerate(buf) if v is not None}
    assert live <= root_slots | template_slots, (
        f"non-root buffers still live after the run: "
        f"{live - root_slots - template_slots}"
    )


# ------------------------------------------------------ feed validation
def test_missing_feed_raises_named_error(rng):
    """execute()/jit_execute() name the missing parameter like
    reference_execute does — not a bare KeyError from a dict lookup."""
    module = ALL_GRAPHS["LR"]()
    comp = compile_module(module, OPTS)
    feeds = _feeds(module, rng)
    missing = sorted(feeds)[0]
    del feeds[missing]
    for runner in (comp.executable.execute_eager, comp.executable.jit_execute):
        with pytest.raises(KeyError, match=f"missing feed for parameter {missing}"):
            runner(feeds)


def test_bad_feed_shape_raises(rng):
    module = ALL_GRAPHS["LR"]()
    comp = compile_module(module, OPTS)
    feeds = _feeds(module, rng)
    name = sorted(feeds)[0]
    feeds[name] = np.zeros((3, 3), np.float32)
    with pytest.raises(ValueError, match="feed shape"):
        comp.executable.jit_execute(feeds)


def test_multi_root_builder_graph_parity(rng):
    """Hand-built two-sink module (not from the benchmark set): both
    replay modes agree with each other bit-for-bit."""
    def f(b, x, y):
        s = b.tanh(x + y)
        t = b.reduce(s, (1,), "sum")
        u = b.exp(b.broadcast(t, (16, 16), (0,)) - s)
        return s * 2.0, u          # two sinks -> two module roots

    module = trace(
        f, ("x", (16, 16), np.float32), ("y", (16, 16), np.float32)
    )
    comp = compile_module(module, OPTS)
    feeds = _feeds(module, rng)
    eager = comp.executable.execute_eager(feeds)
    traced = comp.executable.jit_execute(feeds)
    assert len(eager) >= 2
    for k in eager:
        assert np.array_equal(np.asarray(eager[k]), np.asarray(traced[k]))
