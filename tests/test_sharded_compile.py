"""Shard-aware compilation: collective IR ops, layout propagation, the
sharded frontend, and the one-multi-device-ExecutionPlan replay.

Runs on the 8 host-platform CPU devices conftest.py forces (the
``--xla_force_host_platform_device_count=8`` flag set before jax init);
every parity check compares the stitched plan bit-for-bit against the
``jax.jit(shard_map(fn))`` oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compiler import StitchOptions, compile_module
from repro.core.ir import GraphBuilder, infer_shape
from repro.core.shard import (
    mesh_axes_of,
    propagate_layouts,
    spec_to_layout,
    wrap_shard_map,
)
from repro.core.signature import fusion_signature

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs the 8-device host-platform fixture"
)


def _mesh():
    return Mesh(np.array(jax.devices()[:8]).reshape(8), ("model",))


MESH_AXES = (("model", 8),)


# ------------------------------------------------------ collective IR ops
def test_collective_shape_inference():
    assert infer_shape("all_reduce", [(4, 8)], {"axes": ("model",)}) == (4, 8)
    assert infer_shape(
        "all_gather", [(4, 8)], {"axes": ("model",), "dim": 1, "group_size": 8}
    ) == (4, 64)
    assert infer_shape(
        "reduce_scatter",
        [(4, 64)],
        {"axes": ("model",), "dim": 1, "group_size": 8},
    ) == (4, 8)
    with pytest.raises(ValueError, match="divisible"):
        infer_shape(
            "reduce_scatter",
            [(4, 9)],
            {"axes": ("model",), "dim": 1, "group_size": 8},
        )


def test_is_collective_flag():
    b = GraphBuilder("m")
    x = b.parameter("x", (4, 8))
    r = b.all_reduce(x, "model")
    assert r.instr.is_collective and not x.instr.is_collective
    assert not r.instr.is_library_call


# --------------------------------------------------- layout propagation
def _tp_module():
    """Row-parallel dot: x replicated, w k-sharded -> partial -> all_reduce."""
    b = GraphBuilder("tp")
    x = b.parameter("x", (8, 4))
    w = b.parameter("w", (4, 16))
    y = b.dot(x, w)
    r = b.all_reduce(y, "model")
    b.unary("tanh", r)
    return b.module


def test_propagate_layouts_partial_tracking():
    m = _tp_module()
    stats = propagate_layouts(
        m, MESH_AXES, {"x": (None, ("model",)), "w": (("model",), None)}
    )
    by_name = {i.name: i for i in m.instructions}
    dot = next(i for i in m.instructions if i.opcode == "dot")
    ar = next(i for i in m.instructions if i.opcode == "all_reduce")
    tanh = next(i for i in m.instructions if i.opcode == "elementwise")
    # k-sharded contraction: the dot output is a pending partial sum …
    assert dot.attrs["partial"] == ("model",)
    # … the all_reduce clears it, and nothing downstream carries it
    assert "partial" not in ar.attrs and "partial" not in tanh.attrs
    assert stats["collective_ops"] == 1
    assert by_name["x"].attrs["shard"] == (None, ("model",))


def test_propagate_layouts_conflict_raises():
    b = GraphBuilder("c")
    x = b.parameter("x", (8, 8))
    y = b.parameter("y", (8, 8))
    b.binary("add", x, y)
    with pytest.raises(ValueError, match="conflict"):
        propagate_layouts(
            b.module,
            MESH_AXES + (("data", 2),),
            {"x": (("model",), None), "y": (("data",), None)},
        )


def test_propagate_layouts_validates_mesh():
    b = GraphBuilder("v")
    x = b.parameter("x", (8, 8))
    b.all_reduce(x, "nonexistent")
    with pytest.raises(ValueError, match="mesh has axes"):
        propagate_layouts(b.module, MESH_AXES, {})

    b2 = GraphBuilder("v2")
    x2 = b2.parameter("x", (8, 8))
    b2.all_gather(x2, "model", dim=1, group_size=4)  # mesh size is 8
    with pytest.raises(ValueError, match="group_size"):
        propagate_layouts(b2.module, MESH_AXES, {})


# ------------------------------------------- collectives break schedules
def test_collective_is_a_schedule_break():
    m = _tp_module()
    opts = StitchOptions(mesh_axes=MESH_AXES)
    compiled = compile_module(m, opts)
    plan = compiled.executable.plan
    standalone_colls = [s for s in plan.standalone if s.is_collective]
    assert len(standalone_colls) == 1
    # collectives are ICI traffic, never kernels: excluded from every count
    assert plan.num_collectives == 1
    assert all(
        not any(mm.is_collective for mm in f.members) for f in plan.fusions
    )
    assert compiled.stats.collective_calls == 1
    assert compiled.stats.collective_time_s > 0


# ------------------------------------------------- cache never aliases
def test_fusion_signature_salted_by_shard_layout():
    from repro.core.fusion import FusedComputation

    def col_parallel():
        b = GraphBuilder("cp")
        x = b.parameter("x", (8, 4))
        w = b.parameter("w", (4, 16))     # per-shard slice of (4, 128)
        b.unary("tanh", b.dot(x, w))
        return b.module

    m1, m2 = col_parallel(), col_parallel()
    # m2 is the SAME local computation, but as one shard of a column-parallel
    # matmul — the stamped layout must keep its kernels from aliasing m1's
    propagate_layouts(m2, MESH_AXES, {"w": (None, ("model",))})
    tanh1 = next(i for i in m1.instructions if i.opcode == "elementwise")
    tanh2 = next(i for i in m2.instructions if i.opcode == "elementwise")
    assert tanh2.attrs["shard"] == (None, ("model",))
    sig1 = fusion_signature(FusedComputation(members=[tanh1]))
    sig2 = fusion_signature(FusedComputation(members=[tanh2]))
    assert sig1 != sig2


def test_measure_salt_covers_mesh():
    from repro.core.pipeline import _measure_salt

    assert _measure_salt(StitchOptions()) != _measure_salt(
        StitchOptions(mesh_axes=MESH_AXES)
    )


# ------------------------------------------------------ sharded frontend
def test_unlowered_collective_raises_named_error():
    from repro.frontend.jaxpr_lower import (
        UnsupportedPrimitiveError,
        lower_sharded_jaxpr,
    )

    mesh = _mesh()

    def bad(x):
        return jax.lax.ppermute(
            x, "model", [(i, (i + 1) % 8) for i in range(8)]
        )

    closed = jax.make_jaxpr(
        wrap_shard_map(bad, mesh, (P("model"),), P("model"))
    )(jnp.arange(8.0))
    with pytest.raises(UnsupportedPrimitiveError, match="ppermute"):
        lower_sharded_jaxpr(closed)


def test_sharded_capture_requires_single_shard_map():
    from repro.frontend.jaxpr_lower import (
        UnsupportedPrimitiveError,
        lower_sharded_jaxpr,
    )

    closed = jax.make_jaxpr(lambda x: x + 1.0)(jnp.arange(4.0))
    with pytest.raises(UnsupportedPrimitiveError, match="shard_map"):
        lower_sharded_jaxpr(closed)


def _mlp(x, w1, w2):
    h = jax.nn.gelu(x @ w1)
    return jnp.tanh(jax.lax.psum(h @ w2, "model"))


_MLP_SPECS = dict(
    in_specs=(P(), P(None, "model"), P("model", None)), out_specs=P()
)


def _mlp_args(rng):
    return (
        jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
        jnp.asarray(rng.normal(size=(16, 64)), jnp.float32),
        jnp.asarray(rng.normal(size=(64, 16)), jnp.float32),
    )


def test_stitch_sharded_bitwise_parity(rng):
    from repro import stitch

    mesh = _mesh()
    sharded = stitch(_mlp, mesh=mesh, **_MLP_SPECS)
    args = _mlp_args(rng)
    out = sharded(*args)
    oracle = jax.jit(
        wrap_shard_map(_mlp, mesh, _MLP_SPECS["in_specs"], _MLP_SPECS["out_specs"])
    )(*args)
    assert jnp.all(out == oracle), "sharded replay must be bit-identical"
    s = sharded.stats
    assert s.replay_mode == "sharded"
    assert s.collective_calls == 1
    assert s.sharded_instrs > 0
    # the Megatron MLP stitches compute on BOTH sides of the all-reduce
    assert s.collective_breaks_spanned >= 1
    # plan cache: second call recompiles nothing and stays bit-identical
    assert jnp.all(sharded(*args) == oracle) and sharded.num_compiles == 1


def test_stitch_sharded_all_gather_reduce_scatter(rng):
    from repro import stitch

    mesh = _mesh()

    def fn(x):
        g = jax.lax.all_gather(x, "model", axis=0, tiled=True)
        return jax.lax.psum_scatter(
            g * 2.0, "model", scatter_dimension=0, tiled=True
        )

    specs = dict(in_specs=(P("model"),), out_specs=P("model"))
    sharded = stitch(fn, mesh=mesh, **specs)
    x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    out = sharded(x)
    oracle = jax.jit(wrap_shard_map(fn, mesh, specs["in_specs"], specs["out_specs"]))(x)
    assert jnp.all(out == oracle)
    assert sharded.stats.collective_calls == 2


def test_stitch_mesh_argument_validation():
    from repro import stitch

    with pytest.raises(ValueError, match="in_specs"):
        stitch(_mlp, mesh=_mesh())
    with pytest.raises(ValueError, match="mesh"):
        stitch(_mlp, in_specs=(P(),), out_specs=P())
    with pytest.raises(ValueError, match="donate"):
        stitch(_mlp, mesh=_mesh(), donate_argnums=0, **_MLP_SPECS)


def test_sharded_options_validation():
    with pytest.raises(ValueError, match="mesh_axes"):
        StitchOptions(mesh_axes=(("model", 0),)).validate()
    with pytest.raises(ValueError, match="mesh_axes"):
        StitchOptions(mesh_axes=((1, 8),)).validate()


def test_codegen_refuses_collective_members():
    from types import SimpleNamespace

    from repro.core.codegen import emit_fusion
    from repro.core.fusion import FusedComputation

    b = GraphBuilder("cg")
    x = b.parameter("x", (8,))
    r = b.all_reduce(x, "model")
    f = FusedComputation(members=[r.instr])
    sol = SimpleNamespace(assignment={}, blocks=1)
    with pytest.raises(ValueError, match="collective"):
        emit_fusion(f, sol, plan=None)
