"""Optimizer, schedules, loss, gradient accumulation, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_config, reduced_config
from repro.data import SyntheticLM
from repro.models import init_params
from repro.train import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cross_entropy,
    lr_at,
    make_train_step,
)
from repro.train.compression import (
    EFState,
    bf16_compress,
    compress_int8_ef,
    ef_init,
    wire_bytes,
)
from repro.train.optimizer import clip_by_global_norm, global_norm


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=110, schedule="cosine")
    assert float(lr_at(cfg, 0)) < 1e-3 * 0.2          # warmup ramp
    assert abs(float(lr_at(cfg, 10)) - 1e-3) < 1e-6   # peak at warmup end
    assert float(lr_at(cfg, 110)) <= 1e-3 * cfg.min_lr_ratio + 1e-9


def test_adamw_converges_quadratic():
    """AdamW drives a quadratic to its minimum — optimizer correctness."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3, 1))}
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1, total_steps=500,
                      schedule="constant")
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"][:, 0] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-3


def test_grad_clip():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 1.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_cross_entropy_masks_padded_vocab_and_labels():
    logits = jnp.zeros((1, 3, 8), jnp.float32)
    labels = jnp.asarray([[1, 2, -1]], jnp.int32)     # last position ignored
    loss = cross_entropy(logits, labels, vocab_size=5)  # cols 5..7 padded out
    assert abs(float(loss) - np.log(5)) < 1e-5           # uniform over 5 classes


def test_loss_decreases_on_tiny_model():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, schedule="constant")
    ))
    data = SyntheticLM(cfg, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::8]


def test_grad_accumulation_matches_full_batch():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, 0)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step1 = jax.jit(make_train_step(cfg, ocfg, accum_steps=1))
    step4 = jax.jit(make_train_step(cfg, ocfg, accum_steps=4))
    data = SyntheticLM(cfg, seq_len=16, global_batch=8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    p1, _, m1 = step1(params, adamw_init(params), batch)
    p4, _, m4 = step4(params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4), strict=False):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-4,
        )


# -------------------------------------------------------- compression
def test_int8_ef_roundtrip_reasonable():
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(64, 64).astype("f4"))}
    st = ef_init(g)
    wire, deq, st2 = compress_int8_ef(g, st)
    q, scale = wire["w"]
    assert q.dtype == jnp.int8
    rel = float(jnp.linalg.norm(deq["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02
    # wire payload is ~4x smaller
    assert wire_bytes({"w": q}) * 4 == wire_bytes(g)


def test_error_feedback_compensates_bias():
    """With EF, repeated quantized steps track the true gradient sum —
    residual accumulation cancels systematic quantization error."""
    rng = np.random.RandomState(0)
    true_sum = np.zeros(32, np.float32)
    applied = np.zeros(32, np.float32)
    st = ef_init({"w": jnp.zeros(32)})
    for i in range(50):
        g = {"w": jnp.asarray(rng.randn(32).astype("f4") * 0.1)}
        true_sum += np.asarray(g["w"])
        _, deq, st = compress_int8_ef(g, st)
        applied += np.asarray(deq["w"])
    resid = np.asarray(st.residual["w"])
    np.testing.assert_allclose(applied + resid, true_sum, rtol=1e-4, atol=1e-4)


def test_bf16_compress_halves_bytes():
    g = {"w": jnp.zeros((128, 128), jnp.float32)}
    assert wire_bytes(bf16_compress(g)) * 2 == wire_bytes(g)
