"""The unified LatencyModel: single device spec, per-op/per-fusion time."""
import jax.numpy as jnp
import pytest

from repro.core import (
    DeviceSpec,
    GraphBuilder,
    LatencyModel,
    deep_fuse,
    trace,
)
from repro.core.latency import TPU_V5E, instr_flops, instr_hbm_bytes
from repro.core.schedule import REPLICATED, any_satisfiable


# --------------------------------------------------- single source of truth
def test_perf_library_spec_is_the_latency_spec():
    from repro.core import perf_library

    assert perf_library.TpuSpec is DeviceSpec
    assert perf_library.TPU_V5E is TPU_V5E
    assert perf_library.CostModel is LatencyModel
    lib = perf_library.PerfLibrary()
    assert isinstance(lib.model, LatencyModel)
    assert lib.model.spec is TPU_V5E


def test_roofline_constants_derive_from_device_spec():
    from repro.launch import roofline

    assert roofline.PEAK_FLOPS == TPU_V5E.peak_flops_bf16
    assert roofline.HBM_BW == TPU_V5E.hbm_bw
    assert roofline.ICI_BW == TPU_V5E.ici_bw
    m = LatencyModel()
    assert m.compute_time(TPU_V5E.peak_flops_bf16) == pytest.approx(1.0)
    assert m.memory_time(TPU_V5E.hbm_bw, chips=2) == pytest.approx(0.5)
    assert m.collective_time(TPU_V5E.ici_bw) == pytest.approx(1.0)


def test_tuning_uses_shared_trivial_convention():
    from repro.core import latency, tuning

    assert tuning._is_trivial is latency.is_trivial


# ----------------------------------------------------------- per-op model
def _exp_module(shape=(64, 128)):
    return trace(lambda b, x: b.exp(x), ("x", shape, jnp.float32))


def test_op_time_positive_and_monotone_in_size():
    model = LatencyModel()
    small = _exp_module((8, 128)).instructions[-1]
    big = _exp_module((512, 128)).instructions[-1]
    t_small = model.op_time(small, REPLICATED, 1)
    t_big = model.op_time(big, REPLICATED, 1)
    assert 0 < t_small < t_big


def test_kernel_time_charges_launch_and_grid_steps():
    model = LatencyModel()
    assert model.kernel_time(1, 0.0) == pytest.approx(
        TPU_V5E.launch_overhead_s + TPU_V5E.grid_step_overhead_s
    )
    assert model.kernel_time(64, 0.0) > model.kernel_time(1, 0.0)


def test_standalone_time_includes_launch_overhead():
    model = LatencyModel()
    instr = _exp_module((8, 128)).instructions[-1]
    assert model.standalone_time(instr) > TPU_V5E.launch_overhead_s
    # parameters/constants never launch
    param = _exp_module((8, 128)).instructions[0]
    assert param.opcode == "parameter"
    assert model.standalone_time(param) == 0.0


def test_flops_and_bytes_helpers():
    m = trace(
        lambda b, x, w: b.dot(x, w),
        ("x", (4, 8), jnp.float32),
        ("w", (8, 16), jnp.float32),
    )
    dot = m.instructions[-1]
    assert instr_flops(dot) == 2.0 * 4 * 16 * 8
    assert instr_hbm_bytes(dot) == (4 * 16 + 4 * 8 + 8 * 16) * 4


# ------------------------------------------------------- per-fusion model
def _chain_fusion():
    m = trace(
        lambda b, x: b.sigmoid(b.exp(x) * 2.0 + 1.0),
        ("x", (16, 128), jnp.float32),
    )
    plan = deep_fuse(m)
    assert len(plan.fusions) == 1
    return plan.fusions[0]


def test_fusion_time_beats_standalone_sum_on_a_chain():
    """Fusing a chain saves launches and intermediate HBM round-trips."""
    model = LatencyModel()
    f = _chain_fusion()
    sol = any_satisfiable(f.members, f.roots)
    assert sol is not None
    fused = model.fusion_time(f.members, f.roots, sol)
    unfused = sum(model.standalone_time(m) for m in f.members)
    assert 0 < fused < unfused


def test_fusion_time_charges_replication_duplication():
    """A replicated member of a multi-block kernel recomputes per block."""
    model = LatencyModel()
    f = _chain_fusion()
    sol = any_satisfiable(f.members, f.roots)
    base = model.fusion_time(f.members, f.roots, sol)
    # force every member replicated under a many-block launch
    import dataclasses

    repl_sol = dataclasses.replace(
        sol,
        blocks=16,
        assignment={k: REPLICATED for k in sol.assignment},
    )
    assert model.fusion_time(f.members, f.roots, repl_sol) > base
