"""IrEmitterStitched: generated Pallas kernels vs the pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np

from conftest import compile_and_compare
from repro.core import trace


def feeds_for(module, rng, lo=-2.0, hi=2.0):
    out = {}
    for p in module.parameters:
        if np.dtype(p.dtype) == np.int32:
            out[p.name] = rng.randint(0, 4, size=p.shape).astype(np.int32)
        else:
            out[p.name] = rng.uniform(lo, hi, size=p.shape).astype(
                np.dtype(p.dtype)
            )
    return out


def run(fn, specs, rng, **kw):
    m = trace(fn, *specs)
    return compile_and_compare(m, feeds_for(m, rng), **kw)


def test_softmax_stitched(rng):
    run(
        lambda b, x: b.softmax(x, dim=-1),
        [("x", (4, 8, 16), jnp.float32)],
        rng,
    )


def test_softmax_dot_fig3(rng):
    def f(b, scores, v):
        return b.dot(b.softmax(scores, dim=-1), v, fusable=True)

    run(
        f,
        [("scores", (2, 4, 8, 8), jnp.float32), ("v", (2, 4, 8, 4), jnp.float32)],
        rng,
    )


def test_rmsnorm_pattern(rng):
    def f(b, x, g):
        ms = b.reduce(b.square(x), (2,), "mean")
        inv = b.rsqrt(ms + 1e-6)
        return x * b.broadcast(inv, x.shape, (0, 1)) * b.broadcast(g, x.shape, (2,))

    run(f, [("x", (2, 8, 32), jnp.float32), ("g", (32,), jnp.float32)], rng)


def test_column_reduce(rng):
    """Column reductions are an explicit XLA pain point the paper targets."""
    def f(b, x):
        s = b.reduce(x, (0,), "sum")           # reduce the MAJOR dim
        return b.tanh(s)

    run(f, [("x", (16, 8), jnp.float32)], rng)


def test_transpose_inside_fusion(rng):
    def f(b, x):
        t = b.transpose(x, (0, 2, 1))
        return b.exp(t) + 1.0

    run(f, [("x", (4, 6, 8), jnp.float32)], rng)


def test_reshape_chain(rng):
    def f(b, x):
        y = b.reshape(x, (8, 12))
        z = b.exp(y)
        return b.reshape(z, (4, 2, 12)) * 2.0

    run(f, [("x", (4, 24), jnp.float32)], rng)


def test_concat_fusion(rng):
    def f(b, x, y):
        c = b.concat([b.exp(x), b.tanh(y)], dim=1)
        return c * 0.5

    run(f, [("x", (4, 8), jnp.float32), ("y", (4, 8), jnp.float32)], rng)


def test_multi_root_horizontal(rng):
    def f(b, w0, g0, w1, g1):
        return (w0 - g0 * 0.1, w1 - g1 * 0.1)

    run(
        f,
        [(n, (8, 8), jnp.float32) for n in ("w0", "g0", "w1", "g1")],
        rng,
    )


def test_broadcast_scalar_and_vector(rng):
    def f(b, x, s):
        return x * b.broadcast(s, x.shape, (1,)) + 3.0

    run(f, [("x", (4, 8), jnp.float32), ("s", (8,), jnp.float32)], rng)


def test_select_and_compare(rng):
    def f(b, x, y):
        return b.select(x > y, x, y) - b.minimum(x, y)

    run(f, [("x", (4, 8), jnp.float32), ("y", (4, 8), jnp.float32)], rng)


def test_iota_member(rng):
    def f(b, x):
        pos = b.iota((4, 8), dim=1, dtype=jnp.float32)
        return x + pos

    run(f, [("x", (4, 8), jnp.float32)], rng)


def test_gather_small_table(rng):
    def f(b, table, idx):
        g = b.gather(table, idx)
        return b.tanh(g)

    m = trace(f, ("table", (16, 8), jnp.float32), ("idx", (4,), jnp.int32))
    feeds = {
        "table": rng.randn(16, 8).astype("f4"),
        "idx": rng.randint(0, 16, size=(4,)).astype(np.int32),
    }
    compile_and_compare(m, feeds)


def test_library_dot_boundary(rng):
    def f(b, x, w):
        h = b.tanh(b.dot(x, w))          # LC layer between the two fusions
        return b.softmax(h, dim=-1)

    c = run(f, [("x", (4, 8), jnp.float32), ("w", (8, 8), jnp.float32)], rng)
    assert c.stats.library_calls == 1


def test_mean_reduce_and_log(rng):
    def f(b, x):
        mu = b.reduce(x, (1,), "mean")
        d = x - b.broadcast(mu, x.shape, (0,))
        return b.log(b.abs(d) + 1.0)

    run(f, [("x", (8, 16), jnp.float32)], rng)


def test_bf16_softmax(rng):
    def f(b, x):
        return b.softmax(x, dim=-1)

    m = trace(f, ("x", (4, 16), jnp.bfloat16))
    feeds = {"x": rng.randn(4, 16).astype(jnp.bfloat16)}
    compile_and_compare(m, feeds, rtol=2e-2, atol=2e-2)


def test_deep_chain_single_kernel(rng):
    def f(b, x):
        for _ in range(12):
            x = b.tanh(x * 1.01)
        return x

    c = run(f, [("x", (8, 8), jnp.float32)], rng)
    assert c.stats.stitched_kernels == 1
    assert c.stats.standalone_kernels == 0
