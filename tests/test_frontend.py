"""jaxpr-frontend suite: repro.stitch parity, plan caching, fallback,
StitchOptions validation, duplicate-parameter rejection.

The parity contract: for each ported benchmark family, ``stitch(fn)`` must
produce outputs allclose to ``jax.jit(fn)`` AND commit the same kernel
counts as compiling the hand-built StitchIR module of the same computation.
"""
import os
import sys
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import (
    StitchOptions,
    StitchedFunction,
    UnsupportedPrimitiveError,
    compile_module,
    stitch,
)
from repro.core import GraphBuilder, Module, trace
from repro.frontend import SUPPORTED_PRIMITIVES, lower_jaxpr

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from graphs import JNP_FAMILIES  # noqa: E402

OPTS = StitchOptions(max_blocks=32)


def assert_tree_close(a, b, rtol=2e-5, atol=2e-5):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=False):
        np.testing.assert_allclose(
            np.asarray(x, dtype=np.float64),
            np.asarray(y, dtype=np.float64),
            rtol=rtol, atol=atol,
        )


# --------------------------------------------------------------------------
# end-to-end: pure-jnp functions, zero GraphBuilder calls
# --------------------------------------------------------------------------


def fig3_attention(q, k, v):
    d = q.shape[-1]
    s = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) * (1.0 / d ** 0.5)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    return jnp.matmul(e / jnp.sum(e, axis=-1, keepdims=True), v)


def rmsnorm(x, g):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + 1e-6) * g


def gated_mlp(x, w_gate, w_up):
    return jax.nn.silu(jnp.matmul(x, w_gate)) * jnp.matmul(x, w_up)


def layer_stats(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5)


def speech_head(x):
    lg = jnp.log(jnp.maximum(jnp.square(x), 1e-6))
    tr = jnp.transpose(lg, (0, 2, 1))
    feats = jnp.concatenate([tr, tr * 0.5 + 0.1], axis=1)
    return jnp.mean(jax.nn.sigmoid(feats) * feats, axis=2)


@pytest.mark.parametrize(
    "name,fn,arg_shapes",
    [
        ("fig3_attention", fig3_attention, [(2, 4, 16, 32)] * 3),
        ("rmsnorm", rmsnorm, [(16, 64), (64,)]),
        ("gated_mlp", gated_mlp, [(16, 64), (64, 128), (64, 128)]),
        ("layer_stats", layer_stats, [(8, 96)]),
        ("speech_head", speech_head, [(4, 20, 16)]),
    ],
)
def test_stitch_end_to_end(rng, name, fn, arg_shapes):
    args = [rng.randn(*s).astype("f4") for s in arg_shapes]
    stitched = stitch(fn, options=OPTS)
    out = stitched(*args)
    assert_tree_close(out, jax.jit(fn)(*args))
    assert stitched.num_compiles == 1
    assert stitched.stats.stitched_kernels + stitched.stats.standalone_kernels >= 1


@pytest.mark.parametrize("family", sorted(JNP_FAMILIES))
def test_parity_with_hand_built_modules(rng, family):
    """The frontend reproduces the hand-built plans: same kernel counts,
    outputs allclose to jax.jit of the same function."""
    fam = JNP_FAMILIES[family]
    hand = compile_module(fam["module"](), OPTS)
    stitched = stitch(fam["fn"], options=replace(OPTS, **fam["options"]))
    args = fam["args"](rng)
    assert_tree_close(stitched(*args), jax.jit(fam["fn"])(*args), rtol=2e-4, atol=2e-4)
    hs, fs = hand.stats, stitched.stats
    assert (fs.stitched_kernels, fs.standalone_kernels, fs.library_calls) == (
        hs.stitched_kernels, hs.standalone_kernels, hs.library_calls
    ), f"{family}: frontend plan diverged from the hand-built plan"


def test_fig3_attention_single_stitched_kernel(rng):
    """The paper's headline: attention lowers to ONE stitched kernel."""
    stitched = stitch(fig3_attention, options=OPTS)
    args = [rng.randn(2, 4, 16, 32).astype("f4") for _ in range(3)]
    stitched(*args)
    assert stitched.stats.stitched_kernels == 1
    assert stitched.stats.standalone_kernels == 0


# --------------------------------------------------------------------------
# per-shape plan caching
# --------------------------------------------------------------------------


def test_plan_cache_no_recompile_at_same_shape(rng):
    stitched = stitch(rmsnorm, options=OPTS)
    x, g = rng.randn(16, 64).astype("f4"), rng.randn(64).astype("f4")
    stitched(x, g)
    assert stitched.num_compiles == 1
    stitched(x + 1, g)                      # same signature, new values
    assert stitched.num_compiles == 1       # no recompile
    stitched(x[:8], g)                      # new shape: recompile once
    assert stitched.num_compiles == 2
    out = stitched(x[:8] * 2, g)
    assert stitched.num_compiles == 2
    assert_tree_close(out, jax.jit(rmsnorm)(x[:8] * 2, g))


def test_plan_cache_distinguishes_dtypes(rng):
    stitched = stitch(lambda x: x * 2.0 + 1.0, options=OPTS)
    x = rng.randn(8, 8)
    stitched(x.astype("f4"))
    stitched(x.astype("f4") * 3)
    assert stitched.num_compiles == 1
    stitched(np.abs(x).astype("i4"))
    assert stitched.num_compiles == 2


# --------------------------------------------------------------------------
# pytrees, kwargs, aliased outputs, closures
# --------------------------------------------------------------------------


def test_pytree_inputs_and_outputs(rng):
    def fn(params, x):
        h = jnp.tanh(jnp.matmul(x, params["w"]) + params["b"])
        return {"h": h, "norms": (jnp.sum(h * h), jnp.max(h))}

    params = {"w": rng.randn(8, 4).astype("f4"), "b": rng.randn(4).astype("f4")}
    x = rng.randn(3, 8).astype("f4")
    stitched = stitch(fn, options=OPTS)
    out = stitched(params, x)
    assert set(out) == {"h", "norms"} and isinstance(out["norms"], tuple)
    assert_tree_close(out, jax.jit(fn)(params, x))


def test_kwargs_supported(rng):
    stitched = stitch(lambda x, scale: x * scale, options=OPTS)
    x = rng.randn(4, 4).astype("f4")
    assert_tree_close(stitched(x, scale=jnp.float32(2.5)), x * 2.5)


def test_aliased_and_duplicate_outputs(rng):
    """Outputs that alias a parameter, an interior value, or repeat must
    still materialize (reshape sinks keep them as module roots)."""
    def fn(x):
        y = jnp.exp(x)
        return x, y, y * 2.0, y
    x = rng.randn(4, 4).astype("f4")
    out = stitch(fn, options=OPTS)(x)
    assert_tree_close(out, jax.jit(fn)(x))


def test_closure_constants_fold(rng):
    table = rng.randn(8, 8).astype("f4")
    def fn(x):
        return jnp.matmul(x, jnp.asarray(table) * 2.0)
    stitched = stitch(fn, options=OPTS)
    x = rng.randn(4, 8).astype("f4")
    assert_tree_close(stitched(x), jax.jit(fn)(x))
    module = stitched.lower()
    assert any(i.opcode == "constant" for i in module.instructions)
    assert len(module.parameters) == 1      # the closure array is NOT a feed


def test_dead_code_is_eliminated(rng):
    """jax.make_jaxpr does not DCE; the lowering must, or dead subgraphs
    become module roots computed on every call."""
    def fn(x):
        dead = jnp.exp(x) / jnp.sum(jnp.tanh(x))     # unused chain
        _also_dead = jnp.where(x > 0, dead, x)       # unused nested select
        return x + 1.0

    x = rng.randn(4, 4).astype("f4")
    stitched = stitch(fn, options=OPTS)
    assert_tree_close(stitched(x), jax.jit(fn)(x))
    m = stitched.lower()
    opcodes = {i.opcode for i in m.instructions}
    fns = {i.attrs.get("fn") for i in m.instructions if i.opcode == "elementwise"}
    assert "reduce" not in opcodes and "select" not in opcodes
    assert "exp" not in fns and "tanh" not in fns
    assert len(m.roots) == 1                         # only the real output


def test_dead_closure_constant_not_materialized(rng):
    big = np.ones((64, 64), "f4")

    def fn(x):
        _dead = jnp.matmul(x, jnp.asarray(big))      # unused
        return x * 2.0

    x = rng.randn(4, 64).astype("f4")
    stitched = stitch(fn, options=OPTS)
    assert_tree_close(stitched(x), jax.jit(fn)(x))
    m = stitched.lower()
    assert not any(
        i.opcode == "constant" and i.num_elements > 1 for i in m.instructions
    )
    assert "dot" not in {i.opcode for i in m.instructions}


def test_side_effecting_eqns_are_not_silently_dropped(rng):
    """An effectful primitive (jax.debug.print) must raise — or fall back —
    rather than being dead-code-eliminated into silent divergence."""
    def fn(x):
        jax.debug.print("x0={v}", v=x[0, 0])
        return x + 1.0

    x = rng.randn(4, 4).astype("f4")
    with pytest.raises(UnsupportedPrimitiveError):
        stitch(fn, options=OPTS)(x)
    assert_tree_close(
        stitch(fn, on_unsupported="fallback", options=OPTS)(x), x + 1.0
    )


def test_remat_checkpoint_inlines(rng):
    def fn(x):
        return jax.checkpoint(lambda y: jnp.tanh(y) * 2.0)(x) + x

    x = rng.randn(4, 4).astype("f4")
    stitched = stitch(fn, options=OPTS)
    assert_tree_close(stitched(x), jax.jit(fn)(x))
    assert stitched.num_compiles == 1


def test_stats_error_names_fallback_cause(rng):
    fb = stitch(lambda x: jnp.cumsum(x), on_unsupported="fallback", options=OPTS)
    fb(rng.randn(4, 4).astype("f4"))
    with pytest.raises(ValueError, match="fell back to plain"):
        fb.stats


def test_unused_argument_stays_a_parameter(rng):
    stitched = stitch(lambda x, unused: x * 3.0, options=OPTS)
    x, u = rng.randn(4, 4).astype("f4"), rng.randn(8).astype("f4")
    assert_tree_close(stitched(x, u), x * 3.0)
    assert [p.name for p in stitched.lower().parameters] == ["arg0", "arg1"]


# --------------------------------------------------------------------------
# lowering coverage details
# --------------------------------------------------------------------------


def test_dot_general_noncanonical_layouts(rng):
    def fn(a, b, c):
        y = jnp.einsum("bij,bkj->bik", a, b)   # contract rhs last dim
        z = jnp.matmul(y, c)                   # matvec: (B,I,K) @ (K,)
        return jnp.sum(z, axis=-1)
    a = rng.randn(2, 3, 5).astype("f4")
    b = rng.randn(2, 4, 5).astype("f4")
    c = rng.randn(4).astype("f4")
    assert_tree_close(stitch(fn, options=OPTS)(a, b, c), jax.jit(fn)(a, b, c))


def test_integer_pow_and_reciprocal(rng):
    def fn(x):
        return x ** 3 + (x + 2.0) ** -2
    x = np.abs(rng.randn(4, 4)).astype("f4") + 0.5
    assert_tree_close(stitch(fn, options=OPTS)(x), jax.jit(fn)(x))


def test_select_convert_and_compare(rng):
    def fn(x):
        mask = x > 0
        return jnp.where(mask, x, -x) + mask.astype(jnp.float32)
    x = rng.randn(8, 8).astype("f4")
    assert_tree_close(stitch(fn, options=OPTS)(x), jax.jit(fn)(x))


def test_stop_gradient_and_int_inputs(rng):
    def fn(x, n):
        return jax.lax.stop_gradient(x) * n.astype(jnp.float32)
    x = rng.randn(4, 4).astype("f4")
    n = rng.randint(0, 5, size=(4, 4)).astype("i4")
    assert_tree_close(stitch(fn, options=OPTS)(x, n), jax.jit(fn)(x, n))


def test_lower_returns_lowered_handle(rng):
    from repro import Lowered

    stitched = stitch(rmsnorm, options=OPTS)
    m = stitched.lower(
        jax.ShapeDtypeStruct((16, 64), jnp.float32),
        jax.ShapeDtypeStruct((64,), jnp.float32),
    )
    assert isinstance(m, Lowered)
    assert isinstance(m.module, Module)
    assert [p.shape for p in m.parameters] == [(16, 64), (64,)]
    assert stitched.num_compiles == 0       # lowering never compiles
    with pytest.raises(ValueError, match="has not been compiled"):
        stitched.stats
    stitched(np.ones((16, 64), "f4"), np.ones(64, "f4"))
    assert isinstance(stitched.lower(), Lowered)
    assert "rmsnorm" in stitched.report()


def test_decorator_forms(rng):
    @stitch
    def f1(x):
        return x * 2.0

    @stitch(options=StitchOptions(planner="greedy", max_blocks=32))
    def f2(x):
        return x + 1.0

    x = rng.randn(4, 4).astype("f4")
    assert isinstance(f1, StitchedFunction) and isinstance(f2, StitchedFunction)
    assert f2.options.planner == "greedy"
    assert_tree_close(f1(x), x * 2.0)
    assert_tree_close(f2(x), x + 1.0)


# --------------------------------------------------------------------------
# unsupported primitives + fallback
# --------------------------------------------------------------------------


def test_unsupported_primitive_error_names_the_eqn(rng):
    stitched = stitch(lambda x: jnp.cumsum(x) * 2.0, options=OPTS)
    with pytest.raises(UnsupportedPrimitiveError) as ei:
        stitched(rng.randn(4, 4).astype("f4"))
    err = ei.value
    assert err.primitive == "cumsum"
    assert err.eqn is not None and "cumsum" in str(err.eqn)
    assert "fallback" in str(err)           # points at the escape hatch
    assert "cumsum" not in SUPPORTED_PRIMITIVES


def test_fallback_mode_runs_via_jax_jit(rng):
    fn = lambda x: jnp.cumsum(x) + 1.0  # noqa: E731
    stitched = stitch(fn, on_unsupported="fallback", options=OPTS)
    x = rng.randn(4, 4).astype("f4")
    assert_tree_close(stitched(x), jax.jit(fn)(x))
    assert stitched.num_fallbacks == 1 and stitched.num_compiles == 0
    stitched(x)                             # fallback entry is cached too
    assert stitched.num_fallbacks == 1


def test_fallback_mode_still_stitches_supported_fns(rng):
    stitched = stitch(rmsnorm, on_unsupported="fallback", options=OPTS)
    x, g = rng.randn(16, 64).astype("f4"), rng.randn(64).astype("f4")
    assert_tree_close(stitched(x, g), jax.jit(rmsnorm)(x, g))
    assert stitched.num_compiles == 1 and stitched.num_fallbacks == 0


def test_invalid_on_unsupported_mode():
    with pytest.raises(ValueError, match="on_unsupported"):
        stitch(lambda x: x, on_unsupported="ignore")


def test_stitch_requires_callable():
    with pytest.raises(TypeError, match="callable"):
        stitch(42)


# --------------------------------------------------------------------------
# satellite: StitchOptions validation
# --------------------------------------------------------------------------


def test_options_rejects_unknown_planner():
    with pytest.raises(ValueError, match=r"cost.*greedy|greedy.*cost"):
        StitchOptions(planner="gredy")


def test_options_rejects_negative_budgets():
    with pytest.raises(ValueError, match="vmem_limit"):
        StitchOptions(vmem_limit=-1)
    with pytest.raises(ValueError, match="stitch_max_blocks"):
        StitchOptions(stitch_max_blocks=-4)
    with pytest.raises(ValueError, match="stitch_replicate_limit"):
        StitchOptions(stitch_replicate_limit=-2)


def test_options_validate_on_dataclasses_replace():
    opts = StitchOptions()
    with pytest.raises(ValueError, match="planner"):
        replace(opts, planner="bogus")
    assert replace(opts, planner="greedy").planner == "greedy"
    opts.validate()                         # explicit re-validation is public


# --------------------------------------------------------------------------
# satellite: duplicate parameter names
# --------------------------------------------------------------------------


def test_graphbuilder_rejects_duplicate_parameter_names():
    b = GraphBuilder("dup")
    b.parameter("x", (4,), jnp.float32)
    with pytest.raises(ValueError, match="duplicate parameter name 'x'"):
        b.parameter("x", (8,), jnp.float32)


def test_trace_rejects_duplicate_spec_names():
    def fn(b, x, y):
        return x + y
    with pytest.raises(ValueError, match="duplicate parameter name"):
        trace(fn, ("x", (4,), jnp.float32), ("x", (4,), jnp.float32))


# --------------------------------------------------------------------------
# lower_jaxpr is usable standalone (the documented low-level path)
# --------------------------------------------------------------------------


def test_lower_jaxpr_standalone(rng):
    closed = jax.make_jaxpr(rmsnorm)(
        jax.ShapeDtypeStruct((8, 32), jnp.float32),
        jax.ShapeDtypeStruct((32,), jnp.float32),
    )
    lowered = lower_jaxpr(closed, name="rms", param_names=["x", "g"])
    assert [p.name for p in lowered.module.parameters] == ["x", "g"]
    from repro.core import reference_execute

    x, g = rng.randn(8, 32).astype("f4"), rng.randn(32).astype("f4")
    out = reference_execute(lowered.module, {"x": x, "g": g})
    assert_tree_close(
        [out[n] for n in lowered.output_names], [rmsnorm(x, g)]
    )
