"""Serving engine: continuous batching, per-slot positions, greedy decode."""
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_config, reduced_config
from repro.models import decode_step, init_cache, init_params
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    return cfg, init_params(cfg, 0)


def test_single_request_generates(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=2, max_len=64)
    req = Request(rid=0, prompt=np.array([5, 9, 2]), max_new_tokens=6)
    assert eng.admit(req)
    eng.run_until_done()
    assert req.done and len(req.out_tokens) == 6
    assert all(0 <= t < cfg.vocab_size for t in req.out_tokens)


def test_batched_requests_independent(small_model):
    """A request's output must not depend on what else shares the batch —
    the write-mask isolation property."""
    cfg, params = small_model
    prompt = np.array([5, 9, 2, 17])

    solo = Request(rid=0, prompt=prompt, max_new_tokens=5)
    e1 = ServeEngine(cfg, params, pool_size=2, max_len=64)
    e1.admit(solo)
    e1.run_until_done()

    e2 = ServeEngine(cfg, params, pool_size=2, max_len=64)
    other = Request(rid=1, prompt=np.array([3, 3, 3, 3, 3, 3]), max_new_tokens=8)
    same = Request(rid=2, prompt=prompt, max_new_tokens=5)
    e2.admit(other)
    e2.admit(same)
    e2.run_until_done()

    assert same.out_tokens == solo.out_tokens


def test_continuous_batching_admits_mid_stream(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=2, max_len=64)
    r1 = Request(rid=0, prompt=np.array([1, 2, 3]), max_new_tokens=10)
    eng.admit(r1)
    eng.tick()
    eng.tick()
    r2 = Request(rid=1, prompt=np.array([7, 8]), max_new_tokens=4)
    assert eng.admit(r2)                 # joins while r1 is mid-generation
    eng.run_until_done()
    assert r1.done and r2.done
    assert len(r1.out_tokens) == 10 and len(r2.out_tokens) == 4


def test_pool_exhaustion_rejects(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64)
    assert eng.admit(Request(rid=0, prompt=np.array([1]), max_new_tokens=50))
    assert not eng.admit(Request(rid=1, prompt=np.array([2]), max_new_tokens=2))


def test_ssm_engine_serves():
    cfg = reduced_config(get_config("mamba2-1.3b"))
    params = init_params(cfg, 0)
    eng = ServeEngine(cfg, params, pool_size=2, max_len=32)
    req = Request(rid=0, prompt=np.array([4, 4, 4]), max_new_tokens=4)
    eng.admit(req)
    eng.run_until_done()
    assert req.done and len(req.out_tokens) == 4
