"""Serving engine: continuous batching, per-slot positions, greedy decode."""
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_config, reduced_config
from repro.models import init_cache, init_params
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    return cfg, init_params(cfg, 0)


def test_single_request_generates(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=2, max_len=64)
    req = Request(rid=0, prompt=np.array([5, 9, 2]), max_new_tokens=6)
    assert eng.admit(req)
    eng.run_until_done()
    assert req.done and len(req.out_tokens) == 6
    assert all(0 <= t < cfg.vocab_size for t in req.out_tokens)


def test_batched_requests_independent(small_model):
    """A request's output must not depend on what else shares the batch —
    the write-mask isolation property."""
    cfg, params = small_model
    prompt = np.array([5, 9, 2, 17])

    solo = Request(rid=0, prompt=prompt, max_new_tokens=5)
    e1 = ServeEngine(cfg, params, pool_size=2, max_len=64)
    e1.admit(solo)
    e1.run_until_done()

    e2 = ServeEngine(cfg, params, pool_size=2, max_len=64)
    other = Request(rid=1, prompt=np.array([3, 3, 3, 3, 3, 3]), max_new_tokens=8)
    same = Request(rid=2, prompt=prompt, max_new_tokens=5)
    e2.admit(other)
    e2.admit(same)
    e2.run_until_done()

    assert same.out_tokens == solo.out_tokens


def test_continuous_batching_admits_mid_stream(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=2, max_len=64)
    r1 = Request(rid=0, prompt=np.array([1, 2, 3]), max_new_tokens=10)
    eng.admit(r1)
    eng.tick()
    eng.tick()
    r2 = Request(rid=1, prompt=np.array([7, 8]), max_new_tokens=4)
    assert eng.admit(r2)                 # joins while r1 is mid-generation
    eng.run_until_done()
    assert r1.done and r2.done
    assert len(r1.out_tokens) == 10 and len(r2.out_tokens) == 4


def test_pool_exhaustion_rejects(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64)
    assert eng.admit(Request(rid=0, prompt=np.array([1]), max_new_tokens=50))
    assert not eng.admit(Request(rid=1, prompt=np.array([2]), max_new_tokens=2))


def test_ssm_engine_serves():
    cfg = reduced_config(get_config("mamba2-1.3b"))
    params = init_params(cfg, 0)
    eng = ServeEngine(cfg, params, pool_size=2, max_len=32)
    req = Request(rid=0, prompt=np.array([4, 4, 4]), max_new_tokens=4)
    eng.admit(req)
    eng.run_until_done()
    assert req.done and len(req.out_tokens) == 4


# ----------------------------------------------------- chunked prefill
def test_chunked_prefill_token_parity(small_model):
    """Chunked prefill must generate exactly the per-token loop's tokens
    (ragged tail included: 7 tokens with chunk 4)."""
    cfg, params = small_model
    prompt = np.array([5, 9, 2, 17, 3, 8, 1])
    outs, launches = {}, {}
    for chunk in (1, 4, 16):
        eng = ServeEngine(cfg, params, pool_size=2, max_len=64,
                          prefill_chunk=chunk)
        req = Request(rid=0, prompt=prompt, max_new_tokens=5)
        assert eng.admit(req)
        eng.run_until_done()
        outs[chunk] = req.out_tokens
        launches[chunk] = eng.prefill_launches
    assert outs[1] == outs[4] == outs[16]
    assert launches[1] == 7          # per-token oracle: O(S)
    assert launches[4] == 2          # O(ceil(S/chunk))
    assert launches[16] == 1


def test_chunked_prefill_isolation(small_model):
    """Chunked prefill must not perturb a slot mid-generation (the write
    mask covers the whole chunk)."""
    cfg, params = small_model
    prompt = np.array([5, 9, 2, 17])
    solo = Request(rid=0, prompt=prompt, max_new_tokens=6)
    e1 = ServeEngine(cfg, params, pool_size=2, max_len=64, prefill_chunk=4)
    e1.admit(solo)
    e1.run_until_done()

    e2 = ServeEngine(cfg, params, pool_size=2, max_len=64, prefill_chunk=4)
    same = Request(rid=1, prompt=prompt, max_new_tokens=6)
    e2.admit(same)
    e2.tick()
    late = Request(rid=2, prompt=np.array([3, 3, 3, 3, 3]), max_new_tokens=4)
    assert e2.admit(late)            # chunk-prefills while rid=1 is live
    e2.run_until_done()
    assert same.out_tokens == solo.out_tokens


# ----------------------------------------------- admission validation
def test_empty_prompt_rejected(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.admit(Request(rid=0, prompt=np.array([], dtype=np.int32)))
    assert eng.requests_rejected == 1
    assert not eng.wait_queue and eng.active_slots == []


def test_over_capacity_prompt_rejected(small_model):
    """Prompts longer than the KV ring used to scatter past the cache and
    silently corrupt earlier positions; now they are rejected at admit."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=32)
    with pytest.raises(ValueError, match="exceeds the KV cache"):
        eng.admit(Request(rid=0, prompt=np.ones(32, np.int32)))
    assert eng.requests_rejected == 1


def test_at_capacity_prompt_stops_after_first_token(small_model):
    """A max_len-1 prompt is admissible; prefill applies the same
    max_len-1 stop as tick, so exactly one token comes out."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=32, prefill_chunk=8)
    req = Request(rid=0, prompt=np.ones(31, np.int32), max_new_tokens=10)
    assert eng.admit(req)
    assert req.done and len(req.out_tokens) == 1
    assert eng.active_slots == []    # slot freed for the next request


# ------------------------------------------------------- wait queue
def test_wait_queue_admits_in_fifo_order(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64, prefill_chunk=4)
    r1 = Request(rid=0, prompt=np.array([1, 2, 3]), max_new_tokens=2)
    r2 = Request(rid=1, prompt=np.array([4, 5]), max_new_tokens=2)
    r3 = Request(rid=2, prompt=np.array([6]), max_new_tokens=2)
    assert eng.admit(r1) is True
    assert eng.admit(r2) is False    # queued, not dropped
    assert eng.admit(r3) is False
    assert list(eng.wait_queue) == [r2, r3]
    eng.run_until_done()
    assert r1.done and r2.done and r3.done
    assert len(r2.out_tokens) == 2 and len(r3.out_tokens) == 2
    # FIFO: r2 claimed the slot before r3
    assert r2.t_admit <= r3.t_admit
    assert not eng.wait_queue


def test_wait_queue_deduplicates_repeated_admit(small_model):
    """Old callers loop `while admit(req)`; a re-admitted queued request
    must not occupy two queue entries."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64)
    eng.admit(Request(rid=0, prompt=np.array([1]), max_new_tokens=8))
    r = Request(rid=1, prompt=np.array([2]), max_new_tokens=2)
    assert eng.admit(r) is False
    assert eng.admit(r) is False
    assert len(eng.wait_queue) == 1


def test_retry_loop_never_requeues_active_or_done_requests(small_model):
    """The pre-PR launcher pattern `while pending and admit(pending[0])`
    retries a queued request every tick; once it is draining into a slot
    (or finished) a re-admit must NOT queue it again — a done request
    re-placed by _drain_queue would be re-prefilled and re-generated."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64, prefill_chunk=4)
    r1 = Request(rid=0, prompt=np.array([1, 2]), max_new_tokens=3)
    r2 = Request(rid=1, prompt=np.array([3, 4]), max_new_tokens=3)
    ticks = 0
    while not (r1.done and r2.done) and ticks < 50:
        for r in (r1, r2):
            if not r.done:
                eng.admit(r)     # retried every tick, incl. while active
        eng.tick()
        ticks += 1
    assert r1.done and r2.done
    assert eng.requests_completed == 2
    assert len(r1.out_tokens) == 3 and len(r2.out_tokens) == 3
    assert eng.tokens_generated == 6
    # a finished request stays finished even if admitted again
    assert eng.admit(r2) is False
    assert not eng.wait_queue
    eng.run_until_done()
    assert len(r2.out_tokens) == 3 and eng.requests_completed == 2


def test_request_latency_stats_populated(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64, prefill_chunk=4)
    r1 = Request(rid=0, prompt=np.array([1, 2]), max_new_tokens=2)
    r2 = Request(rid=1, prompt=np.array([3, 4]), max_new_tokens=2)
    eng.admit(r1)
    eng.admit(r2)
    eng.run_until_done()
    for r in (r1, r2):
        assert r.ttft_s is not None and r.ttft_s >= 0
        assert r.latency_s is not None and r.latency_s >= r.ttft_s - 1e-9
        assert r.tokens_per_s and r.tokens_per_s > 0
    assert r2.queue_wait_s > 0       # r2 sat in the queue
    st = eng.stats()
    assert st["requests_completed"] == 2
    assert st["prefill_launches"] == 2   # 2 prompts, 1 chunk each
    assert st["decode_launches"] == st["ticks"]


# ------------------------------------------- run_until_done truncation
def test_run_until_done_reports_truncation(small_model):
    """Stopping at max_ticks used to look exactly like completion; now the
    leftover count comes back, with a warning (or strict=True raises)."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64, prefill_chunk=4)
    r1 = Request(rid=0, prompt=np.array([1, 2]), max_new_tokens=30)
    r2 = Request(rid=1, prompt=np.array([3, 4]), max_new_tokens=30)
    eng.admit(r1)
    eng.admit(r2)
    with pytest.warns(RuntimeWarning, match="TRUNCATED"):
        remaining = eng.run_until_done(max_ticks=3)
    assert remaining == 2            # r1 mid-stream + r2 still queued
    with pytest.raises(RuntimeError, match="TRUNCATED"):
        eng.run_until_done(max_ticks=1, strict=True)
    assert eng.run_until_done() == 0
    assert r1.done and r2.done


def test_run_until_done_complete_returns_zero_no_warning(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=64, prefill_chunk=4)
    req = Request(rid=0, prompt=np.array([1, 2]), max_new_tokens=3)
    eng.admit(req)
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        assert eng.run_until_done() == 0
    assert req.done


# ------------------------------------------ rejection double-counting
def test_rejected_request_counted_once_across_retries(small_model):
    """A retry loop re-admitting the same invalid request must not inflate
    requests_rejected — one rejected request == one rejection."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, pool_size=1, max_len=32)
    bad = Request(rid=0, prompt=np.ones(40, np.int32))
    for _ in range(3):
        with pytest.raises(ValueError, match="exceeds the KV cache"):
            eng.admit(bad)
    assert eng.requests_rejected == 1
    # a DIFFERENT invalid request still counts
    with pytest.raises(ValueError, match="empty prompt"):
        eng.admit(Request(rid=1, prompt=np.array([], dtype=np.int32)))
    assert eng.requests_rejected == 2


# -------------------------------------------- SSM slot-reuse state reset
def test_ssm_slot_reuse_resets_recurrent_state():
    """Attention KV is masked by length, but SSM/conv state is unmasked
    recurrent carry: a slot's second occupant must decode as if the first
    had never existed."""
    cfg = reduced_config(get_config("mamba2-1.3b"))
    params = init_params(cfg, 0)
    prompt_b = np.array([40, 41, 42, 43, 44])

    solo = Request(rid=0, prompt=prompt_b, max_new_tokens=5)
    e1 = ServeEngine(cfg, params, pool_size=1, max_len=32, prefill_chunk=4)
    e1.admit(solo)
    e1.run_until_done()

    e2 = ServeEngine(cfg, params, pool_size=1, max_len=32, prefill_chunk=4)
    first = Request(rid=1, prompt=np.array([7, 8, 9]), max_new_tokens=5)
    e2.admit(first)
    e2.run_until_done()
    reused = Request(rid=2, prompt=prompt_b, max_new_tokens=5)
    e2.admit(reused)                 # same slot, previously occupied
    e2.run_until_done()
    assert reused.out_tokens == solo.out_tokens


# -------------------------------------------- greedy sampling inside jit
def test_decode_fn_returns_token_vector(small_model):
    """The jitted step ships a (pool,) int32 token vector, not
    (pool, vocab) logits — argmax happens on device inside the jit."""
    import jax

    from repro.models import init_cache
    from repro.serve.engine import _decode_fn

    cfg, params = small_model
    pool = 2
    fn, _ = _decode_fn(cfg, pool)
    cache = init_cache(cfg, pool, 16)
    toks, cache = fn(
        params, cache, jnp.zeros(pool, jnp.int32), jnp.zeros(pool, jnp.int32),
        jnp.ones(pool, bool),
    )
    toks = jax.device_get(toks)
    assert toks.shape == (pool,)
    assert toks.dtype == np.int32
    assert all(0 <= int(t) < cfg.vocab_size for t in toks)


# --------------------------------------------------- decode-fn LRU cache
def test_decode_cache_lru_bounded(small_model, monkeypatch):
    from collections import OrderedDict

    from repro.serve import engine as engine_mod

    cfg, params = small_model
    monkeypatch.setattr(engine_mod, "_DECODE_CACHE", OrderedDict())
    monkeypatch.setattr(engine_mod, "_DECODE_CACHE_CAP", 2)
    monkeypatch.setattr(engine_mod, "_DECODE_CACHE_EVICTIONS", 0)
    fn1, hit1 = engine_mod._decode_fn(cfg, 1)
    fn2, hit2 = engine_mod._decode_fn(cfg, 2)
    assert (hit1, hit2) == (False, False)
    _, hit1b = engine_mod._decode_fn(cfg, 1)
    assert hit1b is True             # LRU refresh, no rebuild
    engine_mod._decode_fn(cfg, 3)    # evicts pool=2 (least recently used)
    assert len(engine_mod._DECODE_CACHE) == 2
    assert engine_mod._DECODE_CACHE_EVICTIONS == 1
    _, hit2b = engine_mod._decode_fn(cfg, 2)
    assert hit2b is False            # evicted -> rebuilt
    _, hit1c = engine_mod._decode_fn(cfg, 1)
    assert hit1c is False            # pool=1 was evicted by the rebuild
    assert engine_mod.decode_cache_stats()["evictions"] >= 2
