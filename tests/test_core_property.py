"""Property-based fuzzing: random StitchIR DAGs -> compiled == oracle.

This exercises the full pipeline (span -> fusion -> schedule propagation ->
memory planning -> Pallas codegen) on graphs no human wrote.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import compile_and_compare
from repro.core import GraphBuilder

SHAPES = [(4, 8), (2, 4, 8), (8,), (2, 8, 4)]


@st.composite
def random_module(draw):
    b = GraphBuilder("fuzz")
    shape = draw(st.sampled_from(SHAPES))
    pool = [b.parameter(f"p{i}", shape, jnp.float32) for i in range(draw(st.integers(1, 3)))]
    n_ops = draw(st.integers(3, 22))
    for _ in range(n_ops):
        kind = draw(
            st.sampled_from(
                ["unary", "binary", "reduce_bcast", "transpose", "reshape",
                 "select", "scalar"]
            )
        )
        x = pool[draw(st.integers(0, len(pool) - 1))]
        try:
            if kind == "unary":
                fn = draw(st.sampled_from(["exp", "tanh", "abs", "sigmoid", "square"]))
                pool.append(b.unary(fn, x))
            elif kind == "binary":
                same = [t for t in pool if t.shape == x.shape]
                y = same[draw(st.integers(0, len(same) - 1))]
                fn = draw(st.sampled_from(["add", "mul", "sub", "max", "min"]))
                pool.append(b.binary(fn, x, y))
            elif kind == "scalar":
                pool.append(x * draw(st.floats(-2, 2, allow_nan=False)))
            elif kind == "reduce_bcast":
                if x.ndim < 2:
                    continue
                dim = draw(st.integers(0, x.ndim - 1))
                r = b.reduce(x, (dim,), draw(st.sampled_from(["sum", "max", "mean"])))
                kept = tuple(i for i in range(x.ndim) if i != dim)
                pool.append(b.broadcast(r, x.shape, kept) + x)
            elif kind == "transpose":
                if x.ndim < 2:
                    continue
                perm = list(range(x.ndim))
                i = draw(st.integers(0, x.ndim - 2))
                perm[i], perm[i + 1] = perm[i + 1], perm[i]
                t = b.transpose(x, tuple(perm))
                # transpose back so the pool shape stays uniform
                pool.append(b.transpose(b.exp(t), tuple(np.argsort(perm))))
            elif kind == "reshape":
                total = int(np.prod(x.shape))
                y = b.reshape(x, (total,))
                pool.append(b.reshape(b.tanh(y), x.shape))
        except (AssertionError, ValueError):
            continue
    # make sure at least one op exists
    if all(t.instr.opcode == "parameter" for t in pool):
        pool.append(b.exp(pool[0]))
    return b.module


@given(random_module(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_fuzz_compiled_matches_reference(module, seed):
    rng = np.random.RandomState(seed)
    feeds = {
        p.name: rng.uniform(-1.5, 1.5, size=p.shape).astype("f4")
        for p in module.parameters
    }
    compile_and_compare(module, feeds, rtol=5e-4, atol=5e-4)


@given(random_module())
@settings(max_examples=25, deadline=None)
def test_fuzz_fusion_plan_invariants(module):
    from repro.core import deep_fuse

    plan = deep_fuse(module)
    pos = {i.id: k for k, i in enumerate(module.instructions)}
    seen = set()
    for f in plan.fusions:
        for m in f.members:
            assert m.id not in seen
            seen.add(m.id)
        order = [pos[m.id] for m in f.members]
        assert order == sorted(order)
    for s in plan.standalone:
        assert s.id not in seen
        seen.add(s.id)
    covered = {
        i.id
        for i in module.instructions
        if i.opcode not in ("parameter", "constant")
    }
    assert covered <= seen | {
        i.id for i in module.instructions if i.opcode in ("parameter", "constant")
    }
