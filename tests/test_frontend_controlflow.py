"""Control-flow and gradient capture through ``repro.stitch``.

The contract (ISSUE 8): ``lax.scan``, bounded ``fori_loop``/``while_loop``,
shape-agreeing ``lax.cond`` and ``jax.grad``/``value_and_grad`` all compile
with ZERO fallbacks and are bit-identical to ``jax.jit`` in both replay
modes (eager per-step dispatch and one traced ``lax.scan`` segment).
Plus the jit-parity API surface: static-argnum cache keying, donation
safety, and a stitched AdamW train step whose loss trajectory matches the
plain ``jax.jit`` trainer exactly.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import StitchOptions, UnsupportedPrimitiveError, stitch
from repro.train import AdamWConfig, adamw_init, make_stitched_train_step

OPTS = StitchOptions(max_blocks=32)
EAGER = replace(OPTS, jit_replay=False)

REPLAYS = pytest.mark.parametrize(
    "opts", [OPTS, EAGER], ids=["traced", "eager"]
)


def assert_tree_bitwise(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=False):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def decode_loop(h, w):
    def step(carry, _):
        carry = jnp.tanh(carry @ w)
        return carry, carry.sum(axis=-1)

    return jax.lax.scan(step, h, None, length=6)


# --------------------------------------------------------------------------
# scan
# --------------------------------------------------------------------------


@REPLAYS
def test_scan_decode_loop_bitwise_vs_jit(opts):
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 16), scale=0.2), jnp.float32)

    st = stitch(decode_loop, options=opts)
    got = st(h, w)
    assert st.num_fallbacks == 0
    assert_tree_bitwise(got, jax.jit(decode_loop)(h, w))

    s = st.stats
    assert s.loop_calls == 1
    assert s.sub_compiles == 1
    assert s.sub_kernels >= 1


@REPLAYS
def test_scan_with_xs_and_reverse(opts):
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)

    def fn(init, xs):
        def step(c, x):
            c = c * 0.9 + x
            return c, c - x

        return jax.lax.scan(step, init, xs, reverse=True)

    init = jnp.ones((8,), jnp.float32)
    st = stitch(fn, options=opts)
    assert_tree_bitwise(st(init, xs), jax.jit(fn)(init, xs))
    assert st.num_fallbacks == 0


def test_two_identical_scans_share_one_compiled_body():
    def fn(a, w):
        c1, ys1 = decode_loop(a, w)
        c2, ys2 = decode_loop(a + 1.0, w)
        return c1 + c2, ys1 + ys2

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 16), scale=0.2), jnp.float32)

    st = stitch(fn, options=OPTS)
    assert_tree_bitwise(st(a, w), jax.jit(fn)(a, w))
    s = st.stats
    assert s.loop_calls == 2
    assert s.sub_compiles == 1  # module-signature dedup: one body, two sites
    assert s.sub_call_sites == 2


def test_traced_replay_reduces_dispatches():
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 16), scale=0.2), jnp.float32)

    st = stitch(decode_loop, options=OPTS)
    st(h, w)
    s = st.stats
    assert s.replay_mode == "jit"
    assert s.traced_dispatches_per_call < s.eager_dispatches_per_call


# --------------------------------------------------------------------------
# fori / while
# --------------------------------------------------------------------------


@REPLAYS
def test_fori_loop_static_bounds(opts):
    def fn(x):
        return jax.lax.fori_loop(0, 4, lambda i, c: c @ c * 0.5, x)

    x = jnp.eye(8, dtype=jnp.float32) * 1.5
    st = stitch(fn, options=opts)
    assert_tree_bitwise(st(x), jax.jit(fn)(x))
    assert st.num_fallbacks == 0


@REPLAYS
def test_while_loop_counted(opts):
    def fn(x):
        def cond(c):
            return c[0] < 5

        def body(c):
            i, v = c
            return i + 1, v * 1.1 + 0.25

        return jax.lax.while_loop(cond, body, (0, x))[1]

    x = jnp.linspace(0.0, 1.0, 12, dtype=jnp.float32)
    st = stitch(fn, options=opts)
    assert_tree_bitwise(st(x), jax.jit(fn)(x))
    assert st.num_fallbacks == 0


def test_data_dependent_while_raises():
    def fn(x):
        return jax.lax.while_loop(
            lambda v: jnp.sum(v) < 100.0, lambda v: v * 2.0, x
        )

    with pytest.raises(UnsupportedPrimitiveError) as err:
        stitch(fn, options=OPTS)(jnp.ones((4,), jnp.float32))
    assert err.value.primitive == "while"


# --------------------------------------------------------------------------
# cond
# --------------------------------------------------------------------------


@REPLAYS
@pytest.mark.parametrize("flag", [False, True])
def test_cond_inlines_via_select(opts, flag):
    def fn(pred, x):
        return jax.lax.cond(pred, lambda v: v * 2.0, lambda v: v - 1.0, x)

    x = jnp.arange(8, dtype=jnp.float32)
    pred = jnp.asarray(flag)
    st = stitch(fn, options=opts)
    assert_tree_bitwise(st(pred, x), jax.jit(fn)(pred, x))
    assert st.num_fallbacks == 0


def test_nway_switch_raises():
    def fn(i, x):
        return jax.lax.switch(
            i, [lambda v: v, lambda v: v * 2.0, lambda v: v * 3.0], x
        )

    with pytest.raises(UnsupportedPrimitiveError):
        stitch(fn, options=OPTS)(jnp.asarray(1), jnp.ones((4,), jnp.float32))


# --------------------------------------------------------------------------
# grad
# --------------------------------------------------------------------------


def mlp_loss(params, x, y):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    pred = h @ params["w2"] + params["b2"]
    return jnp.mean((pred - y) ** 2)


def _mlp_data(seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "w1": jnp.asarray(rng.normal(size=(8, 16), scale=0.3), jnp.float32),
        "b1": jnp.zeros((16,), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(16, 4), scale=0.3), jnp.float32),
        "b2": jnp.zeros((4,), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
    return params, x, y


@REPLAYS
def test_grad_mlp_bitwise_vs_jit(opts):
    params, x, y = _mlp_data()
    fn = jax.value_and_grad(mlp_loss)
    st = stitch(fn, options=opts)
    assert_tree_bitwise(st(params, x, y), jax.jit(fn)(params, x, y))
    assert st.num_fallbacks == 0


@REPLAYS
def test_grad_of_scan(opts):
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.normal(size=(8, 8), scale=0.2), jnp.float32)
    h = jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)

    def loss(w, h):
        c, ys = decode_loop(h, w)
        return jnp.sum(c ** 2) + jnp.sum(ys)

    fn = jax.grad(loss)
    st = stitch(fn, options=opts)
    assert_tree_bitwise(st(w, h), jax.jit(fn)(w, h))
    assert st.num_fallbacks == 0
    assert st.stats.loop_calls >= 2  # forward scan + transposed reverse scan


# --------------------------------------------------------------------------
# jit-parity API: statics, donation
# --------------------------------------------------------------------------


def test_static_argnums_key_the_plan_cache():
    def fn(x, n):
        return x * float(n)

    st = stitch(fn, options=OPTS, static_argnums=(1,))
    x = jnp.ones((4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(st(x, 2)), 2 * np.ones(4))
    np.testing.assert_array_equal(np.asarray(st(x, 3)), 3 * np.ones(4))
    assert st.num_compiles == 2  # distinct static values -> distinct plans
    st(x, 2)
    assert st.num_compiles == 2  # cache hit on a seen static


def test_static_argnames_and_nonhashable_rejection():
    def fn(x, *, mode="a"):
        return x + (1.0 if mode == "a" else 2.0)

    st = stitch(fn, options=OPTS, static_argnames="mode")
    x = jnp.zeros((4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(st(x, mode="a")), np.ones(4))
    np.testing.assert_array_equal(np.asarray(st(x, mode="b")), 2 * np.ones(4))

    with pytest.raises(TypeError, match="hashable"):
        stitch(lambda x, c: x, options=OPTS, static_argnums=(1,))(x, [1, 2])


def test_donate_argnums_threads_to_plan():
    def fn(x, y):
        return x + y

    st = stitch(fn, options=OPTS, donate_argnums=(0,))
    x = jnp.ones((16,), jnp.float32)
    y = jnp.full((16,), 2.0, jnp.float32)
    out = st(x, y)
    np.testing.assert_array_equal(np.asarray(out), 3 * np.ones(16))
    assert st.num_fallbacks == 0


def test_static_donate_overlap_rejected():
    with pytest.raises(ValueError, match="intersect"):
        stitch(lambda x: x, static_argnums=(0,), donate_argnums=(0,))


# --------------------------------------------------------------------------
# stitched train step: one plan, trajectory parity with jax.jit
# --------------------------------------------------------------------------


def test_stitched_train_step_matches_jit_trajectory():
    from repro.train.optimizer import adamw_update

    opt_cfg = AdamWConfig()
    st = make_stitched_train_step(mlp_loss_batch, opt_cfg, options=OPTS)

    def ref_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(mlp_loss_batch)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    ref = jax.jit(ref_step)

    params, x, y = _mlp_data(seed=7)
    # independent buffers: the stitched step donates params/opt_state
    p_a = jax.tree.map(jnp.copy, params)
    p_b = jax.tree.map(jnp.copy, params)
    s_a, s_b = adamw_init(p_a), adamw_init(p_b)

    rng = np.random.default_rng(8)
    for _ in range(4):
        batch = (
            jnp.asarray(rng.normal(size=(32, 8)), jnp.float32),
            jnp.asarray(rng.normal(size=(32, 4)), jnp.float32),
        )
        p_a, s_a, m_a = st(p_a, s_a, batch)
        p_b, s_b, m_b = ref(p_b, s_b, batch)
        np.testing.assert_array_equal(
            np.asarray(m_a["loss"]), np.asarray(m_b["loss"])
        )

    assert_tree_bitwise(p_a, p_b)
    assert_tree_bitwise(tuple(s_a), tuple(s_b))
    assert st.num_fallbacks == 0
    assert st.num_compiles == 1  # the whole train step is ONE plan
    assert st.stats.stitched_kernels >= 1


def mlp_loss_batch(params, batch):
    x, y = batch
    return mlp_loss(params, x, y)
