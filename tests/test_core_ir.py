"""StitchIR structure, shape inference, tracing, and the apply_op oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GraphBuilder, apply_op, reference_execute, trace
from repro.core.ir import infer_shape


def test_builder_softmax_structure():
    b = GraphBuilder("m")
    x = b.parameter("x", (2, 8), jnp.float32)
    y = b.softmax(x, dim=-1)
    m = b.module
    m.verify()
    opcodes = [i.opcode for i in m.instructions]
    assert opcodes.count("reduce") == 2
    assert opcodes.count("broadcast") == 2
    assert [r.name for r in m.roots] == [y.instr.name]


def test_shape_inference_table():
    assert infer_shape("reduce", [(4, 5, 6)], {"dims": (1,)}) == (4, 6)
    assert infer_shape("transpose", [(4, 5, 6)], {"perm": (2, 0, 1)}) == (6, 4, 5)
    assert infer_shape("dot", [(3, 4, 5), (3, 5, 7)], {}) == (3, 4, 7)
    assert infer_shape("concat", [(2, 3), (2, 5)], {"dim": 1}) == (2, 8)
    assert infer_shape("broadcast", [(4,)], {"out_shape": (2, 4)}) == (2, 4)
    assert infer_shape("gather", [(100, 8), (3, 2)], {}) == (3, 2, 8)


def test_verify_rejects_bad_shape():
    b = GraphBuilder("bad")
    x = b.parameter("x", (2, 3), jnp.float32)
    y = b.exp(x)
    y.instr.shape = (3, 3)  # corrupt
    with pytest.raises(ValueError):
        b.module.verify()


def test_reference_execute_matches_jnp(rng):
    def f(b, x, y):
        z = b.exp(x) * y + 1.5
        s = b.reduce(z, (1,), "sum")
        return b.tanh(s)

    m = trace(f, ("x", (4, 6), jnp.float32), ("y", (4, 6), jnp.float32))
    xs = rng.randn(4, 6).astype("f4")
    ys = rng.randn(4, 6).astype("f4")
    out = reference_execute(m, {"x": xs, "y": ys})
    expected = np.tanh(np.sum(np.exp(xs) * ys + 1.5, axis=1))
    (val,) = out.values()
    np.testing.assert_allclose(np.asarray(val), expected, rtol=1e-5)


def test_operator_overloads_and_scalars(rng):
    def f(b, x):
        return (2.0 * x - 1.0) / (x + 3.0)

    m = trace(f, ("x", (3, 3), jnp.float32))
    xs = rng.rand(3, 3).astype("f4")
    (val,) = reference_execute(m, {"x": xs}).values()
    np.testing.assert_allclose(np.asarray(val), (2 * xs - 1) / (xs + 3), rtol=1e-6)


def test_footprint_and_expensive_flags():
    b = GraphBuilder()
    x = b.parameter("x", (16, 16), jnp.float32)
    e = b.exp(x)
    a = x + x
    assert e.instr.is_expensive and not a.instr.is_expensive
    assert e.instr.footprint_bytes() == 2 * 16 * 16 * 4
    d = b.dot(x, x)
    assert d.instr.is_library_call
    d2 = b.dot(x, x, fusable=True)
    assert not d2.instr.is_library_call


def test_apply_op_every_opcode(rng):
    """apply_op is the oracle the kernels are validated against — cover it."""
    b = GraphBuilder()
    x = b.parameter("x", (2, 3, 4), jnp.float32)
    xs = rng.randn(2, 3, 4).astype("f4")
    checks = [
        (b.exp(x).instr, [xs], np.exp(xs)),
        (b.reshape(x, (6, 4)).instr, [xs], xs.reshape(6, 4)),
        (b.transpose(x, (1, 0, 2)).instr, [xs], xs.transpose(1, 0, 2)),
        (b.reduce(x, (2,), "max").instr, [xs], xs.max(2)),
        (b.reduce(x, (0, 1), "sum").instr, [xs], xs.sum((0, 1))),
    ]
    for instr, vals, want in checks:
        got = np.asarray(apply_op(instr, *vals))
        np.testing.assert_allclose(got, want, rtol=1e-6)
