"""Deep fusion algorithm (paper §3.2, Algorithm 1) structural tests."""
import jax.numpy as jnp

from repro.core import (
    FusionConfig,
    GraphBuilder,
    deep_fuse,
    trace,
    xla_baseline_kernel_count,
)


def _softmax_dot_module():
    def f(b, scores, v):
        p = b.softmax(scores, dim=-1)
        return b.dot(p, v, fusable=True)

    return trace(
        f, ("scores", (2, 4, 8, 8), jnp.float32), ("v", (2, 4, 8, 4), jnp.float32)
    )


def test_exclusive_membership_and_coverage():
    m = _softmax_dot_module()
    plan = deep_fuse(m)
    seen = set()
    for f in plan.fusions:
        for mem in f.members:
            assert mem.id not in seen, "instruction fused twice"
            seen.add(mem.id)
    for s in plan.standalone:
        assert s.id not in seen
        seen.add(s.id)
    uncovered = [
        i
        for i in m.instructions
        if i.id not in seen and i.opcode not in ("parameter", "constant")
    ]
    assert not uncovered


def test_members_topologically_ordered():
    m = _softmax_dot_module()
    plan = deep_fuse(m)
    pos = {i.id: k for k, i in enumerate(m.instructions)}
    for f in plan.fusions:
        order = [pos[mem.id] for mem in f.members]
        assert order == sorted(order)
        for mem in f.members:
            for op in mem.operands:
                if op in f:
                    assert pos[op.id] < pos[mem.id]


def test_fusable_dot_is_stitched_but_library_dot_is_not():
    m = _softmax_dot_module()
    plan = deep_fuse(m, FusionConfig(fuse_dot=True))
    fused_ops = {mem.opcode for f in plan.fusions for mem in f.members}
    assert "dot" in fused_ops
    # same graph, user says no dot fusion
    plan2 = deep_fuse(m, FusionConfig(fuse_dot=False))
    fused_ops2 = {mem.opcode for f in plan2.fusions for mem in f.members}
    assert "dot" not in fused_ops2
    assert plan2.num_library_calls == 0  # fusable-attr dot is standalone, not LC


def test_fusion_never_crosses_library_call():
    def f(b, x, w1, w2):
        h = b.tanh(b.dot(x, w1))         # library dot
        return b.sigmoid(b.dot(h, w2))   # library dot

    m = trace(
        f,
        ("x", (4, 8), jnp.float32),
        ("w1", (8, 8), jnp.float32),
        ("w2", (8, 8), jnp.float32),
    )
    plan = deep_fuse(m)
    assert plan.num_library_calls == 2
    for fu in plan.fusions:
        assert all(mem.opcode != "dot" for mem in fu.members)
        # tanh and sigmoid sit on opposite sides of an LC layer
        names = {mem.attrs.get("fn") for mem in fu.members}
        assert not ({"tanh", "sigmoid"} <= names)


def test_elementwise_horizontal_fusion_groups_independent_ops():
    """The weight-accumulation pattern: N independent same-shape updates."""
    b = GraphBuilder()
    outs = []
    for i in range(6):
        w = b.parameter(f"w{i}", (8, 8), jnp.float32)
        g = b.parameter(f"g{i}", (8, 8), jnp.float32)
        outs.append(w - g * 0.1)
    m = b.module
    plan = deep_fuse(m)
    # all six updates (plus their scalar mul chains) should land in ONE kernel
    assert len(plan.fusions) == 1
    assert len(plan.fusions[0].roots) == 6


def test_footprint_threshold_splits_horizontal_groups():
    b = GraphBuilder()
    for i in range(4):
        w = b.parameter(f"w{i}", (32, 32), jnp.float32)
        g = b.parameter(f"g{i}", (32, 32), jnp.float32)
        _ = w + g
    cfg = FusionConfig(ew_footprint_limit=3 * 32 * 32 * 4 * 2)  # fits ~2 adds
    plan = deep_fuse(b.module, cfg)
    assert len(plan.fusions) >= 2


def test_giveup_blocks_cyclic_fusion():
    """A producer whose consistency fails poisons its transitive producers."""
    def f(b, x):
        e = b.exp(x)
        r = b.reduce(e, (1,), "sum")          # (4,)
        return b.broadcast(r, (4, 8), (0,)) + e

    m = trace(f, ("x", (4, 8), jnp.float32))
    rejected = []

    def consistency(roots, members):
        # refuse any fusion containing the reduce
        bad = any(mem.opcode == "reduce" for mem in members)
        if bad:
            rejected.append(members)
        return not bad

    plan = deep_fuse(m, FusionConfig(consistency=consistency))
    assert rejected, "checker was consulted"
    for fu in plan.fusions:
        assert all(mem.opcode != "reduce" for mem in fu.members)
    # the reduce runs standalone
    assert any(s.opcode == "reduce" for s in plan.standalone)


def test_fusion_reduces_kernel_count_vs_xla_baseline():
    m = _softmax_dot_module()
    plan = deep_fuse(m)
    assert plan.num_kernels < xla_baseline_kernel_count(m)
