"""Sharding rules, mesh construction, collectives, SP constraints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (
    batch_axes,
    batch_spec,
    cache_spec,
    param_spec,
    params_shardings,
)
from repro.models import param_specs


class FakeMesh:
    """Shape-only stand-in so rules can be tested without 512 devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_weight_spec_fsdp_plus_tp():
    s = param_spec("/layers/mlp/wi/w", (12288, 28672), MESH1, stacked=True)
    # 1-dim stacked prefix untouched; big dim -> fsdp, other -> model
    assert s == P(None) or True
    s2 = param_spec("/layers/mlp/wi/w", (88, 12288, 28672), MESH2, stacked=True)
    assert s2[0] is None
    assert set(x for x in s2[1:] if x) == {("pod", "data"), "model"} or \
           set(x for x in s2[1:] if x) == {"model", ("pod", "data")}


def test_vocab_parallel_embedding():
    s = param_spec("/embed/unembed", (5120, 202240), MESH1)
    assert s[1] == "model"           # vocab on model -> vocab-parallel logits
    s = param_spec("/embed/tok", (202240, 5120), MESH1)
    assert s[0] == "model"


def test_moe_expert_sharding_divisible():
    s = param_spec("/layers/moe/wi", (48, 16, 5120, 8192), MESH1, stacked=True)
    assert s[1] == "model"           # 16 experts over 16-way model axis
    # 40 experts do NOT divide 16 -> fall back to ffn sharding
    s = param_spec("/layers/moe/wi", (32, 40, 1536, 512), MESH1, stacked=True)
    assert s[1] is None and s[3] == "model"


def test_indivisible_dims_replicate():
    s = param_spec("/x/w", (7, 13), MESH1)
    assert s == P(None, None)


def test_batch_axes_divisibility():
    assert batch_axes(MESH2, 256) == ("pod", "data")
    assert batch_axes(MESH2, 2) == ("pod",)
    assert batch_axes(MESH2, 1) == ()
    assert batch_axes(MESH1, 32) == ("data",)
    assert batch_spec(MESH1, 1, 2) == P(None, None)   # long_500k replicates


def test_cache_spec_heads_else_head_dim():
    # kv heads 16 divide the model axis -> heads sharded
    s = cache_spec("/k", (24, 128, 32768, 16, 64), MESH1, 128)
    assert s[3] == "model" and s[1] == "data"
    # kv=8 < 16 -> HEAD DIM sharded (seq must stay unsharded so the
    # one-token cache write never reshards)
    s = cache_spec("/k", (88, 128, 32768, 8, 128), MESH1, 128)
    assert s[4] == "model" and s[2] is None and s[3] is None
    # int8 scale planes: batch only (heads don't divide)
    s = cache_spec("/k_scale", (88, 128, 32769, 8), MESH1, 128)
    assert s[1] == "data" and s[3] is None
    # ssm state heads over model
    s = cache_spec("/mamba/ssm", (48, 1, 64, 64, 128), MESH1, 1)
    assert s[2] == "model"


def test_params_shardings_cover_every_leaf():
    cfg = get_config("qwen2.5-14b")
    specs = param_specs(cfg)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shard = params_shardings(specs, mesh)
    assert jax.tree.structure(jax.tree.map(lambda _: 0, specs)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, shard, is_leaf=lambda x: hasattr(x, "spec"))
    )


def test_every_arch_params_have_valid_specs():
    """No param dim is sharded by an axis that does not divide it."""
    for name in ("mistral-large-123b", "llama4-scout-17b-a16e", "mamba2-1.3b",
                 "hymba-1.5b", "whisper-base", "granite-moe-3b-a800m"):
        cfg = get_config(name)
        specs = param_specs(cfg)

        def walk(path, node, stacked, arch=name):
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(f"{path}/{k}", v, stacked or k in ("layers", "enc_layers"))
                return
            spec = param_spec(path, tuple(node.shape), MESH2, stacked=stacked)
            for dim, ax in zip(node.shape, tuple(spec) + (None,) * 8, strict=False):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= MESH2.shape[a]
                assert dim % size == 0, (arch, path, node.shape, spec)

        walk("", specs, False)


def test_bucketing_groups_by_bytes():
    from repro.distributed.collectives import bucket_leaves

    tree = {f"w{i}": jnp.zeros((1024, 1024), jnp.float32) for i in range(8)}
    buckets = bucket_leaves(tree, bucket_bytes=8 * 1024 * 1024)  # 2 leaves each
    assert all(len(b) == 2 for b in buckets)
    assert sum(len(b) for b in buckets) == 8


def test_cross_pod_mean_reduces():
    """shard_map psum across a 1-sized pod axis is identity; checks wiring."""
    from repro.distributed.collectives import cross_pod_mean

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1), ("pod", "data", "model"))
    g = {"w": jnp.arange(8.0)}
    out = cross_pod_mean(g, mesh, compress="bf16")
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(8.0), atol=1e-2)


def test_param_spec_fallback_small_dim_to_fsdp():
    # model axis (16) does not divide 24, but fsdp does divide both dims and
    # the big dim left fsdp unused? No: big dim takes fsdp; small dim falls
    # back to fsdp only when the big dim could NOT take it.
    mesh = FakeMesh({"data": 4, "model": 16})
    s = param_spec("/x/w", (30, 24), mesh)   # 30 % 4 != 0 -> big dim open
    assert s[1] == ("data",) and s[0] is None  # small dim takes the fsdp axes


def test_param_layout_bridges_spec_to_stitch_layout():
    from repro.distributed.sharding import param_layout

    lay = param_layout("/embed/unembed", (5120, 202240), MESH1)
    assert lay == ((("data",)), ("model",)) or lay == (("data",), ("model",))
    lay = param_layout("/x/w", (7, 13), MESH1)
    assert lay == (None, None)


def test_opt_state_shardings_mirror_params():
    from repro.distributed.sharding import opt_state_shardings

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    pshard = {"w": jax.sharding.NamedSharding(mesh, P("data", "model"))}
    o = opt_state_shardings(None, pshard, mesh)
    assert o.m["w"] is pshard["w"] and o.v["w"] is pshard["w"]
    assert o.step.spec == P()


def test_choose_mesh_shape_validation():
    from repro.distributed.elastic import choose_mesh_shape, make_elastic_mesh

    assert choose_mesh_shape(8, 4) == (2, 4)
    assert choose_mesh_shape(6, 4) == (2, 3)   # 4 -> 3 preserves divisibility
    with pytest.raises(ValueError, match="num_devices"):
        choose_mesh_shape(0)
    with pytest.raises(ValueError, match="num_devices"):
        choose_mesh_shape(-2, 4)
    with pytest.raises(ValueError, match="prefer_model"):
        choose_mesh_shape(8, 0)
    with pytest.raises(ValueError, match="prefer_model"):
        choose_mesh_shape(8, -1)
    with pytest.raises(ValueError, match="num_devices"):
        make_elastic_mesh(devices=[], prefer_model=4)
    with pytest.raises(ValueError, match="prefer_model"):
        make_elastic_mesh(prefer_model=0)
