"""Multi-phase kernel stitching: the three-way schedule verdict, phase
partitioning, staged-interface memory planning, the stitched Pallas emitter
(oracle parity), planner pack/stitch commits, signature salting, and the
codegen scratch edge cases."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import compile_and_compare, make_feeds as _feeds
from repro.core import (
    CONSISTENT,
    INFEASIBLE,
    STITCHABLE,
    FusedComputation,
    MemoryInfeasible,
    StitchOptions,
    compile_module,
    fusion_signature,
    plan_memory,
    plan_stitched_memory,
    reference_execute,
    resolve_stitched,
    stitchable,
    trace,
)
from repro.core.schedule import any_satisfiable


def _softmax_transpose(b, x, g):
    """Row-softmax feeding a full 2-D transpose: the canonical schedule
    break once the intermediate exceeds the replicate limit."""
    scaled = x * b.broadcast(g, x.shape, (1,))
    mx = b.reduce(scaled, (1,), "max")
    e = b.exp(scaled - b.broadcast(mx, x.shape, (0,)))
    s = b.reduce(e, (1,), "sum")
    p = e / b.broadcast(s, x.shape, (0,))
    t = b.transpose(p, (1, 0))
    return b.tanh(t) * 0.5


B, D = 32, 48
TINY_REPL = 1024   # (B, D) f32 is 6144B: far past this replicate limit


def _break_module():
    return trace(
        _softmax_transpose, ("x", (B, D), jnp.float32), ("g", (D,), jnp.float32)
    )


def _members(module):
    return [i for i in module.instructions if i.opcode != "parameter"]




# ------------------------------------------------------- three-way verdict
def test_verdict_consistent_when_one_schedule_exists():
    m = trace(
        lambda b, x: b.tanh(x * 2.0), ("x", (8, 16), jnp.float32)
    )
    members = _members(m)
    roots = FusedComputation(members).roots
    v = stitchable(roots, members)
    assert v.verdict == CONSISTENT
    assert v.solution is not None and v.stitched is None


def test_verdict_stitchable_across_transpose_break():
    m = _break_module()
    members = _members(m)
    roots = FusedComputation(members).roots
    v = stitchable(roots, members, replicate_limit=TINY_REPL, max_blocks=64)
    assert v.verdict == STITCHABLE
    st = v.stitched
    assert st.num_phases >= 2
    assert st.interfaces, "the softmax output must be staged"
    assert st.interface_bytes == B * D * 4
    # every member lands in exactly one phase
    assert sum(st.phase_sizes) == len(members)


def test_verdict_infeasible_when_stitching_disallowed():
    m = _break_module()
    members = _members(m)
    roots = FusedComputation(members).roots
    v = stitchable(
        roots, members, replicate_limit=TINY_REPL, max_blocks=64,
        allow_stitch=False,
    )
    assert v.verdict == INFEASIBLE
    assert not v


def test_phase_partition_cuts_at_the_break():
    m = _break_module()
    members = _members(m)
    roots = FusedComputation(members).roots
    st = resolve_stitched(
        members, roots, replicate_limit=TINY_REPL, max_blocks=64
    )
    # the transpose must start a later phase than the softmax body
    tr = next(i for i in members if i.opcode == "transpose")
    assert st.phase_of(tr) > 0
    assert st.phase_of(st.interfaces[0]) < st.phase_of(tr)


# ------------------------------------------------- stitched memory planning
def test_stitched_memory_plan_allocates_full_interfaces():
    m = _break_module()
    members = _members(m)
    roots = FusedComputation(members).roots
    st = resolve_stitched(
        members, roots, replicate_limit=TINY_REPL, max_blocks=64
    )
    plan = plan_stitched_memory(st, vmem_limit=512 * 1024)
    assert plan.interface_bytes == st.interface_bytes
    for buf in plan.interfaces.values():
        assert int(np.prod(buf.shape or (1,))) * np.dtype(buf.dtype).itemsize \
            == buf.nbytes                      # FULL, untiled allocation
        assert buf.produced_phase < buf.last_consumer_phase
    assert plan.total_bytes <= 512 * 1024
    assert len(plan.phase_plans) == st.num_phases


def test_stitched_memory_plan_infeasible_past_budget():
    m = _break_module()
    members = _members(m)
    roots = FusedComputation(members).roots
    st = resolve_stitched(
        members, roots, replicate_limit=TINY_REPL, max_blocks=64
    )
    with pytest.raises(MemoryInfeasible):
        plan_stitched_memory(st, vmem_limit=2048)  # < one interface tensor


# ------------------------------------------------------ end-to-end compile
def test_stitched_compile_single_kernel_oracle_parity(rng):
    m = _break_module()
    comp = compile_and_compare(
        m, _feeds(m, rng), max_blocks=32, replicate_limit=TINY_REPL
    )
    s = comp.stats
    assert s.stitched_kernels == 1 and s.standalone_kernels == 0
    assert s.stitch_lowered_kernels == 1
    assert s.stitch_phases_total >= 2
    assert s.stitch_interface_bytes == B * D * 4
    assert s.planner_stitches == 1
    [rep] = s.reports
    assert rep.num_phases >= 2
    assert rep.interface_bytes == B * D * 4


def test_stitching_disabled_splits_at_the_break(rng):
    m = _break_module()
    comp = compile_and_compare(
        m, _feeds(m, rng), max_blocks=32, replicate_limit=TINY_REPL,
        enable_stitching=False,
    )
    s = comp.stats
    assert s.stitched_kernels + s.standalone_kernels > 1
    assert s.stitch_lowered_kernels == 0


def test_stitch_falls_back_to_split_when_interface_exceeds_vmem(rng):
    """Satellite: a stitched group whose staged interface cannot fit the
    VMEM budget must fall back to the split plan — and stay correct."""
    m = _break_module()
    comp = compile_and_compare(
        m, _feeds(m, rng), max_blocks=32, replicate_limit=TINY_REPL,
        vmem_limit=4096,
    )
    s = comp.stats
    assert s.stitch_lowered_kernels == 0
    assert s.stitched_kernels + s.standalone_kernels > 1


def test_stitched_and_split_signatures_never_alias():
    m = _break_module()
    members = _members(m)
    plain = FusedComputation(members, name="a")
    stitched = FusedComputation(members, name="a", stitch_phases=(9, 5))
    assert fusion_signature(plain) != fusion_signature(stitched)
    assert fusion_signature(stitched) == fusion_signature(
        FusedComputation(members, name="b", stitch_phases=(9, 5))
    )


def test_greedy_mode_keeps_the_paper_hard_veto(rng):
    """planner='greedy' reproduces the paper's Algorithm 1 exactly: the
    boolean SchdConsistent veto splits at the break and nothing is ever
    lowered through the stitched emitter."""
    m = _break_module()
    comp = compile_and_compare(
        m, _feeds(m, rng), max_blocks=32, replicate_limit=TINY_REPL,
        planner="greedy",
    )
    assert comp.stats.stitch_lowered_kernels == 0
    assert comp.stats.stitched_kernels + comp.stats.standalone_kernels > 1


# ------------------------------------------------- codegen scratch edges
def test_zero_scratch_slot_fusion(rng):
    """Satellite: a fused group whose plan allocates NO scratch slots (pure
    elementwise chain) emits and matches the oracle."""
    def f(b, x):
        for _ in range(5):
            x = b.tanh(x * 1.1 + 0.1)
        return x

    m = trace(f, ("x", (8, 16), jnp.float32))
    comp = compile_and_compare(m, _feeds(m, rng))
    s = comp.stats
    assert s.stitched_kernels == 1
    assert s.smem_max == 0            # no ALLOC anywhere
    assert all(r.scratch_bytes == 0 for r in s.reports)


def test_share_slot_reuse_across_interior_ops(rng):
    """Satellite: two serial interior reduces with identical chunk shapes —
    the second dominates the first (its value is dead), so the dominance
    planner reuses ONE scratch slot for both."""
    def f(b, x):
        m1 = b.reduce(x, (1,), "mean")
        y = x * b.broadcast(m1, x.shape, (0,))
        m2 = b.reduce(y, (1,), "mean")
        return b.tanh(b.broadcast(m2, x.shape, (0,)))

    m = trace(f, ("x", (16, 32), jnp.float32))
    members = _members(m)
    fusion = FusedComputation(members)
    roots = fusion.roots
    sol = any_satisfiable(members, roots, max_blocks=32)
    plan = plan_memory(members, roots, sol)
    actions = [e.action for e in plan.entries.values()]
    assert "SHARE" in actions
    assert len(plan.slots) == 1       # one slot serves both reduces
    assert plan.shared_bytes > 0
    comp = compile_and_compare(m, _feeds(m, rng), max_blocks=32)
    assert comp.stats.shared_ratio > 0
