"""VMEM (shared-memory) planning: requirements, shrinking, dominance sharing
(paper §5.1)."""
import jax.numpy as jnp
import pytest

from repro.core import GraphBuilder, MemoryInfeasible, Sched, plan_memory, resolve_schedules
from repro.core.memory import ALLOC, INLINE, SHARE, dominance_tree, dominates
from repro.core.schedule import ROW


def _resolve(b, root, split=0, sword=1):
    m = b.module
    members = [i for i in m.instructions if i.opcode != "parameter"]
    roots = [r for r in m.roots]
    sol = resolve_schedules(
        members, roots, {r.id: Sched("chunked", split, sword, ROW) for r in roots}
    )
    return members, roots, sol


def test_nonroot_reduce_requires_alloc():
    b = GraphBuilder()
    x = b.parameter("x", (4, 8), jnp.float32)
    s = b.reduce(x, (1,), "sum")
    y = b.broadcast(s, (4, 8), (0,)) + x
    members, roots, sol = _resolve(b, y)
    plan = plan_memory(members, roots, sol)
    assert plan.action(s.instr) == ALLOC
    assert plan.action(y.instr) == INLINE


def test_expensive_multiuser_allocated_cheap_singleuser_inlined():
    b = GraphBuilder()
    x = b.parameter("x", (4, 8), jnp.float32)
    e = b.exp(x)              # expensive, 2 users
    a = e + x                 # cheap, 1 user
    _ = a * e
    members, roots, sol = _resolve(b, None)
    plan = plan_memory(members, roots, sol)
    assert plan.action(e.instr) == ALLOC
    assert plan.action(a.instr) == INLINE


def test_expensive_feeding_dot_through_bitcast_allocated():
    """The paper's Divide.1 -> Bitcast.1 -> Dot.1 case (Fig. 3)."""
    b = GraphBuilder()
    x = b.parameter("x", (2, 4, 8), jnp.float32)
    v = b.parameter("v", (2, 8, 4), jnp.float32)
    d = b.exp(x) / 2.0                        # expensive, single user
    bc = b.bitcast(d, (2, 4, 8))
    _ = b.dot(bc, v, fusable=True)
    members, roots, sol = _resolve(b, None)
    plan = plan_memory(members, roots, sol)
    assert plan.action(d.instr) == ALLOC


def test_shrinking_order_cheap_multiuser_first():
    b = GraphBuilder()
    x = b.parameter("x", (64, 64), jnp.float32)   # 16 KiB chunks
    cheap = x + x                                  # cheap multi-user
    e = b.exp(x)                                   # expensive multi-user
    _ = cheap * e + (cheap - e)
    members, roots, sol = _resolve(b, None)
    # budget fits only one buffer: the cheap one is dropped first
    plan = plan_memory(members, roots, sol, vmem_limit=20 * 1024)
    assert plan.action(cheap.instr) == INLINE
    assert plan.action(e.instr) == ALLOC
    assert plan.num_shrinks == 1
    assert plan.shrunk == [cheap.instr.name]


def test_required_over_budget_raises_feedback():
    b = GraphBuilder()
    x = b.parameter("x", (64, 64), jnp.float32)
    s = b.reduce(x, (1,), "sum")                   # required buffer
    _ = b.broadcast(s, (64, 64), (0,)) + x
    members, roots, sol = _resolve(b, None)
    with pytest.raises(MemoryInfeasible):
        plan_memory(members, roots, sol, vmem_limit=16)


def test_dominance_tree_on_diamond():
    b = GraphBuilder()
    x = b.parameter("x", (4, 4), jnp.float32)
    e = b.exp(x)                   # diamond top
    lhs, rhs = e + 1.0, e * 2.0
    root = lhs / rhs                   # diamond bottom (root)
    m = b.module
    members = [i for i in m.instructions if i.opcode != "parameter"]
    idom = dominance_tree(members, [root.instr])
    assert dominates(root.instr.id, e.instr.id, idom)      # root dominates all
    assert not dominates(lhs.instr.id, e.instr.id, idom)   # side of diamond no
    assert not dominates(rhs.instr.id, e.instr.id, idom)


def test_space_sharing_dominator_reuses_dead_slot():
    """exp.2 dominates exp.1 in a two-stage chain -> SHARE (paper Fig. 3)."""
    b = GraphBuilder()
    x = b.parameter("x", (8, 16), jnp.float32)
    e1 = b.exp(x)                                  # expensive, 2 users
    r1 = b.reduce(e1, (1,), "sum")
    m1 = e1 * b.broadcast(r1, (8, 16), (0,))
    e2 = b.exp(m1)                                 # expensive, 2 users
    r2 = b.reduce(e2, (1,), "sum")
    _ = e2 * b.broadcast(r2, (8, 16), (0,))
    members, roots, sol = _resolve(b, None)
    plan = plan_memory(members, roots, sol)
    assert plan.action(e1.instr) == ALLOC
    assert plan.entries[e2.instr.id].action == SHARE
    assert plan.entries[e2.instr.id].slot == plan.entries[e1.instr.id].slot
    assert plan.shared_bytes > 0 and plan.shared_ratio > 0


def test_no_sharing_between_concurrently_live_buffers():
    b = GraphBuilder()
    x = b.parameter("x", (8, 16), jnp.float32)
    e1 = b.exp(x)
    e2 = b.log(b.abs(x) + 1.0)
    r1 = b.reduce(e1, (1,), "sum")
    r2 = b.reduce(e2, (1,), "sum")
    # both e1 and e2 used again AFTER both reduces -> overlapping live ranges
    _ = (e1 + e2) * b.broadcast(r1 + r2, (8, 16), (0,))
    members, roots, sol = _resolve(b, None)
    plan = plan_memory(members, roots, sol)
    slots = {
        plan.entries[i.instr.id].slot
        for i in (e1, e2)
        if plan.entries[i.instr.id].action in (ALLOC, SHARE)
    }
    assert len(slots) == 2, "live buffers must not share a slot"
