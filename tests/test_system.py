"""End-to-end behaviour tests for the whole system: the paper's pipeline on
its motivating example, training-to-convergence with checkpoint/restart, and
the serving path — the integration layer above the unit tests."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core import StitchOptions, compile_module, reference_execute, trace
from repro.data import SyntheticLM
from repro.models import init_params
from repro.serve import Request, ServeEngine
from repro.train import AdamWConfig, adamw_init, make_train_step


def test_fig3_pattern_single_stitched_kernel(rng):
    """The paper's motivating example end-to-end: softmax×BatchDot becomes
    ONE stitched kernel, uses VMEM scratch with sharing, matches the oracle,
    and beats the XLA baseline by >4x on launches."""

    def attn(b, q, k, v):
        kt = b.transpose(k, (0, 1, 3, 2))
        s = b.dot(q, kt, fusable=True) * 0.125
        return b.dot(b.softmax(s, dim=-1), v, fusable=True)

    m = trace(
        attn,
        ("q", (2, 4, 16, 32), jnp.float32),
        ("k", (2, 4, 16, 32), jnp.float32),
        ("v", (2, 4, 16, 32), jnp.float32),
    )
    comp = compile_module(m, StitchOptions(max_blocks=32))
    s = comp.stats
    assert s.stitched_kernels == 1 and s.standalone_kernels == 0
    assert s.xla_baseline_kernels >= 5
    assert s.fusion_ratio <= 0.25
    rep = s.reports[0]
    assert rep.scratch_bytes > 0, "block composition must use VMEM scratch"
    assert rep.shared_bytes > 0, "dominance sharing must trigger (Fig. 3)"
    feeds = {n: rng.randn(2, 4, 16, 32).astype("f4") for n in "qkv"}
    ref = reference_execute(m, feeds)
    out = comp(feeds)
    for key in ref:
        np.testing.assert_allclose(
            np.asarray(out[key]), np.asarray(ref[key]), rtol=2e-5, atol=2e-5
        )


def test_train_then_serve_roundtrip():
    """Train a tiny LM until loss drops, then serve greedy completions from
    the trained weights — the full lifecycle."""
    cfg = reduced_config(get_config("qwen1.5-0.5b"), num_layers=2,
                         vocab_size=128)
    params = init_params(cfg, 0)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60,
                         schedule="constant")
    ), donate_argnums=(0, 1))
    opt = adamw_init(params)
    data = SyntheticLM(cfg, seq_len=24, global_batch=8, seed=3)
    losses = []
    for i in range(50):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.85

    engine = ServeEngine(cfg, params, pool_size=2, max_len=64)
    req = Request(rid=0, prompt=np.array([3, 14, 15]), max_new_tokens=8)
    assert engine.admit(req)
    engine.run_until_done()
    assert req.done and len(req.out_tokens) == 8
    assert all(0 <= t < cfg.vocab_size for t in req.out_tokens)


def test_compiler_handles_training_graph(rng):
    """FusionStitching over a training-style graph (fwd + grads + updates):
    the weight-accumulation horizontal-fusion case from §3.2."""
    from repro.core import GraphBuilder

    b = GraphBuilder("sgd")
    x = b.parameter("x", (8, 16), jnp.float32)
    y = b.parameter("y", (8, 4), jnp.float32)
    W = b.parameter("W", (16, 4), jnp.float32)
    z = b.dot(x, W)
    p = b.sigmoid(z)
    e = p - y
    dW = b.dot(b.transpose(x, (1, 0)), e)
    _W2 = W - dW * 0.05
    _loss = b.reduce(b.square(e), (0, 1), "mean")
    comp = compile_module(b.module, StitchOptions(max_blocks=16))
    assert comp.stats.fusion_ratio <= 1.0
    feeds = {
        "x": rng.randn(8, 16).astype("f4"),
        "y": rng.rand(8, 4).astype("f4"),
        "W": rng.randn(16, 4).astype("f4"),
    }
    ref = reference_execute(b.module, feeds)
    out = comp(feeds)
    for key in ref:
        np.testing.assert_allclose(
            np.asarray(out[key]), np.asarray(ref[key]), rtol=2e-5, atol=2e-5
        )


def test_perf_library_persists_across_compiles(tmp_path):
    """Paper §4.4: the KV store is persistent; a second compile hits it."""
    from repro.core import PerfLibrary

    path = str(tmp_path / "perf.json")

    def f(b, x):
        return b.softmax(x, dim=-1)

    m = trace(f, ("x", (4, 16), jnp.float32))
    compile_module(m, StitchOptions(max_blocks=16, perf_library_path=path))
    lib = PerfLibrary(path)
    assert len(lib) > 0
    before = len(lib)
    m2 = trace(f, ("x", (4, 16), jnp.float32))
    compile_module(m2, StitchOptions(max_blocks=16, perf_library_path=path))
    lib2 = PerfLibrary(path)
    assert len(lib2) == before  # pure cache hits, no new keys
