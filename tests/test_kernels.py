"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import (
    attention_ref,
    decode_attention_ref,
    moe_gate_ref,
    rmsnorm_ref,
    softmax_ref,
)

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.randn(*shape), dtype=dtype)


# ------------------------------------------------------------------ softmax
@pytest.mark.parametrize("shape", [(8, 16), (4, 8, 32), (2, 3, 5, 64), (16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_softmax_sweep(rng, shape, dtype):
    x = _rand(rng, shape, dtype)
    got = ops.softmax(x)
    want = softmax_ref(x)
    assert got.dtype == x.dtype and got.shape == x.shape
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64), **TOL[dtype]
    )


@pytest.mark.parametrize("block_rows", [1, 2, 4, 8])
def test_softmax_block_sweep(rng, block_rows):
    x = _rand(rng, (8, 24), jnp.float32)
    got = ops.softmax(x, block_rows=block_rows)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(softmax_ref(x)), rtol=2e-5, atol=2e-5
    )


# ------------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("shape", [(4, 32), (2, 8, 64), (3, 5, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rng, shape, dtype):
    x = _rand(rng, shape, dtype)
    g = _rand(rng, shape[-1:], dtype)
    got = ops.rmsnorm(x, g)
    want = rmsnorm_ref(x, g)
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64), **TOL[dtype]
    )


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D", [(1, 2, 2, 16, 8), (2, 4, 2, 32, 16), (1, 8, 1, 16, 8)]
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(rng, B, Hq, Hkv, S, D, causal):
    q = _rand(rng, (B, Hq, S, D), jnp.float32)
    k = _rand(rng, (B, Hkv, S, D), jnp.float32)
    v = _rand(rng, (B, Hkv, S, D), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, block_q=8, block_k=8)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_flash_attention_bf16(rng):
    q = _rand(rng, (1, 2, 16, 8), jnp.bfloat16)
    k = _rand(rng, (1, 2, 16, 8), jnp.bfloat16)
    v = _rand(rng, (1, 2, 16, 8), jnp.bfloat16)
    got = ops.attention(q, k, v, causal=True, block_q=8, block_k=8)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_flash_attention_blocks_equivalent(rng):
    q = _rand(rng, (1, 2, 32, 8), jnp.float32)
    k = _rand(rng, (1, 2, 32, 8), jnp.float32)
    v = _rand(rng, (1, 2, 32, 8), jnp.float32)
    a = ops.attention(q, k, v, block_q=8, block_k=16)
    b = ops.attention(q, k, v, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D", [(2, 4, 2, 32, 8), (1, 8, 1, 64, 16), (3, 2, 2, 16, 8)]
)
def test_decode_attention_sweep(rng, B, Hq, Hkv, S, D):
    q = _rand(rng, (B, Hq, D), jnp.float32)
    k = _rand(rng, (B, Hkv, S, D), jnp.float32)
    v = _rand(rng, (B, Hkv, S, D), jnp.float32)
    lengths = jnp.asarray(rng.randint(1, S + 1, size=(B,)), jnp.int32)
    got = ops.attention_decode(q, k, v, lengths, block_k=8)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_decode_matches_prefill_last_token(rng):
    """decode(q_last, cache) == causal prefill's last row — the serve-path
    consistency invariant."""
    B, H, S, D = 1, 2, 16, 8
    q = _rand(rng, (B, H, S, D), jnp.float32)
    k = _rand(rng, (B, H, S, D), jnp.float32)
    v = _rand(rng, (B, H, S, D), jnp.float32)
    full = ops.attention(q, k, v, causal=True, block_q=8, block_k=8)
    dec = ops.attention_decode(
        q[:, :, -1], k, v, jnp.full((B,), S, jnp.int32), block_k=8
    )
    np.testing.assert_allclose(
        np.asarray(full[:, :, -1]), np.asarray(dec), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------- moe gate
@pytest.mark.parametrize("T,E,k", [(16, 8, 2), (32, 40, 8), (8, 16, 1), (64, 64, 4)])
def test_moe_gate_sweep(rng, T, E, k):
    logits = _rand(rng, (T, E), jnp.float32)
    w, i = ops.moe_gate(logits, top_k=k, block_tokens=8)
    w_ref, i_ref = moe_gate_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, rtol=1e-5)


def test_moe_gate_bf16_logits(rng):
    logits = _rand(rng, (16, 8), jnp.bfloat16)
    w, i = ops.moe_gate(logits.astype(jnp.float32), top_k=2, block_tokens=8)
    assert w.dtype == jnp.float32 and i.dtype == jnp.int32
