"""Launch-layer tests on a 1-device mesh: input specs, cell lowering,
jaxpr cost model, HLO collective census (no 512-device requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.costmodel import fn_cost
from repro.launch.dryrun import cell_is_skipped, input_specs
from repro.launch.hlostats import collective_bytes
from repro.configs import ARCHITECTURES, SHAPES


def test_input_specs_cover_every_cell():
    for arch in ARCHITECTURES:
        for shape in SHAPES:
            specs = input_specs(arch, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves and all(isinstance(leaf, jax.ShapeDtypeStruct) for leaf in leaves)
            if SHAPES[shape]["kind"] == "decode":
                assert specs["tokens"].shape == (SHAPES[shape]["global_batch"],)


def test_long_context_skips_match_design():
    skipped = {
        a for a in ARCHITECTURES if cell_is_skipped(a, "long_500k") is not None
    }
    assert skipped == {
        "llama4-scout-17b-a16e", "granite-moe-3b-a800m", "qwen1.5-0.5b",
        "mistral-large-123b", "granite-20b", "qwen2.5-14b", "qwen2-vl-2b",
        "whisper-base",
    }
    assert cell_is_skipped("mamba2-1.3b", "long_500k") is None
    assert cell_is_skipped("hymba-1.5b", "long_500k") is None


def test_jaxpr_cost_counts_scan_bodies():
    """The raison d'être of the walker: scan body costs multiply by length
    (XLA's cost_analysis counts while bodies once)."""

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    cost = fn_cost(f, x, w)
    dot_flops = 2 * 8 * 16 * 16
    assert cost["dot_flops"] == pytest.approx(7 * dot_flops)


def test_jaxpr_cost_dot_general_exact():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    cost = fn_cost(f, a, b)
    assert cost["dot_flops"] == 2 * 4 * 8 * 32 * 16


def test_jaxpr_cost_counts_remat_recompute():
    def g(x):
        return jnp.sum(jnp.tanh(x) ** 2)

    def with_remat(x):
        return jax.grad(lambda y: jax.checkpoint(g)(y))(x)

    def without(x):
        return jax.grad(g)(x)

    x = jax.ShapeDtypeStruct((64,), jnp.float32)
    assert fn_cost(with_remat, x)["flops"] >= fn_cost(without, x)["flops"]


HLO_SAMPLE = """
HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ag = f32[16]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""


def test_collective_census_scales_by_trip_count():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 4                 # once, entry
    assert out["all-reduce"] == 5 * 8 * 4              # 5 loop trips


def test_one_device_cell_lowers_and_compiles():
    """End-to-end build_cell on a 1x1 mesh with a reduced arch — keeps the
    dry-run path under pytest without 512 host devices."""

    from repro.launch import dryrun as dr
    from repro.configs import get_config, reduced_config

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # monkeypatch a tiny cell: reduced config + tiny shape
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    orig_get, orig_shapes = dr.get_config, dict(dr.SHAPES)
    try:
        dr.get_config = lambda name: cfg  # noqa: E731
        dr.SHAPES["tiny"] = dict(seq_len=16, global_batch=2, kind="train")
        with mesh:
            fn, args, raw = dr.build_cell("qwen1.5-0.5b", "tiny", mesh, 1)
            compiled = fn.lower(*args).compile()
        assert compiled.cost_analysis() is not None
        cost = dr_cost = fn_cost(raw, *args)
        assert cost["flops"] > 0
    finally:
        dr.get_config = orig_get
        dr.SHAPES.clear()
        dr.SHAPES.update(orig_shapes)
