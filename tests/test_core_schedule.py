"""Schedule spec + Table-1 propagation rules (paper §4.1/§4.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    GraphBuilder,
    REPLICATED,
    Sched,
    Unsatisfiable,
    blocks_of,
    candidate_schedules,
    chunk_shape,
    propagate,
    resolve_schedules,
)
from repro.core.schedule import ROW, COLUMN, block_index


# ---------------------------------------------------------------- blocks math
def test_blocks_and_chunks_row():
    s = Sched("chunked", 1, 2, ROW)
    assert blocks_of((4, 6, 8), s) == 4 * 2
    assert chunk_shape((4, 6, 8), s) == (1, 3, 8)


def test_blocks_and_chunks_column():
    s = Sched("chunked", 1, 3, COLUMN)
    assert blocks_of((4, 6, 8), s) == 3 * 8
    assert chunk_shape((4, 6, 8), s) == (4, 2, 1)


@given(
    st.lists(st.integers(1, 6), min_size=1, max_size=4),
    st.data(),
)
@settings(max_examples=80, deadline=None)
def test_block_index_covers_workspace(dims, data):
    """Property: the blocks×chunk grid tiles the whole output space exactly."""
    shape = tuple(dims)
    cands = candidate_schedules(shape, max_blocks=1 << 12)
    sched = data.draw(st.sampled_from(cands))
    if sched.kind != "chunked":
        return
    blocks = blocks_of(shape, sched)
    cs = chunk_shape(shape, sched)
    seen = np.zeros(shape, dtype=int)
    for b in range(blocks):
        idx = block_index(shape, sched, b)
        sl = tuple(
            slice(i * c, (i + 1) * c) for i, c in zip(idx, cs, strict=False)
        )
        seen[sl] += 1
    assert (seen == 1).all(), f"{sched} does not tile {shape}"


# ---------------------------------------------------------------- propagation
def _instr(builder_fn):
    b = GraphBuilder()
    return builder_fn(b).instr


def test_elementwise_passes_row_and_column():
    i = _instr(lambda b: b.exp(b.parameter("x", (4, 8), jnp.float32)))
    for t in (ROW, COLUMN):
        s = Sched("chunked", 0, 2, t)
        assert propagate(i, s) == [s]


def test_reduce_row_requires_split_left_of_reduce_dims():
    i = _instr(
        lambda b: b.reduce(b.parameter("x", (4, 6, 8), jnp.float32), (2,), "sum")
    )
    # output (4,6); split on dim 0 -> input split 0 < reduce dim 2: Row OK
    (got,) = propagate(i, Sched("chunked", 0, 4, ROW))
    assert got == Sched("chunked", 0, 4, ROW)
    # Column with split left of the reduce dims is rejected
    with pytest.raises(Unsatisfiable):
        propagate(i, Sched("chunked", 0, 4, COLUMN))


def test_reduce_column_requires_split_right_of_reduce_dims():
    i = _instr(
        lambda b: b.reduce(b.parameter("x", (4, 6, 8), jnp.float32), (0,), "sum")
    )
    # output (6,8); out dim 1 -> input dim 2 > reduce dim 0: Column OK
    (got,) = propagate(i, Sched("chunked", 1, 2, COLUMN))
    assert got == Sched("chunked", 2, 2, COLUMN)
    with pytest.raises(Unsatisfiable):
        propagate(i, Sched("chunked", 1, 2, ROW))


def test_transpose_rules():
    i = _instr(
        lambda b: b.transpose(b.parameter("x", (4, 6, 8), jnp.float32), (0, 2, 1))
    )
    # moved dims = {1,2}; split 0 < 1 -> Row passes unchanged
    (got,) = propagate(i, Sched("chunked", 0, 2, ROW))
    assert got == Sched("chunked", 0, 2, ROW)
    with pytest.raises(Unsatisfiable):
        propagate(i, Sched("chunked", 1, 2, ROW))
    with pytest.raises(Unsatisfiable):
        propagate(i, Sched("chunked", 1, 2, COLUMN))


def test_dot_requires_batch_split():
    i = _instr(
        lambda b: b.dot(
            b.parameter("l", (4, 8, 16), jnp.float32),
            b.parameter("r", (4, 16, 8), jnp.float32),
            fusable=True,
        )
    )
    got = propagate(i, Sched("chunked", 0, 2, ROW))
    assert got == [Sched("chunked", 0, 2, ROW)] * 2
    with pytest.raises(Unsatisfiable):
        propagate(i, Sched("chunked", 1, 2, ROW))  # M dim is not a batch dim


def test_reshape_row_remaps_contiguous_runs():
    i = _instr(
        lambda b: b.reshape(b.parameter("x", (4, 6, 8), jnp.float32), (24, 8))
    )
    # out (24,8) split 0 sword 4 -> run = 6*8 elements = input (s=0, sword=4)?
    # run=48 -> input suffix(1)=48 -> c=1, s'=0, w'=4
    (got,) = propagate(i, Sched("chunked", 0, 4, ROW))
    assert got.sched_type == ROW and blocks_of((4, 6, 8), got) == 4


def test_broadcast_maps_or_replicates():
    i = _instr(
        lambda b: b.broadcast(
            b.parameter("x", (6,), jnp.float32), (4, 6, 8), (1,)
        )
    )
    (got,) = propagate(i, Sched("chunked", 1, 2, ROW))
    assert got == Sched("chunked", 0, 2, ROW)       # split maps to operand dim
    (got,) = propagate(i, Sched("chunked", 0, 2, ROW))
    assert got == REPLICATED                        # split not in dims


def test_concat_rules():
    i = _instr(
        lambda b: b.concat(
            [b.parameter("a", (4, 3), jnp.float32), b.parameter("b", (4, 5), jnp.float32)],
            dim=1,
        )
    )
    got = propagate(i, Sched("chunked", 0, 4, ROW))
    assert len(got) == 2 and all(g.sched_type == ROW for g in got)
    with pytest.raises(Unsatisfiable):
        propagate(i, Sched("chunked", 1, 2, ROW))


# ------------------------------------------------------------- resolution
def test_softmax_resolution_all_chunked_on_batch_split():
    b = GraphBuilder()
    x = b.parameter("x", (4, 8, 16), jnp.float32)
    y = b.softmax(x, dim=-1)
    m = b.module
    members = [i for i in m.instructions if i.opcode != "parameter"]
    roots = [y.instr]
    sol = resolve_schedules(members, roots, {y.instr.id: Sched("chunked", 0, 4, ROW)})
    assert sol.blocks == 4
    # every member aligns with the launch grid (no forced replication)
    for mem in members:
        assert sol.sched(mem).kind == "chunked", mem


def test_resolution_rejects_oversized_replication():
    b = GraphBuilder()
    x = b.parameter("x", (512, 1024), jnp.float32)   # 2 MiB
    s = b.reduce(x, (0,), "sum")                     # (1024,)
    y = b.broadcast(s, (512, 1024), (1,)) * x
    m = b.module
    members = [i for i in m.instructions if i.opcode != "parameter"]
    # split on dim 0: the column-reduce input would need full replication of x
    with pytest.raises(Unsatisfiable):
        resolve_schedules(
            members, [y.instr], {y.instr.id: Sched("chunked", 0, 512, ROW)},
            replicate_limit=64 * 1024,
        )
