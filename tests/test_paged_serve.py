"""Paged continuous batching: block allocator, paged-vs-slot token parity
(the slot ring is the oracle), preemption-by-recomputation, scheduler
fairness/liveness, and the traffic harness."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serve import (
    BlockAllocator,
    PagedServeEngine,
    Request,
    Scheduler,
    ServeEngine,
    SLOConfig,
    TraceConfig,
    blocks_for_tokens,
    generate_trace,
    run_trace,
)
from repro.serve.scheduler import DECODE_ACTION, IDLE_ACTION, PREFILL_ACTION


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    return cfg, init_params(cfg, 0)


# ---------------------------------------------------------- allocator
def test_blocks_for_tokens():
    assert blocks_for_tokens(1, 4, 32) == 1
    assert blocks_for_tokens(4, 4, 32) == 1
    assert blocks_for_tokens(5, 4, 32) == 2
    assert blocks_for_tokens(100, 4, 32) == 8   # ring caps the need


def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(num_blocks=4, block_size=8)
    got = a.alloc(3)
    assert got is not None and len(got) == 3
    assert len(set(got)) == 3                   # no double-assignment
    assert a.num_free == 1 and a.num_in_use == 3
    assert a.alloc(2) is None                   # all-or-nothing
    assert a.alloc_failures == 1
    assert a.num_free == 1                      # failed alloc takes nothing
    a.free(got)
    assert a.num_free == 4 and a.num_in_use == 0
    a.check_consistent()
    assert a.stats()["peak_in_use"] == 3


def test_allocator_double_free_raises():
    a = BlockAllocator(num_blocks=2, block_size=4)
    got = a.alloc(1)
    a.free(got)
    with pytest.raises(RuntimeError, match="double free|not allocated"):
        a.free(got)
    with pytest.raises(RuntimeError, match="not allocated"):
        a.free([99])                            # foreign block
    a.check_consistent()


# ------------------------------------------------------- token parity
def test_paged_matches_slot_engine_single_request(small_model):
    """Acceptance criterion: for any single request the paged engine emits
    exactly the slot-ring oracle's token sequence (several prompt lengths,
    crossing block boundaries and the chunked-prefill ragged tail)."""
    cfg, params = small_model
    for plen, chunk in ((1, 4), (3, 4), (7, 4), (12, 8), (17, 4)):
        prompt = (np.arange(plen) % 100 + 1).astype(np.int32)
        oracle = Request(rid=0, prompt=prompt, max_new_tokens=6)
        e1 = ServeEngine(cfg, params, pool_size=2, max_len=32,
                         prefill_chunk=chunk)
        e1.admit(oracle)
        e1.run_until_done()

        req = Request(rid=0, prompt=prompt, max_new_tokens=6)
        e2 = PagedServeEngine(cfg, params, decode_width=2, max_len=32,
                              block_size=4, prefill_chunk=chunk)
        e2.admit(req)
        assert e2.run_until_done() == 0
        assert req.out_tokens == oracle.out_tokens, (plen, chunk)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "hymba-1.5b"])
def test_paged_matches_slot_engine_other_families(arch):
    """SSM rows (no KV blocks at all) and the hybrid sliding-window family
    (block tables over a ring smaller than max_len) hit different paged
    paths — parity must hold for both."""
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, 0)
    prompt = np.arange(1, 10, dtype=np.int32)
    oracle = Request(rid=0, prompt=prompt, max_new_tokens=6)
    e1 = ServeEngine(cfg, params, pool_size=2, max_len=32, prefill_chunk=4)
    e1.admit(oracle)
    e1.run_until_done()

    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    e2 = PagedServeEngine(cfg, params, decode_width=2, max_len=32,
                          block_size=4, prefill_chunk=4)
    e2.admit(req)
    assert e2.run_until_done() == 0
    assert req.out_tokens == oracle.out_tokens


def test_paged_batch_isolation(small_model):
    """A request's tokens must not depend on what shares the decode batch
    or which physical blocks it happens to get."""
    cfg, params = small_model
    prompt = np.array([5, 9, 2, 17], np.int32)
    solo = Request(rid=0, prompt=prompt, max_new_tokens=5)
    e1 = PagedServeEngine(cfg, params, decode_width=4, max_len=32,
                          block_size=4, prefill_chunk=4)
    e1.admit(solo)
    e1.run_until_done()

    e2 = PagedServeEngine(cfg, params, decode_width=4, max_len=32,
                          block_size=4, prefill_chunk=4)
    others = [
        Request(rid=i, prompt=np.full(6, 3 + i, np.int32), max_new_tokens=8)
        for i in (1, 2)
    ]
    same = Request(rid=3, prompt=prompt, max_new_tokens=5)
    for r in others:
        e2.admit(r)
    e2.admit(same)
    e2.run_until_done()
    assert same.out_tokens == solo.out_tokens


def test_paged_mid_stream_admission(small_model):
    cfg, params = small_model
    eng = PagedServeEngine(cfg, params, decode_width=2, max_len=32,
                           block_size=4, prefill_chunk=4)
    r1 = Request(rid=0, prompt=np.array([1, 2, 3]), max_new_tokens=10)
    eng.admit(r1)
    eng.tick()
    eng.tick()
    r2 = Request(rid=1, prompt=np.array([7, 8]), max_new_tokens=4)
    assert eng.admit(r2)             # joins while r1 is mid-generation
    assert eng.run_until_done() == 0
    assert len(r1.out_tokens) == 10 and len(r2.out_tokens) == 4


# ------------------------------------------------ preemption/recompute
def test_preemption_resume_token_parity(small_model):
    """A pool of exactly one max-length context forces the two requests to
    fight for blocks; the preempted one resumes by recomputation and must
    still emit its solo token sequence."""
    cfg, params = small_model
    p1 = np.arange(1, 13, dtype=np.int32)
    p2 = np.arange(20, 32, dtype=np.int32)
    solo = {}
    for i, p in enumerate((p1, p2)):
        e = ServeEngine(cfg, params, pool_size=1, max_len=32, prefill_chunk=4)
        r = Request(rid=i, prompt=p, max_new_tokens=16)
        e.admit(r)
        e.run_until_done()
        solo[i] = r.out_tokens

    eng = PagedServeEngine(cfg, params, decode_width=2, max_len=32,
                           block_size=4, num_blocks=8, prefill_chunk=4)
    ra = Request(rid=0, prompt=p1, max_new_tokens=16)
    rb = Request(rid=1, prompt=p2, max_new_tokens=16)
    eng.admit(ra)
    eng.admit(rb)
    assert eng.run_until_done(max_ticks=1000) == 0
    assert eng.sched.preemptions > 0, "pool was sized to force preemption"
    assert ra.out_tokens == solo[0]
    assert rb.out_tokens == solo[1]
    eng.allocator.check_consistent()
    assert eng.allocator.num_in_use == 0


def test_single_request_pool_floor_enforced(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="cannot hold even one"):
        PagedServeEngine(cfg, params, decode_width=2, max_len=32,
                         block_size=4, num_blocks=5)


# --------------------------------------------------- fairness/liveness
def test_bursty_trace_liveness_and_no_block_leak(small_model):
    """Under bursty arrivals over an undersized pool every admitted request
    must eventually finish, no block may be double-assigned, and every
    freed block must return to the pool."""
    cfg, params = small_model
    tc = TraceConfig(num_requests=32, arrival="bursty", burst_size=12,
                     burst_gap_ticks=8.0, prompt_len_lo=3, prompt_len_hi=10,
                     max_new_lo=3, max_new_hi=8, vocab_size=cfg.vocab_size,
                     seed=3)
    eng = PagedServeEngine(cfg, params, decode_width=8, max_len=32,
                           block_size=4, num_blocks=16, prefill_chunk=4)
    rep = run_trace(eng, generate_trace(tc), max_ticks=20_000, strict=True)
    assert rep.completed == rep.total == 32
    assert rep.unfinished == 0
    eng.allocator.check_consistent()       # no double-assign, no leak
    assert eng.allocator.num_in_use == 0
    st = eng.allocator.stats()
    assert st["allocated_total"] == st["freed_total"]


def test_paged_fifo_admission_order(small_model):
    """Queued requests claim rows in submission order even when later ones
    are smaller and would fit sooner."""
    cfg, params = small_model
    eng = PagedServeEngine(cfg, params, decode_width=1, max_len=32,
                           block_size=4, num_blocks=8, prefill_chunk=4)
    reqs = [
        Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=3),
        Request(rid=1, prompt=np.arange(1, 7, dtype=np.int32), max_new_tokens=3),
        Request(rid=2, prompt=np.array([1], np.int32), max_new_tokens=3),
    ]
    assert eng.admit(reqs[0]) is True
    assert eng.admit(reqs[1]) is False     # queued (width 1)
    assert eng.admit(reqs[2]) is False
    assert eng.run_until_done() == 0
    assert reqs[0].t_first <= reqs[1].t_first <= reqs[2].t_first
    assert all(len(r.out_tokens) == 3 for r in reqs)


def test_paged_rejection_and_truncation_satellites(small_model):
    cfg, params = small_model
    eng = PagedServeEngine(cfg, params, decode_width=2, max_len=32,
                           block_size=4, prefill_chunk=4)
    bad = Request(rid=0, prompt=np.ones(40, np.int32))
    for _ in range(3):
        with pytest.raises(ValueError, match="exceeds the KV cache"):
            eng.admit(bad)
    assert eng.requests_rejected == 1      # counted once across retries

    slow = Request(rid=1, prompt=np.array([1, 2]), max_new_tokens=25)
    eng.admit(slow)
    with pytest.warns(RuntimeWarning, match="TRUNCATED"):
        remaining = eng.run_until_done(max_ticks=2)
    assert remaining == 1
    with pytest.raises(RuntimeError, match="TRUNCATED"):
        eng.run_until_done(max_ticks=1, strict=True)
    assert eng.run_until_done() == 0 and slow.done


def test_paged_concurrency_exceeds_slot_pool(small_model):
    """The tentpole claim at test scale: same total KV budget (16 blocks x
    4 == 2 slots x 32 tokens), short requests — the paged engine runs >=4x
    the slot engine's pool in flight at once."""
    cfg, params = small_model
    tc = TraceConfig(num_requests=24, arrival="bursty", burst_size=24,
                     prompt_len_lo=3, prompt_len_hi=6, max_new_lo=3,
                     max_new_hi=4, vocab_size=cfg.vocab_size, seed=4)
    trace = generate_trace(tc)
    paged = PagedServeEngine(cfg, params, decode_width=8, max_len=32,
                             block_size=4, num_blocks=16, prefill_chunk=4)
    pr = run_trace(paged, trace, max_ticks=20_000, strict=True)
    slot = ServeEngine(cfg, params, pool_size=2, max_len=32, prefill_chunk=4)
    sr = run_trace(slot, trace, max_ticks=20_000, strict=True)
    assert pr.completed == sr.completed == 24
    assert pr.max_inflight >= 4 * sr.max_inflight


# ---------------------------------------------------------- scheduler
def test_scheduler_alternates_without_slo():
    clock = iter(float(i) for i in range(1000))
    s = Scheduler(clock=lambda: next(clock))
    assert s.choose(0, 0) == IDLE_ACTION
    assert s.choose(1, 0) == PREFILL_ACTION
    assert s.choose(0, 1) == DECODE_ACTION
    # contested: strict alternation, deterministic in ticks
    seq = [s.choose(1, 1) for _ in range(4)]
    assert seq == [PREFILL_ACTION, DECODE_ACTION, PREFILL_ACTION,
                   DECODE_ACTION]


def test_scheduler_decode_slo_overrides_prefill():
    t = [0.0]
    s = Scheduler(SLOConfig(decode_slo_s=0.5), clock=lambda: t[0])
    assert s.choose(1, 1) == PREFILL_ACTION   # first contested pick
    t[0] = 0.1
    assert s.choose(1, 1) == DECODE_ACTION    # alternation
    t[0] = 1.0                                 # decode gap 0.9 > 0.5 SLO
    assert s.choose(1, 1) == DECODE_ACTION    # override, not alternation
    assert s.decode_overrides == 1


def test_scheduler_ttft_slo_overrides_decode():
    t = [0.0]
    s = Scheduler(SLOConfig(ttft_slo_s=1.0, safety=0.8), clock=lambda: t[0])
    s.observe_launch(PREFILL_ACTION, 0.2)
    assert s.choose(1, 1) == PREFILL_ACTION
    assert s.choose(1, 1) == DECODE_ACTION
    # oldest waited 0.7s + 2 chunks * 0.2s EMA = 1.1 > 0.8 * 1.0
    assert s.choose(1, 1, oldest_prefill_wait_s=0.7,
                    chunks_remaining=2) == PREFILL_ACTION
    assert s.ttft_overrides == 1


def test_slo_config_validation():
    with pytest.raises(ValueError, match="ttft_slo_s"):
        SLOConfig(ttft_slo_s=-1.0)
    with pytest.raises(ValueError, match="safety"):
        SLOConfig(safety=0.0)


# ----------------------------------------------------- traffic harness
def test_generate_trace_deterministic_and_sorted():
    tc = TraceConfig(num_requests=16, arrival="poisson", seed=7)
    a = generate_trace(tc)
    b = generate_trace(tc)
    assert [e.arrive_tick for e in a] == [e.arrive_tick for e in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b, strict=False))
    ticks = [e.arrive_tick for e in a]
    assert ticks == sorted(ticks)
    with pytest.raises(ValueError, match="arrival"):
        TraceConfig(arrival="adversarial")


def test_traffic_report_fields(small_model):
    cfg, params = small_model
    tc = TraceConfig(num_requests=8, arrival="poisson",
                     mean_interarrival_ticks=0.5, prompt_len_lo=2,
                     prompt_len_hi=5, max_new_lo=2, max_new_hi=3,
                     vocab_size=cfg.vocab_size, seed=5)
    eng = PagedServeEngine(cfg, params, decode_width=4, max_len=32,
                           block_size=4, prefill_chunk=4)
    rep = run_trace(eng, generate_trace(tc), max_ticks=5_000, strict=True)
    assert rep.completed == rep.total == 8
    assert rep.tokens_out > 0 and rep.tokens_per_s > 0
    assert rep.ttft_p50_ms <= rep.ttft_p99_ms
    assert rep.latency_p50_ms <= rep.latency_p99_ms
    assert 1 <= rep.max_inflight <= 4
    assert "done in" in rep.summary()


def test_paged_engine_stats_shape(small_model):
    cfg, params = small_model
    eng = PagedServeEngine(cfg, params, decode_width=2, max_len=32,
                           block_size=4, prefill_chunk=4)
    req = Request(rid=0, prompt=np.array([1, 2, 3]), max_new_tokens=3)
    eng.admit(req)
    eng.run_until_done()
    st = eng.stats()
    assert st["requests_completed"] == 1
    assert st["tokens_generated"] == 3
    assert st["kv_blocks"]["in_use"] == 0
    assert st["kv_blocks"]["freed_total"] == st["kv_blocks"]["allocated_total"]
    assert st["scheduler"]["admitted"] == 1
    assert st["max_inflight"] == 1
