"""Pass pipeline, fusion signatures, kernel cache, and the planned runtime."""
import jax.numpy as jnp
import numpy as np

from conftest import compile_and_compare, make_feeds as _feeds
from repro.core import (
    GraphBuilder,
    KernelCache,
    StitchOptions,
    compile_module,
    deep_fuse,
    fusion_signature,
    reference_execute,
    trace,
)
from repro.core import executor as executor_mod


# ------------------------------------------------------------- signatures
def _rmsnorm_module(shape=(8, 32), eps=1e-6, fn="rsqrt"):
    def f(b, x, g):
        ms = b.reduce(b.square(x), (1,), "mean")
        inv = b.unary(fn, ms + eps)
        return x * b.broadcast(inv, x.shape, (0,)) * b.broadcast(g, x.shape, (1,))

    return trace(f, ("x", shape, jnp.float32), ("g", (shape[1],), jnp.float32))


def _single_fusion(module):
    plan = deep_fuse(module)
    assert len(plan.fusions) == 1
    return plan.fusions[0]


def test_signature_equal_across_traces():
    """Two separately-traced copies (different instr ids/names) hash equal."""
    f1 = _single_fusion(_rmsnorm_module())
    f2 = _single_fusion(_rmsnorm_module())
    assert f1.members[0].id != f2.members[0].id
    assert fusion_signature(f1) == fusion_signature(f2)


def test_signature_differs_on_shape():
    f1 = _single_fusion(_rmsnorm_module(shape=(8, 32)))
    f2 = _single_fusion(_rmsnorm_module(shape=(8, 64)))
    assert fusion_signature(f1) != fusion_signature(f2)


def test_signature_differs_on_elementwise_fn():
    f1 = _single_fusion(_rmsnorm_module(fn="rsqrt"))
    f2 = _single_fusion(_rmsnorm_module(fn="sqrt"))
    assert fusion_signature(f1) != fusion_signature(f2)


def test_signature_differs_on_constant_value():
    """Attr payloads (here the folded eps constant) enter the hash: the
    value is baked into the emitted kernel body."""
    f1 = _single_fusion(_rmsnorm_module(eps=1e-6))
    f2 = _single_fusion(_rmsnorm_module(eps=1e-3))
    assert fusion_signature(f1) != fusion_signature(f2)


# ------------------------------------------------------------ kernel cache
def _stacked_module(n_layers):
    def f(b, x, *weights):
        gs, Ws = weights[:n_layers], weights[n_layers:]
        for g, W in zip(gs, Ws, strict=False):
            ms = b.reduce(b.square(x), (1,), "mean")
            inv = b.rsqrt(ms + 1e-6)
            normed = (
                x * b.broadcast(inv, x.shape, (0,)) * b.broadcast(g, x.shape, (1,))
            )
            h = b.dot(normed, W)  # library call: layer boundary
            x = x + b.tanh(h)
        return x

    specs = [("x", (8, 32), jnp.float32)]
    specs += [(f"g{i}", (32,), jnp.float32) for i in range(n_layers)]
    specs += [(f"W{i}", (32, 32), jnp.float32) for i in range(n_layers)]
    return trace(f, *specs)




def test_kernel_cache_hits_on_identical_blocks(rng):
    """N identical middle layers tune/emit once; outputs match the oracle."""
    m = _stacked_module(4)
    comp = compile_and_compare(m, _feeds(m, rng))
    s = comp.stats
    assert s.stitched_kernels > s.unique_kernels, "identical fusions must dedup"
    assert s.kernel_cache_hits >= 2          # the identical middle layers
    assert s.kernel_cache_hits + s.kernel_cache_misses == s.stitched_kernels
    assert sum(1 for r in s.reports if r.cached) == s.kernel_cache_hits
    # cached instances share the representative's signature
    by_sig = {}
    for r in s.reports:
        by_sig.setdefault(r.signature, []).append(r.cached)
    for sig, cached_flags in by_sig.items():
        assert cached_flags[0] is False      # first instance tuned it
        assert all(cached_flags[1:])         # the rest hit


def test_dedup_disabled_tunes_every_fusion(rng):
    m = _stacked_module(3)
    comp = compile_and_compare(m, _feeds(m, rng), dedup_kernels=False)
    s = comp.stats
    assert s.kernel_cache_hits == 0
    assert s.unique_kernels == s.stitched_kernels


def test_shared_cache_across_compiles(rng):
    """A shared KernelCache makes a recompile of the same graph all-hits."""
    cache = KernelCache()
    opts = StitchOptions(max_blocks=32)
    comp1 = compile_module(_stacked_module(3), opts, kernel_cache=cache)
    assert comp1.stats.kernels_emitted == comp1.stats.unique_kernels > 0
    comp2 = compile_module(_stacked_module(3), opts, kernel_cache=cache)
    assert comp2.stats.kernel_cache_hits == comp2.stats.stitched_kernels
    assert comp2.stats.kernel_cache_misses == 0
    assert comp2.stats.kernels_emitted == 0  # everything served from cache
    m = _stacked_module(3)
    ref = reference_execute(m, _feeds(m, rng))
    out = compile_module(m, opts, kernel_cache=cache)(_feeds(m, rng))
    assert set(out) == set(ref)


def test_kernel_cache_disk_roundtrip(tmp_path, rng):
    """Warm processes skip the tuning search via the persisted records."""
    path = str(tmp_path / "kernels.json")
    opts = StitchOptions(max_blocks=32, kernel_cache_path=path)
    compile_module(_stacked_module(3), opts)
    comp2 = compile_module(_stacked_module(3), opts)  # fresh cache, warm disk
    assert comp2.stats.tuning_disk_hits == comp2.stats.kernel_cache_misses > 0
    m = _stacked_module(3)
    feeds = _feeds(m, rng)
    out = compile_module(m, opts)(feeds)
    ref = reference_execute(m, feeds)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=2e-5
        )


def test_cache_not_shared_across_differing_options(rng):
    """A kernel tuned under one options regime must not serve another:
    cache keys are salted with the compile-options fingerprint."""
    cache = KernelCache()
    m = _stacked_module(2)
    compile_module(_stacked_module(2), StitchOptions(max_blocks=32),
                   kernel_cache=cache)
    comp2 = compile_module(_stacked_module(2), StitchOptions(max_blocks=8),
                           kernel_cache=cache)
    assert comp2.stats.kernel_cache_hits == 0  # different max_blocks regime
    feeds = _feeds(m, rng)
    out = compile_module(m, StitchOptions(max_blocks=8), kernel_cache=cache)(feeds)
    ref = reference_execute(m, feeds)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=2e-5
        )


def test_unfusable_representative_does_not_poison_hits(rng, monkeypatch):
    """If memory planning kills a fusion down to nothing, signature-sharing
    instances are demoted too (not bound to a kernel-less entry), and the
    dead entry leaves the cache so later compiles retune cleanly."""
    from repro.core import (
        CompilationState, FinalizePass, FusionPass, MemoryPass, SchedulePass,
    )
    from repro.core import pipeline as pipeline_mod
    from repro.core.memory import MemoryInfeasible
    from repro.core.perf_library import PerfLibrary

    m = _stacked_module(3)
    cache = KernelCache()
    opts = StitchOptions(max_blocks=32)
    feeds = _feeds(m, rng)
    ref = reference_execute(m, feeds)

    # run fusion + schedule normally: entries exist, middle layers hit
    state = CompilationState(
        module=m, options=opts, library=PerfLibrary(), kernel_cache=cache
    )
    FusionPass().run(state)
    SchedulePass().run(state)
    assert any(p.cache_hit for p in state.planned)
    assert len(cache) > 0

    # now make every memory plan infeasible: each representative shrinks to
    # nothing, its entry must die, and its hits must be demoted with it
    def always_infeasible(*a, **kw):
        raise MemoryInfeasible("forced by test")

    monkeypatch.setattr(pipeline_mod, "plan_memory", always_infeasible)
    MemoryPass().run(state)
    assert state.planned == [], "all planned fusions must be demoted"
    assert state.demoted, "demoted members must run standalone"
    assert len(cache) == 0, "dead entries must leave the cache"

    # the module still executes correctly, everything standalone
    FinalizePass().run(state)  # codegen has nothing to emit
    assert state.stats.stitched_kernels == 0
    assert state.stats.standalone_kernels > 0
    out = state.executable(feeds)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=2e-5
        )

    # with memory planning restored, the same cache compiles cleanly again
    monkeypatch.undo()
    comp2 = compile_module(m, opts, kernel_cache=cache)
    assert comp2.stats.stitched_kernels > 0
    out2 = comp2(feeds)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out2[k]), np.asarray(ref[k]), rtol=2e-5, atol=2e-5
        )


# --------------------------------------------------------- pass pipeline
def test_pass_times_cover_all_stages(rng):
    m = _stacked_module(2)
    comp = compile_and_compare(m, _feeds(m, rng))
    assert set(comp.stats.pass_times) == {
        "submodule", "sharding", "fusion", "schedule", "memory", "codegen",
        "autotune", "finalize", "verify",
    }
    assert comp.stats.compile_time_s > 0


# ------------------------------------------------------- planned runtime
def _const_chain_module():
    """A constant-like chain feeding a library dot: stays uncovered by any
    fusion, so the execution plan must fold it at compile time."""
    b = GraphBuilder("folded")
    x = b.parameter("x", (4, 8), jnp.float32)
    c = b.constant(np.arange(64.0, dtype=np.float32))
    w = b.reshape(c, (8, 8))
    _out = b.dot(x, w)  # non-fusable -> library call
    return b.module


def test_folded_constants_computed_once(rng, monkeypatch):
    m = _const_chain_module()
    comp = compile_module(m, StitchOptions(max_blocks=16))
    plan = comp.executable.execution_plan
    assert plan.fold_evals >= 2              # constant + reshape
    folds_after_compile = plan.fold_evals

    feeds = {"x": rng.randn(4, 8).astype("f4")}
    ref = reference_execute(m, feeds)

    seen_opcodes = []
    real_apply = executor_mod.apply_op

    def spy(instr, *vals, **kw):
        seen_opcodes.append(instr.opcode)
        return real_apply(instr, *vals, **kw)

    monkeypatch.setattr(executor_mod, "apply_op", spy)
    out1 = comp(feeds)
    out2 = comp(feeds)
    # calls never re-evaluate the folded chain — only the library dot runs
    assert set(seen_opcodes) <= {"dot"}
    assert plan.fold_evals == folds_after_compile
    for k in ref:
        np.testing.assert_allclose(np.asarray(out1[k]), np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(out2[k]), np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)


def test_buffer_table_releases_intermediates(rng):
    """Buffers are freed right after their last use; module outputs never."""
    m = _stacked_module(3)
    comp = compile_and_compare(m, _feeds(m, rng))
    plan = comp.executable.execution_plan
    released = [s for step in plan.steps for s in step.release]
    assert released, "a stacked graph must have releasable intermediates"
    assert len(released) == len(set(released)), "each slot released once"
    out_slots = {s for _, s in plan._root_binds}
    assert not (set(released) & out_slots)


def test_execution_plan_steps_prebound(rng):
    m = _stacked_module(2)
    comp = compile_and_compare(m, _feeds(m, rng))
    plan = comp.executable.execution_plan
    kernel_steps = [s for s in plan.steps if hasattr(s, "out_slots")]
    assert len(kernel_steps) == comp.stats.stitched_kernels
    for step in plan.steps:
        for s in step.arg_slots:
            assert 0 <= s < plan.num_slots
