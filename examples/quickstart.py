"""Quickstart: compile the paper's Figure-3 pattern with FusionStitching.

Builds softmax(QKᵀ/√d)·V in StitchIR, runs the full pipeline (Work/Span
deep fusion → schedule tuning → VMEM planning → stitched Pallas codegen),
validates against the pure-jnp oracle, and prints the paper's metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro.core import (  # noqa: E402
    StitchOptions,
    compile_module,
    critical_path_length,
    reference_execute,
    trace,
)


def attention(b, q, k, v):
    """The motivating example: BatchMatMul stitched with softmax."""
    kt = b.transpose(k, (0, 1, 3, 2))
    scores = b.dot(q, kt, fusable=True) * (1.0 / q.shape[-1] ** 0.5)
    p = b.softmax(scores, dim=-1)           # max, sub, exp, sum, div
    return b.dot(p, v, fusable=True)        # Dot.1 in Figure 3


def main():
    B, H, S, D = 2, 4, 16, 32
    module = trace(
        attention,
        ("q", (B, H, S, D), jnp.float32),
        ("k", (B, H, S, D), jnp.float32),
        ("v", (B, H, S, D), jnp.float32),
        name="fig3",
    )
    print(f"StitchIR module: {len(module.instructions)} instructions, "
          f"critical path {critical_path_length(module)}")

    compiled = compile_module(module, StitchOptions(max_blocks=32))
    s = compiled.stats
    print(f"stitched kernels : {s.stitched_kernels}")
    print(f"standalone       : {s.standalone_kernels}")
    print(f"XLA baseline     : {s.xla_baseline_kernels} kernels")
    print(f"fusion ratio     : {s.fusion_ratio:.3f}  "
          f"({(1 - s.fusion_ratio) * 100:.0f}% fewer launches)")
    for r in s.reports:
        print(f"  kernel {r.name}: {r.num_ops} ops, {r.blocks} blocks, "
              f"{r.scratch_bytes}B VMEM scratch "
              f"({r.shared_bytes}B shared), roots={r.roots}")

    rng = np.random.RandomState(0)
    feeds = {n: rng.randn(B, H, S, D).astype("f4") for n in ("q", "k", "v")}
    ref = reference_execute(module, feeds)
    out = compiled(feeds)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(ref[k]), rtol=2e-5, atol=2e-5
        )
    print("stitched kernels match the jnp oracle ✓")


if __name__ == "__main__":
    main()
