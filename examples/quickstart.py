"""Quickstart: compile the paper's Figure-3 pattern straight from jax.numpy.

``repro.stitch`` is a ``jax.jit``-shaped entry point: it captures a real
JAX function via jaxpr, lowers it into StitchIR, runs the full pipeline
(Work/Span deep fusion → schedule tuning → VMEM planning → stitched Pallas
codegen), and caches the compiled plan per input-shape signature.  No
hand-built IR anywhere.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro import StitchOptions, stitch  # noqa: E402


@stitch(options=StitchOptions(max_blocks=32))
def attention(q, k, v):
    """The motivating example: BatchMatMul stitched with softmax."""
    d = q.shape[-1]
    scores = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) * (1.0 / d ** 0.5)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)  # Figure 3:
    p = jnp.exp(scores)                                        # max, sub, exp,
    p = p / jnp.sum(p, axis=-1, keepdims=True)                 # sum, div
    return jnp.matmul(p, v)                                    # Dot.1


def main():
    B, H, S, D = 2, 4, 16, 32
    rng = np.random.RandomState(0)
    q, k, v = (jnp.asarray(rng.randn(B, H, S, D).astype("f4")) for _ in range(3))

    out = attention(q, k, v)              # traced + lowered + compiled + run
    s = attention.stats
    module = attention.lower()
    print(f"captured StitchIR : {len(module.instructions)} instructions "
          f"from the jaxpr of attention()")
    print(f"stitched kernels  : {s.stitched_kernels}")
    print(f"standalone        : {s.standalone_kernels}")
    print(f"XLA baseline      : {s.xla_baseline_kernels} kernels")
    print(f"fusion ratio      : {s.fusion_ratio:.3f}  "
          f"({(1 - s.fusion_ratio) * 100:.0f}% fewer launches)")
    for r in s.reports:
        print(f"  kernel {r.name}: {r.num_ops} ops, {r.blocks} blocks, "
              f"{r.scratch_bytes}B VMEM scratch "
              f"({r.shared_bytes}B shared), roots={r.roots}")

    # bit-validate against plain jax.jit of the SAME function
    ref = jax.jit(attention.__wrapped__)(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
    )
    print("stitched kernels match jax.jit of the same function ✓")

    # per-shape plan caching: a second same-shape call performs no recompile
    before = attention.num_compiles
    attention(q, k, v)
    assert attention.num_compiles == before, "same-shape call recompiled!"
    print(f"plan cache holds  : {attention.num_compiles} compile(s) "
          f"after a repeated call ✓")


if __name__ == "__main__":
    main()
