"""Fusion report: run the FusionStitching compiler over all six paper
benchmark graphs and print the per-workload plan (kernels, schedules,
VMEM scratch, sharing) — the compiler's explain-mode.

    PYTHONPATH=src python examples/fusion_report.py
"""
import sys

import jax

jax.config.update("jax_platform_name", "cpu")

sys.path.insert(0, ".")  # for benchmarks.*

from benchmarks.graphs import ALL_GRAPHS  # noqa: E402
from repro.core import StitchOptions, compile_module  # noqa: E402


def main():
    for name, build in ALL_GRAPHS.items():
        module = build()
        comp = compile_module(module, StitchOptions(max_blocks=64))
        s = comp.stats
        print(f"=== {name}: {len(module.instructions)} instrs -> "
              f"{s.stitched_kernels} stitched + {s.standalone_kernels} standalone "
              f"(+{s.library_calls} library) | XLA baseline {s.xla_baseline_kernels} "
              f"| ratio {s.fusion_ratio:.3f}")
        print(f"    kernel cache: {s.unique_kernels} unique kernels for "
              f"{s.stitched_kernels} fusions ({s.kernel_cache_hits} hits, "
              f"hit rate {s.cache_hit_rate:.0%}) | compile "
              f"{s.compile_time_s * 1e3:.1f}ms "
              + " ".join(f"{k}={v * 1e3:.1f}ms" for k, v in s.pass_times.items()))
        print(f"    verify[{s.verify_mode}]: {s.verify_boundaries} boundaries, "
              f"{s.verify_warnings} warnings, {s.verify_time_s * 1e3:.1f}ms")
        print(f"    planner[{s.planner_mode}]: {s.plans_explored} plans explored "
              f"({s.plans_rejected} infeasible), {s.planner_splits} splits, "
              f"{s.planner_merges} merges, {s.planner_packs} packs, "
              f"{s.planner_stitches} stitches | modeled "
              f"{s.planner_predicted_s * 1e6:.2f}us vs greedy "
              f"{s.greedy_predicted_s * 1e6:.2f}us | launches saved: "
              f"{s.launches_saved_vs_greedy} vs greedy, "
              f"{s.launches_saved_vs_unfused} vs unfused")
        if s.stitch_lowered_kernels:
            print(f"    stitched lowering: {s.stitch_lowered_kernels} kernels, "
                  f"{s.stitch_phases_total} phases, "
                  f"{s.stitch_interface_bytes}B staged interfaces")
        for r in s.reports:
            shared = f", {r.shared_bytes}B shared" if r.shared_bytes else ""
            shrunk = f", {r.num_shrinks} shrinks" if r.num_shrinks else ""
            cached = "  [cached]" if r.cached else ""
            phases = f"  phases={r.num_phases}" if r.num_phases > 1 else ""
            print(f"    {r.name}: {r.num_ops:3d} ops  blocks={r.blocks:<4d} "
                  f"scratch={r.scratch_bytes}B{shared}{shrunk}{phases}  "
                  f"roots={','.join(r.roots)}{cached}")


if __name__ == "__main__":
    main()
