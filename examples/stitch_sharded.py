"""Shard-aware compilation: one multi-device ExecutionPlan under shard_map.

A Megatron-style tensor-parallel MLP (column-parallel W1, row-parallel W2,
one ``lax.psum`` merging the partial block outputs) compiled through
``stitch(mesh=...)`` on an 8-device host-platform mesh:

  * the per-shard computation lowers to StitchIR with the psum as an
    ``all_reduce`` collective instruction — a deliberate schedule break the
    planner stitches compute around, never into a kernel;
  * the ShardingPass propagates layouts from the ``in_specs`` and salts
    every fusion signature, so per-shard kernels can never alias the
    full-shape kernels of the same function in the kernel cache;
  * the whole ExecutionPlan replays under ONE ``jax.jit(shard_map(...))`` —
    bit-identical to jitting the shard_map directly, with the same
    per-device kernel count as the single-device plan.

    PYTHONPATH=src python examples/stitch_sharded.py
"""
import os

# jax locks the device count on first init: set the flag before importing it
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

jax.config.update("jax_platform_name", "cpu")

from repro import StitchOptions, stitch  # noqa: E402
from repro.core.shard import wrap_shard_map  # noqa: E402

NUM_LAYERS = 4
B, D, F = 16, 64, 128


def mlp_stack(x, gains, w1s, w2s):
    """Pre-norm MLP blocks, written for ONE shard: each device holds a
    column slice of W1 and a row slice of W2, and the psum merges the
    per-device partial outputs back into the replicated residual stream."""
    for g, W1, W2 in zip(gains, w1s, w2s, strict=False):
        ms = jnp.mean(jnp.square(x), axis=1, keepdims=True)
        normed = x * jax.lax.rsqrt(ms + 1e-6) * g[None, :]
        y = jnp.matmul(jax.nn.silu(jnp.matmul(normed, W1)), W2)
        x = x + jax.lax.psum(y, "model")
    return x


def main():
    devices = jax.devices()
    assert len(devices) >= 8, "the XLA_FLAGS line above must run before jax init"
    mesh = Mesh(np.array(devices[:8]).reshape(8), ("model",))
    in_specs = (
        P(),                                 # x: replicated
        [P()] * NUM_LAYERS,                  # norm gains: replicated
        [P(None, "model")] * NUM_LAYERS,     # W1: column-parallel
        [P("model", None)] * NUM_LAYERS,     # W2: row-parallel
    )
    out_specs = P()

    sharded = stitch(
        mlp_stack,
        options=StitchOptions(max_blocks=64, fuse_dot=False),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )

    rng = np.random.RandomState(0)
    x = rng.randn(B, D).astype("f4")
    gains = [rng.randn(D).astype("f4") for _ in range(NUM_LAYERS)]
    w1s = [rng.randn(D, F).astype("f4") * 0.1 for _ in range(NUM_LAYERS)]
    w2s = [rng.randn(F, D).astype("f4") * 0.1 for _ in range(NUM_LAYERS)]

    out = sharded(x, gains, w1s, w2s)       # callers pass GLOBAL arrays

    oracle = jax.jit(wrap_shard_map(mlp_stack, mesh, in_specs, out_specs))(
        x, gains, w1s, w2s
    )
    assert bool(jnp.all(out == oracle)), "replay must be bit-identical"

    s = sharded.stats
    assert s.replay_mode == "sharded"
    assert s.collective_calls == NUM_LAYERS
    assert s.collective_breaks_spanned >= 1
    print(f"mesh            : 8x1 ({'x'.join(mesh.axis_names)}) host devices")
    print(f"kernels/device  : {s.stitched_kernels} stitched + "
          f"{s.standalone_kernels} standalone (+{s.library_calls} library)")
    print(f"collectives     : {s.collective_calls} all-reduce, "
          f"{s.collective_breaks_spanned} with stitched kernels on both "
          f"sides, {s.collective_time_s * 1e6:.1f}us modeled ICI time")
    print(f"sharded instrs  : {s.sharded_instrs} carrying a layout attr")
    print("oracle parity   : bit-identical to jax.jit(shard_map(fn)) ✓")


if __name__ == "__main__":
    main()
