"""Train an MLP with the WHOLE train step compiled as one stitched plan.

``make_stitched_train_step`` captures ``jax.value_and_grad`` of the loss
plus the AdamW update (clipping, cosine LR schedule, per-leaf elementwise
update towers) through ``repro.stitch`` — forward, backward and optimizer
fuse into one kernel plan with donated param/state buffers, bit-identical
to the ``jax.jit`` trainer.

    PYTHONPATH=src python examples/train_stitched.py
"""
import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro import StitchOptions  # noqa: E402
from repro.train import AdamWConfig, adamw_init, make_stitched_train_step  # noqa: E402
from repro.train.optimizer import adamw_update  # noqa: E402

BATCH, D_IN, D_H, D_OUT = 64, 16, 32, 8


def init_params(rng):
    return {
        "w1": jnp.asarray(rng.normal(size=(D_IN, D_H), scale=0.1), jnp.float32),
        "b1": jnp.zeros((D_H,), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(D_H, D_OUT), scale=0.1), jnp.float32),
        "b2": jnp.zeros((D_OUT,), jnp.float32),
    }


def loss_fn(params, batch):
    x, y = batch
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    pred = h @ params["w2"] + params["b2"]
    return jnp.mean((pred - y) ** 2)


def make_batch(rng):
    return (
        jnp.asarray(rng.normal(size=(BATCH, D_IN)), jnp.float32),
        jnp.asarray(rng.normal(size=(BATCH, D_OUT)), jnp.float32),
    )


def main():
    rng = np.random.default_rng(0)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)

    step = make_stitched_train_step(
        loss_fn, opt_cfg, options=StitchOptions(max_blocks=32)
    )

    # reference trainer on its own copies (the stitched step donates buffers)
    def ref_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    ref = jax.jit(ref_step)

    params = init_params(rng)
    p_st = jax.tree.map(jnp.copy, params)
    p_rf = jax.tree.map(jnp.copy, params)
    s_st, s_rf = adamw_init(p_st), adamw_init(p_rf)

    print("step  stitched-loss  jit-loss       lr        bit-identical")
    for i in range(20):
        batch = make_batch(rng)
        p_st, s_st, m_st = step(p_st, s_st, batch)
        p_rf, s_rf, m_rf = ref(p_rf, s_rf, batch)
        same = np.array_equal(np.asarray(m_st["loss"]), np.asarray(m_rf["loss"]))
        if i % 5 == 0 or i == 19:
            print(f"{i:4d}  {float(m_st['loss']):.6f}      "
                  f"{float(m_rf['loss']):.6f}  {float(m_st['lr']):.2e}  {same}")
        assert same, f"loss diverged from jax.jit at step {i}"

    print()
    print(step.report())
    s = step.stats
    assert step.num_fallbacks == 0
    print(f"\nwhole train step = ONE plan: {s.stitched_kernels} stitched kernels "
          f"vs {s.xla_baseline_kernels} XLA-baseline kernels, 0 fallbacks")


if __name__ == "__main__":
    main()
