"""End-to-end training driver: a few hundred steps on a small LM with the
full substrate — synthetic data pipeline, AdamW + cosine schedule, gradient
accumulation, checkpointing with auto-resume, straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch qwen1.5-0.5b]
"""
import argparse
import os
import tempfile

import jax

jax.config.update("jax_platform_name", "cpu")

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.configs import get_config, reduced_config  # noqa: E402
from repro.data import SyntheticLM  # noqa: E402
from repro.models import count_params, init_params  # noqa: E402
from repro.train import (  # noqa: E402
    AdamWConfig,
    Trainer,
    TrainerConfig,
    make_train_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = reduced_config(
        get_config(args.arch), num_layers=4, d_model=128, num_heads=4,
        head_dim=32, d_ff=384, vocab_size=1024,
    )
    params = init_params(cfg, seed=0)
    print(f"arch={cfg.name} (reduced) params={count_params(params):,}")

    ocfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                       schedule="cosine")
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                         keep_checkpoints=2)
    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "repro_ckpt")
    ckpt = CheckpointManager(ckpt_dir, keep=tcfg.keep_checkpoints)

    def data_factory(start_step):
        return SyntheticLM(cfg, args.seq, args.batch, seed=0).iterate(start_step)

    trainer = Trainer(
        cfg, ocfg, tcfg, data_factory, ckpt,
        train_step=jax.jit(
            make_train_step(cfg, ocfg, accum_steps=args.accum),
            donate_argnums=(0, 1),
        ),
    )
    params, _, step = trainer.run(params)

    losses = [h["loss"] for h in trainer.history]
    n = max(len(losses) // 10, 1)
    for i in range(0, len(losses), n):
        window = losses[i: i + n]
        print(f"step {i:4d}..{min(i + n, len(losses)):4d}: "
              f"loss {sum(window) / len(window):.4f}")
    stragglers = [h for h in trainer.history if h["straggler"]]
    print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"{len(stragglers)} straggler steps flagged; "
          f"checkpoints at {ckpt_dir}: steps {ckpt.available_steps()}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
