"""End-to-end serving driver: batched requests through the ServeEngine.

The paper's NMT use case — latency-critical online inference with small
batches — mapped onto our serving substrate: a small decoder LM with the
attention pattern the stitched kernels accelerate, continuous slot-based
batching, KV cache, greedy decode.

    PYTHONPATH=src python examples/serve_nmt.py
"""
import time

import numpy as np

import jax

jax.config.update("jax_platform_name", "cpu")

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402


def main():
    # small qwen-family decoder (the NMT-attention pattern)
    cfg = reduced_config(
        get_config("qwen1.5-0.5b"), num_layers=4, d_model=128,
        num_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    )
    params = init_params(cfg, seed=0)
    engine = ServeEngine(cfg, params, pool_size=4, max_len=128,
                         prefill_chunk=8)

    rng = np.random.RandomState(0)
    requests = [
        Request(rid=i, prompt=rng.randint(1, 500, size=rng.randint(4, 12)),
                max_new_tokens=12)
        for i in range(10)
    ]

    t0 = time.perf_counter()
    done = []
    ticks = 0
    # admit everything up front: overflow parks on the engine's FIFO wait
    # queue and is drained into freed slots at the start of each tick
    for r in requests:
        placed = engine.admit(r)
        print(f"[admit] request {r.rid} (prompt {len(r.prompt)} toks) "
              f"{'-> slot' if placed else '-> queued'}")
    while engine.wait_queue or any(r is not None for r in engine.slot_req):
        engine.tick()
        ticks += 1
        for r in requests:
            if r.done and r not in done:
                done.append(r)
                print(f"[done ] request {r.rid}: {r.out_tokens} "
                      f"(wait {1e3 * (r.queue_wait_s or 0):.0f}ms, "
                      f"ttft {1e3 * (r.ttft_s or 0):.0f}ms, "
                      f"{r.tokens_per_s or 0:.1f} tok/s)")
        if ticks > 500:
            break
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.out_tokens) for r in requests)
    st = engine.stats()
    print(f"\nserved {len(done)}/{len(requests)} requests, "
          f"{total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s on 1 CPU core, pool=4)")
    print(f"prefill launches: {st['prefill_launches']} for "
          f"{st['prefill_tokens']} prompt tokens "
          f"(per-token prefill would be {st['prefill_tokens']}); "
          f"decode launches: {st['decode_launches']}")
    assert len(done) == len(requests)


if __name__ == "__main__":
    main()
