"""End-to-end serving driver: continuous batching through the paged engine.

The paper's NMT use case — latency-critical online inference with small
batches — mapped onto our serving substrate: a small decoder LM with the
attention pattern the stitched kernels accelerate, continuous batching
over paged KV blocks, greedy decode.  Twenty requests share a KV pool
sized for far fewer worst-case contexts; the block allocator and the
prefill/decode scheduler keep them all moving at once, where the old
slot engine would cap concurrency at its pool size.

    PYTHONPATH=src python examples/serve_nmt.py
"""
import time

import numpy as np

import jax

jax.config.update("jax_platform_name", "cpu")

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serve import PagedServeEngine, Request  # noqa: E402


def main():
    # small qwen-family decoder (the NMT-attention pattern)
    cfg = reduced_config(
        get_config("qwen1.5-0.5b"), num_layers=4, d_model=128,
        num_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    )
    params = init_params(cfg, seed=0)
    # 64 blocks x 8 tokens = 512 KV tokens total — the old slot engine's
    # budget for FOUR max_len=128 slots now serves ~20 short requests
    engine = PagedServeEngine(
        cfg, params, decode_width=16, max_len=128, block_size=8,
        num_blocks=64, prefill_chunk=8,
    )

    rng = np.random.RandomState(0)
    requests = [
        Request(rid=i, prompt=rng.randint(1, 500, size=rng.randint(4, 12)),
                max_new_tokens=12)
        for i in range(20)
    ]

    t0 = time.perf_counter()
    done = []
    ticks = 0
    # admit everything up front: placements claim a decode row + KV blocks
    # immediately (prefill itself runs interleaved over the next ticks);
    # overflow parks on the FIFO wait queue and drains as blocks free up
    for r in requests:
        placed = engine.admit(r)
        print(f"[admit] request {r.rid} (prompt {len(r.prompt)} toks) "
              f"{'-> row' if placed else '-> queued'}")
    while engine.busy and ticks < 2000:
        engine.tick()
        ticks += 1
        for r in requests:
            if r.done and r not in done:
                done.append(r)
                print(f"[done ] request {r.rid}: {r.out_tokens} "
                      f"(wait {1e3 * (r.queue_wait_s or 0):.0f}ms, "
                      f"ttft {1e3 * (r.ttft_s or 0):.0f}ms, "
                      f"{r.tokens_per_s or 0:.1f} tok/s)")
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.out_tokens) for r in requests)
    st = engine.stats()
    kv = st["kv_blocks"]
    print(f"\nserved {len(done)}/{len(requests)} requests, "
          f"{total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s on 1 CPU core, "
          f"width=16, {kv['num_blocks']}x{kv['block_size']}-token blocks)")
    print(f"prefill launches: {st['prefill_launches']} for "
          f"{st['prefill_tokens']} prompt tokens; "
          f"decode launches: {st['decode_launches']}; "
          f"max in-flight: {st['max_inflight']} "
          f"(slot engine with this KV budget caps at 4); "
          f"kv peak {kv['peak_in_use']}/{kv['num_blocks']} blocks, "
          f"preemptions {st['preemptions']}")
    assert len(done) == len(requests)
    assert st["max_inflight"] > 4      # the continuous-batching win
    assert kv["in_use"] == 0           # every block returned


if __name__ == "__main__":
    main()
