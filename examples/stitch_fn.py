"""repro.stitch on real jax.numpy functions — the frontend tour.

Four pure-jnp functions (attention, RMSNorm, a gated MLP, a masked softmax)
compiled end-to-end through the stitching pipeline, each validated against
``jax.jit`` of the same function; plus the three things the frontend
guarantees:

  * parity — the captured plan reproduces the hand-built StitchIR plan
    (same kernel counts on the ported NMT benchmark graph);
  * per-shape plan caching — a second same-shape call performs no
    recompile, a new shape recompiles at most once;
  * graceful partial coverage — unsupported primitives raise a named
    ``UnsupportedPrimitiveError``, or fall back to plain ``jax.jit`` with
    ``on_unsupported="fallback"``.

    PYTHONPATH=src python examples/stitch_fn.py
"""
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro import (  # noqa: E402
    StitchOptions,
    UnsupportedPrimitiveError,
    compile_module,
    stitch,
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from graphs import JNP_FAMILIES, nmt_args  # noqa: E402

OPTS = StitchOptions(max_blocks=64)


# -- four pure-jnp workloads ------------------------------------------------

def attention(q, k, v):
    d = q.shape[-1]
    s = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) * (1.0 / d ** 0.5)
    return jnp.matmul(jax.nn.softmax(s, axis=-1), v)


def rmsnorm(x, g):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + 1e-6) * g


def gated_mlp(x, w_gate, w_up):
    return jax.nn.silu(jnp.matmul(x, w_gate)) * jnp.matmul(x, w_up)


def masked_softmax(x, mask):
    z = jnp.where(mask, x, -1e9)
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def check(name, fn, *args):
    stitched = stitch(fn, options=OPTS)
    out = stitched(*args)
    ref = jax.jit(fn)(*args)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
    )
    s = stitched.stats
    print(f"{name:16s}: {s.stitched_kernels} stitched + "
          f"{s.standalone_kernels} standalone kernels "
          f"(+{s.library_calls} library), XLA baseline "
          f"{s.xla_baseline_kernels} — matches jax.jit ✓")
    return stitched


def main():
    rng = np.random.RandomState(0)
    B, H, S, D = 2, 4, 16, 32
    q, k, v = (rng.randn(B, H, S, D).astype("f4") for _ in range(3))
    x = rng.randn(16, 64).astype("f4")
    g = rng.randn(64).astype("f4")
    w1, w2 = (rng.randn(64, 128).astype("f4") for _ in range(2))
    mask = rng.rand(16, 64) > 0.3

    check("attention", attention, q, k, v)
    check("rmsnorm", rmsnorm, x, g)
    check("gated_mlp", gated_mlp, x, w1, w2)
    sm = check("masked_softmax", masked_softmax, x, mask)

    # -- per-shape plan caching --------------------------------------------
    n0 = sm.num_compiles
    sm(x, mask)                                    # same shapes: cache hit
    assert sm.num_compiles == n0
    sm(x[:8], mask[:8])                            # new shape: one recompile
    assert sm.num_compiles == n0 + 1
    sm(x[:8], mask[:8])
    assert sm.num_compiles == n0 + 1
    print(f"plan cache      : {sm.num_compiles} compiles across "
          f"{len(sm._plans)} shape signatures ✓")

    # -- parity with the hand-built StitchIR path --------------------------
    fam = JNP_FAMILIES["NMT"]
    hand = compile_module(fam["module"](), OPTS)
    front = stitch(fam["fn"], options=OPTS)
    front(*nmt_args(rng))
    hk = hand.stats.stitched_kernels + hand.stats.standalone_kernels
    fk = front.stats.stitched_kernels + front.stats.standalone_kernels
    assert hk == fk, f"frontend {fk} kernels vs hand-built {hk}"
    print(f"NMT parity      : frontend plan == hand-built plan "
          f"({fk} kernel{'s' if fk != 1 else ''}) ✓")

    # -- unsupported primitives --------------------------------------------
    try:
        stitch(lambda t: jnp.cumsum(t, axis=-1))(x)
        raise AssertionError("expected UnsupportedPrimitiveError")
    except UnsupportedPrimitiveError as e:
        print(f"unsupported     : named error for '{e.primitive}' ✓")
    fb = stitch(
        lambda t: jnp.cumsum(t, axis=-1) + 1.0, on_unsupported="fallback"
    )
    np.testing.assert_allclose(
        np.asarray(fb(x)), np.cumsum(x, axis=-1) + 1.0, rtol=1e-5, atol=1e-5
    )
    print(f"fallback        : {fb.num_fallbacks} signature(s) via plain "
          f"jax.jit ✓")

    print()
    print(sm.report())


if __name__ == "__main__":
    main()
