"""Benchmark-regression gate for CI.

Diffs a fresh ``bench.json`` (written by ``python -m benchmarks.run
--json-out``) against the committed ``benchmarks/baseline.json``:

  * **hard failures** (exit 1) on kernel-count / launch regressions — the
    planner emitting MORE kernels than the baseline on any graph
    (``planner/*/kernels`` ``cost=N``), a worse fusion ratio
    (``fusion_ratio/*``), a stitched launch count creeping up
    (``stitch/*/launch_reduction`` ``stitched=N``), the jaxpr frontend
    emitting more kernels than its hand-built parity plan
    (``frontend/*/kernels`` ``stitched=N``), a sharded plan launching more
    per-device kernels than baseline or than its own single-device plan, or
    losing bitwise parity with the shard_map oracle, or losing its stitched
    phases around the all-reduce (``sharded/*``), a chunked-prefill
    decode-launch count creeping back toward the per-token O(S) loop
    (``serve_runtime/prefill_launches`` ``chunked=N``), the traced
    ExecutionPlan replay dispatching more segments per call
    (``serve_runtime/*`` ``traced=N``), or the paged serving engine losing
    ground on the traffic gate (``serve_traffic*``: max in-flight or
    completed count below baseline, tokens/s down or p99 TTFT up past
    ``--serve-tolerance``, the paged-vs-slot concurrency ratio under 4x,
    or an incomplete trace replay — the last two checked within the fresh
    row itself, so a blind baseline regen cannot bake them in);
  * **warnings** (exit 0) when modeled latency (``planner/*/predicted_us``)
    drifts past the tolerance (default ±15%), or when the analytic model's
    measured error (``autotune/*/model_error_pct``) drifts past
    ``--error-tolerance-pct`` (default ±25 percentage points).

Every hard failure names the offending row, the graph, the metric that
tripped, and both raw ``derived`` strings — no JSON diffing needed.

Rows only present in the baseline are skipped (CI's fast lane runs a bench
subset); rows only present in the fresh run are reported as new.

    python -m benchmarks.compare benchmarks/baseline.json bench.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Optional, Tuple


def load_rows(path: str) -> Dict[str, dict]:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}

def _derived_int(row: dict, key: str) -> Optional[int]:
    m = re.search(rf"\b{key}=(\d+)", str(row.get("derived", "")))
    return int(m.group(1)) if m else None


def _derived_float(row: dict) -> Optional[float]:
    try:
        return float(row["derived"])
    except (KeyError, TypeError, ValueError):
        return None


def _derived_num(row: dict, key: str) -> Optional[float]:
    """``key=<number>`` with a float value (``ratio=4.2``, ``p99=13.07``)."""
    m = re.search(rf"\b{key}=(-?\d+(?:\.\d+)?)", str(row.get("derived", "")))
    return float(m.group(1)) if m else None


def _graph_of(name: str) -> str:
    """The graph segment of a row name (``planner/NMT/kernels`` -> NMT)."""
    parts = name.split("/")
    return parts[1] if len(parts) > 1 else parts[0]


def _fail_msg(
    name: str, metric: str, what: str, base: dict, cur: dict
) -> str:
    """One self-diagnosing hard-fail line: graph, metric, what moved, and
    both raw derived strings so nobody has to diff JSON by hand."""
    return (
        f"{name} [graph={_graph_of(name)} metric={metric}]: {what}\n"
        f"      baseline derived={base.get('derived')!r}\n"
        f"      fresh    derived={cur.get('derived')!r}"
    )


def compare(
    baseline: Dict[str, dict],
    fresh: Dict[str, dict],
    latency_tolerance: float = 0.15,
    error_tolerance_pct: float = 25.0,
    serve_tolerance: float = 0.5,
) -> Tuple[List[str], List[str], List[str]]:
    """Returns (hard_failures, warnings, notes)."""
    failures: List[str] = []
    warnings: List[str] = []
    notes: List[str] = []

    for name, base in sorted(baseline.items()):
        cur = fresh.get(name)
        if cur is None:
            continue                      # fast lane runs a bench subset

        if name.startswith("planner/") and name.endswith("/kernels"):
            b, f = _derived_int(base, "cost"), _derived_int(cur, "cost")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "cost",
                    f"planner kernel count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name.startswith("fusion_ratio/"):
            b, f = _derived_float(base), _derived_float(cur)
            if b is not None and f is not None and f > b + 1e-9:
                failures.append(_fail_msg(
                    name, "fusion_ratio",
                    f"fusion ratio regressed {b} -> {f}",
                    base, cur,
                ))

        elif name.startswith("stitch/") and name.endswith("/launch_reduction"):
            b = _derived_int(base, "stitched")
            f = _derived_int(cur, "stitched")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "stitched",
                    f"stitched launch count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name.startswith("frontend/") and name.endswith("/kernels"):
            b = _derived_int(base, "stitched")
            f = _derived_int(cur, "stitched")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "stitched",
                    f"frontend kernel count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name.startswith("sharded/") and name.endswith("/kernels"):
            b = _derived_int(base, "perdev")
            f = _derived_int(cur, "perdev")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "perdev",
                    f"per-device kernel count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name == "train_step/kernels":
            b = _derived_int(base, "stitched")
            f = _derived_int(cur, "stitched")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "stitched",
                    f"stitched train-step kernel count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name == "control_flow/decode_loop/replay":
            b = _derived_int(base, "traced")
            f = _derived_int(cur, "traced")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "traced",
                    f"decode-loop traced dispatch count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name == "serve_runtime/prefill_launches":
            b = _derived_int(base, "chunked")
            f = _derived_int(cur, "chunked")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "chunked",
                    f"chunked prefill launch count regressed {b} -> {f} "
                    f"(toward the per-token O(S) loop)",
                    base, cur,
                ))

        elif name.startswith("serve_runtime/") and (
            name.endswith("/replay") or name.endswith("/replay_dispatches")
        ):
            b = _derived_int(base, "traced")
            f = _derived_int(cur, "traced")
            if b is not None and f is not None and f > b:
                failures.append(_fail_msg(
                    name, "traced",
                    f"traced replay dispatch count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name.startswith("serve_traffic") and name.endswith("/inflight"):
            b = _derived_int(base, "paged")
            f = _derived_int(cur, "paged")
            if b is not None and f is not None and f < b:
                failures.append(_fail_msg(
                    name, "paged",
                    f"paged max in-flight regressed {b} -> {f}",
                    base, cur,
                ))

        elif name.startswith("serve_traffic") and name.endswith("/completed"):
            b = _derived_int(base, "paged")
            f = _derived_int(cur, "paged")
            if b is not None and f is not None and f < b:
                failures.append(_fail_msg(
                    name, "paged",
                    f"paged completed-request count regressed {b} -> {f}",
                    base, cur,
                ))

        elif name.startswith("serve_traffic") and name.endswith("/tokens_per_s"):
            b = _derived_num(base, "paged")
            f = _derived_num(cur, "paged")
            if (
                b is not None and f is not None
                and f < b * (1 - serve_tolerance)
            ):
                failures.append(_fail_msg(
                    name, "paged",
                    f"paged throughput regressed {b:.0f} -> {f:.0f} tok/s "
                    f"(> {serve_tolerance:.0%} below baseline)",
                    base, cur,
                ))

        elif name.startswith("serve_traffic") and name.endswith("/ttft_ms"):
            b = _derived_num(base, "p99")
            f = _derived_num(cur, "p99")
            if (
                b is not None and f is not None
                and f > b * (1 + serve_tolerance)
            ):
                failures.append(_fail_msg(
                    name, "p99",
                    f"paged p99 TTFT regressed {b:.2f} -> {f:.2f} ms "
                    f"(> {serve_tolerance:.0%} above baseline)",
                    base, cur,
                ))

        elif name.startswith("planner/") and name.endswith("/predicted_us"):
            b, f = base.get("us_per_call"), cur.get("us_per_call")
            if b and f and abs(f - b) > latency_tolerance * abs(b):
                warnings.append(
                    f"{name}: modeled latency drifted "
                    f"{b:.2f}us -> {f:.2f}us (> {latency_tolerance:.0%})"
                )

        elif name.startswith("autotune/") and name.endswith("/model_error_pct"):
            b, f = _derived_float(base), _derived_float(cur)
            if b is not None and f is not None and abs(f - b) > error_tolerance_pct:
                trend = "worsened" if f > b else "improved"
                warnings.append(
                    f"{name}: model-vs-measured error {trend} "
                    f"{b:.1f}% -> {f:.1f}% (drift > "
                    f"{error_tolerance_pct:.0f} points; if real, the "
                    f"LatencyModel constants deserve a look)"
                )

    # frontend parity is also checked WITHIN each fresh row (hand= is the
    # ground truth the row carries), independent of the baseline — a blind
    # baseline regen can never bake in a lowering drift from the hand plan
    for name, cur in sorted(fresh.items()):
        if name.startswith("frontend/") and name.endswith("/kernels"):
            fh = _derived_int(cur, "hand")
            fs = _derived_int(cur, "stitched")
            if fh is not None and fs is not None and fs > fh:
                failures.append(_fail_msg(
                    name, "hand/stitched",
                    f"jaxpr frontend emits {fs} kernels vs the hand-built "
                    f"plan's {fh} (lowering drifted from parity)",
                    cur, cur,
                ))

    # sharded-compilation invariants (the shard-aware acceptance criteria)
    # are checked WITHIN each fresh row, independent of the baseline: the
    # per-device plan must never launch more kernels than the single-device
    # plan of the same computation (the single= value the row itself
    # carries), the replay must stay bit-identical to the shard_map oracle,
    # and at least one all-reduce must keep stitched kernels on both sides
    for name, cur in sorted(fresh.items()):
        if name.startswith("sharded/") and name.endswith("/kernels"):
            fp = _derived_int(cur, "perdev")
            fs = _derived_int(cur, "single")
            if fp is not None and fs is not None and fp > fs:
                failures.append(_fail_msg(
                    name, "perdev/single",
                    f"sharded plan launches {fp} kernels per device vs the "
                    f"single-device plan's {fs} — sharding must never cost "
                    f"extra launches",
                    cur, cur,
                ))
            br = _derived_int(cur, "breaks")
            if br is not None and br < 1:
                failures.append(_fail_msg(
                    name, "breaks",
                    "no all-reduce break has stitched kernels on both sides "
                    "— compute stopped stitching around the collective",
                    cur, cur,
                ))
        elif name.startswith("sharded/") and name.endswith("/parity"):
            bw = _derived_int(cur, "bitwise")
            if bw is not None and bw != 1:
                failures.append(_fail_msg(
                    name, "bitwise",
                    "sharded replay is not bit-identical to the "
                    "jax.jit-under-shard_map oracle",
                    cur, cur,
                ))

    # control-flow/grad capture invariants (ISSUE 8 acceptance) are checked
    # WITHIN each fresh row, independent of the baseline: zero fallbacks,
    # fewer launches than unfused, bitwise loss parity, and a traced replay
    # that beats the eager per-step loop are the contract, not drift
    for name, cur in sorted(fresh.items()):
        if name == "train_step/kernels":
            fb = _derived_int(cur, "fallbacks")
            if fb is not None and fb > 0:
                failures.append(_fail_msg(
                    name, "fallbacks",
                    f"train step fell back to plain jax.jit {fb} time(s) — "
                    f"forward+backward+optimizer must compile as one plan",
                    cur, cur,
                ))
            fs = _derived_int(cur, "stitched")
            fu = _derived_int(cur, "unfused")
            if fs is not None and fu is not None and fs >= fu:
                failures.append(_fail_msg(
                    name, "stitched/unfused",
                    f"stitched train step launches {fs} kernels, not fewer "
                    f"than the unfused baseline's {fu}",
                    cur, cur,
                ))
        elif name == "train_step/loss_parity":
            bw = _derived_int(cur, "bitwise")
            if bw is not None and bw != 1:
                failures.append(_fail_msg(
                    name, "bitwise",
                    "stitched train-step loss trajectory is not bit-identical "
                    "to jax.jit",
                    cur, cur,
                ))
        elif name == "control_flow/decode_loop/replay":
            fb = _derived_int(cur, "fallbacks")
            if fb is not None and fb > 0:
                failures.append(_fail_msg(
                    name, "fallbacks",
                    f"scan decode loop fell back to plain jax.jit {fb} time(s)",
                    cur, cur,
                ))
            ft = _derived_int(cur, "traced")
            fe = _derived_int(cur, "eager")
            if ft is not None and fe is not None and ft >= fe:
                failures.append(_fail_msg(
                    name, "traced/eager",
                    f"traced replay dispatches {ft} per call, not fewer than "
                    f"the eager loop's {fe}",
                    cur, cur,
                ))
            pa = _derived_int(cur, "parity")
            if pa is not None and pa != 1:
                failures.append(_fail_msg(
                    name, "parity",
                    "decode-loop output is not bit-identical to jax.jit",
                    cur, cur,
                ))

    # serve-traffic invariants are also checked WITHIN each fresh row,
    # independent of the baseline: the >= 4x concurrency claim and full
    # trace completion are acceptance criteria, not relative drift — a
    # blind baseline regen can never bake in a regression of either
    for name, cur in sorted(fresh.items()):
        if name.startswith("serve_traffic/") and name.endswith("/inflight"):
            ratio = _derived_num(cur, "ratio")
            if ratio is not None and ratio < 4.0:
                failures.append(_fail_msg(
                    name, "ratio",
                    f"paged-vs-slot concurrency ratio {ratio:.1f} below the "
                    f"4x gate (same KV budget)",
                    cur, cur,
                ))
        if name.startswith("serve_traffic") and name.endswith("/completed"):
            done = _derived_int(cur, "paged")
            total = _derived_int(cur, "total")
            if done is not None and total is not None and done < total:
                failures.append(_fail_msg(
                    name, "paged/total",
                    f"paged engine finished only {done} of {total} trace "
                    f"requests (liveness violation or truncated replay)",
                    cur, cur,
                ))

    for name in sorted(set(fresh) - set(baseline)):
        notes.append(f"{name}: new row (not in baseline)")
    return failures, warnings, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed benchmarks/baseline.json")
    ap.add_argument("fresh", help="bench.json from this run")
    ap.add_argument(
        "--latency-tolerance",
        type=float,
        default=0.15,
        help="relative modeled-latency drift that triggers a warning",
    )
    ap.add_argument(
        "--error-tolerance-pct",
        type=float,
        default=25.0,
        help="model-vs-measured error drift (percentage points, "
        "autotune/*/model_error_pct) that triggers a warning",
    )
    ap.add_argument(
        "--serve-tolerance",
        type=float,
        default=0.5,
        help="relative wall-clock drift on serve_traffic rows (tokens/s "
        "down or p99 TTFT up) that triggers a hard failure — generous by "
        "default because shared CI runners are noisy",
    )
    args = ap.parse_args(argv)
    failures, warnings, notes = compare(
        load_rows(args.baseline),
        load_rows(args.fresh),
        args.latency_tolerance,
        args.error_tolerance_pct,
        args.serve_tolerance,
    )
    for n in notes:
        print(f"NOTE  {n}")
    for w in warnings:
        print(f"WARN  {w}")
    for f in failures:
        print(f"FAIL  {f}")
    if failures:
        print(f"{len(failures)} benchmark regression(s) vs baseline")
        return 1
    print(f"benchmark gate OK ({len(warnings)} warning(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
