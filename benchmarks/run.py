"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the paper's headline
metric for that table: fusion ratio, speedup, shared-memory bytes, ...).

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
from dataclasses import replace

# The sharded rows need a real multi-device mesh; jax locks the device count
# on first init, so the flag must be set before `import jax` (the same idiom
# as launch/dryrun.py and tests/conftest.py).
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro.core import (  # noqa: E402
    CostModel,
    PerfLibrary,
    StitchOptions,
    compile_module,
    reference_execute,
)
from repro.core.xla_baseline import xla_baseline_groups  # noqa: E402
from repro.core.schedule import REPLICATED  # noqa: E402

from .graphs import ALL_GRAPHS, random_feeds as _feeds  # noqa: E402

OPTS = StitchOptions(max_blocks=64)


_CACHE = None


def compiled_all():
    global _CACHE
    if _CACHE is None:
        lib = PerfLibrary()
        _CACHE = {
            name: (fn(), None, lib) for name, fn in ALL_GRAPHS.items()
        }
        for name, (module, _, lib) in list(_CACHE.items()):
            _CACHE[name] = (module, compile_module(module, OPTS), lib)
    return _CACHE


def _baseline_predicted_time(module, lib: PerfLibrary) -> float:
    """Predicted time of the XLA-like baseline: one launch per kernel group,
    per-op times from the same performance library (paper's methodology)."""
    model = lib.model
    total = 0.0
    for root_id, members in xla_baseline_groups(module).items():
        if any(m.is_library_call for m in members):
            continue
        op_time = sum(model.op_time(m, REPLICATED, 1) for m in members)
        total += model.kernel_time(1, op_time)
    return total


def _library_time(module, lib: PerfLibrary) -> float:
    model = lib.model
    return sum(
        model.kernel_time(1, model.op_time(i, REPLICATED, 1))
        for i in module.instructions
        if i.is_library_call
    )  # identical for baseline and stitched builds


def bench_fusion_ratio():
    """Fig. 7 — kernels(FusionStitching) / kernels(XLA baseline)."""
    rows = []
    ratios = []
    for name, (module, comp, lib) in compiled_all().items():
        ratio = comp.stats.fusion_ratio
        ratios.append(max(ratio, 1e-9))
        rows.append((f"fusion_ratio/{name}", 0.0, round(ratio, 3)))
    geo = float(np.exp(np.mean(np.log(ratios))))
    rows.append(("fusion_ratio/geomean", 0.0, round(geo, 3)))
    rows.append(("fusion_ratio/launch_reduction_pct", 0.0, round((1 - geo) * 100, 1)))
    return rows


def bench_speedup():
    """Fig. 8 — FusionSpeedup on the fusable portion (perf-library
    predicted, both sides through the same cost model) + predicted E2E via
    the paper's formula 1 + FusableRatio*(1 - 1/FusionSpeedup)."""
    rows = []
    speedups = []
    for name, (module, comp, lib) in compiled_all().items():
        base_t = _baseline_predicted_time(module, lib)
        ours_t = comp.stats.predicted_time_s
        lc_t = _library_time(module, lib)
        speedup = base_t / max(ours_t, 1e-12)
        speedups.append(speedup)
        fusable_ratio = base_t / max(base_t + lc_t, 1e-12)
        e2e_pred = 1 + fusable_ratio * (1 - 1 / max(speedup, 1e-9))
        rows.append((f"speedup/{name}/fusable", ours_t * 1e6, round(speedup, 2)))
        rows.append((f"speedup/{name}/pred_e2e", 0.0, round(e2e_pred, 2)))
    geo = float(np.exp(np.mean(np.log(speedups))))
    rows.append(("speedup/geomean_fusable", 0.0, round(geo, 2)))
    return rows


def bench_dispatch_wall_time():
    """CPU-measurable proxy for launch-overhead reduction: op-by-op eager
    dispatch (one XLA call per instruction) vs the whole graph in one jit."""
    rows = []
    rng = np.random.RandomState(0)
    for name, (module, comp, lib) in compiled_all().items():
        feeds = _feeds(module, rng)

        jitted = jax.jit(functools.partial(reference_execute, module))
        out = jitted(feeds)  # warm
        jax.block_until_ready(list(out.values()))
        t0 = time.perf_counter()
        for _ in range(5):
            out = reference_execute(module, feeds)   # eager: 1 dispatch/op
            jax.block_until_ready(list(out.values()))
        t_per_op = (time.perf_counter() - t0) / 5
        t0 = time.perf_counter()
        for _ in range(20):
            out = jitted(feeds)
            jax.block_until_ready(list(out.values()))
        t_fused = (time.perf_counter() - t0) / 20
        rows.append(
            (f"dispatch/{name}", t_fused * 1e6, round(t_per_op / t_fused, 2))
        )
    return rows


def bench_smem_stats():
    """Table 3 — VMEM scratch: average, max, #shrinks, shared ratio."""
    rows = []
    for name, (module, comp, lib) in compiled_all().items():
        s = comp.stats
        rows.append((f"smem/{name}/avg_bytes", 0.0, int(s.smem_average)))
        rows.append((f"smem/{name}/max_bytes", 0.0, int(s.smem_max)))
        rows.append((f"smem/{name}/shrinks", 0.0, s.total_shrinks))
        rows.append((f"smem/{name}/shared_ratio", 0.0, round(s.shared_ratio, 3)))
    return rows


def bench_breakdown():
    """Fig. 6 — execution-time breakdown: library MatMul vs fusable portion."""
    rows = []
    for name, (module, comp, lib) in compiled_all().items():
        lc_t = _library_time(module, lib)
        fus_t = comp.stats.predicted_time_s
        frac = fus_t / max(fus_t + lc_t, 1e-12)
        rows.append((f"breakdown/{name}/fusable_pct", 0.0, round(frac * 100, 1)))
    return rows


def bench_footprint():
    """Fig. 1 — op memory-footprint distribution (floats, log2 quantiles)."""
    from collections import defaultdict

    by_kind = defaultdict(list)
    for name, (module, comp, lib) in compiled_all().items():
        for i in module.instructions:
            if i.opcode in ("parameter", "constant"):
                continue
            kind = "reduce" if i.opcode == "reduce" else (
                i.attrs.get("fn", i.opcode) if i.opcode == "elementwise" else i.opcode
            )
            by_kind[kind].append(max(i.footprint_bytes() / 4, 1))
    rows = []
    for kind, vals in sorted(by_kind.items()):
        v = np.asarray(vals, dtype=float)
        rows.append(
            (f"footprint/{kind}", 0.0,
             f"n={len(v)} p50=2^{np.log2(np.median(v)):.1f} "
             f"p90=2^{np.log2(np.percentile(v, 90)):.1f}")
        )
    return rows


def bench_compile_cache():
    """Kernel-dedup + pipeline accounting: cache hit-rate, unique kernels,
    compile time cold vs warm (shared KernelCache across compiles), and the
    per-pass time breakdown on the repeated-layer workload."""
    from repro.core import KernelCache
    from .graphs import stacked_transformer_graph

    rows = []
    for name, (module, comp, lib) in compiled_all().items():
        s = comp.stats
        rows.append((f"compile/{name}/time", s.compile_time_s * 1e6,
                     f"hit_rate={s.cache_hit_rate:.2f} "
                     f"unique={s.unique_kernels}/{s.stitched_kernels}"))

    cache = KernelCache()
    module = stacked_transformer_graph(num_layers=8)
    t0 = time.perf_counter()
    cold = compile_module(module, OPTS, kernel_cache=cache)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = compile_module(stacked_transformer_graph(num_layers=8), OPTS,
                          kernel_cache=cache)
    t_warm = time.perf_counter() - t0
    s = cold.stats
    rows.append(("compile/stacked8/cold", t_cold * 1e6,
                 f"hit_rate={s.cache_hit_rate:.2f} "
                 f"unique={s.unique_kernels}/{s.stitched_kernels}"))
    rows.append(("compile/stacked8/warm", t_warm * 1e6,
                 f"hit_rate={warm.stats.cache_hit_rate:.2f} "
                 f"speedup={t_cold / max(t_warm, 1e-9):.2f}x"))
    for pname, pt in s.pass_times.items():
        rows.append((f"compile/stacked8/pass/{pname}", pt * 1e6, ""))
    return rows


def bench_fusion_planner():
    """Greedy Algorithm 1 vs the cost-guided planner: kernel launches and
    LatencyModel-predicted µs per graph, plus predicted-vs-counted launch
    reduction.  ReduceTowers and BcastHeavy are the adversarial graphs where
    greedy's per-seed commit misses the horizontal merges."""
    rows = []
    for name, fn in ALL_GRAPHS.items():
        module = fn()
        greedy = compile_module(module, replace(OPTS, planner="greedy"))
        cost = compile_module(module, replace(OPTS, planner="cost"))
        gk = greedy.stats.stitched_kernels + greedy.stats.standalone_kernels
        ck = cost.stats.stitched_kernels + cost.stats.standalone_kernels
        s = cost.stats
        rows.append(
            (f"planner/{name}/kernels", 0.0,
             f"greedy={gk} cost={ck} explored={s.plans_explored} "
             f"rejected={s.plans_rejected} merges={s.planner_merges} "
             f"splits={s.planner_splits}")
        )
        rows.append(
            (f"planner/{name}/predicted_us", s.planner_predicted_s * 1e6,
             f"greedy_us={s.greedy_predicted_s * 1e6:.2f}")
        )
        # predicted reduction is the fusion pass's pre-demotion view
        # (planner_kernels); counted is what the final executable actually
        # launches — they diverge when MemoryPass demotes members.  Both
        # compare against the in-compile floor plan, NOT the separate
        # planner="greedy" compile of the kernels row: on stitched graphs
        # the floor already grows across schedule breaks, so the kernels
        # row is the paper-exact comparison
        rows.append(
            (f"planner/{name}/launch_reduction", 0.0,
             f"predicted={s.greedy_kernels - s.planner_kernels} "
             f"counted={s.launches_saved_vs_greedy} "
             f"vs_unfused={s.launches_saved_vs_unfused}")
        )
    return rows


def bench_stitched_kernels():
    """Interpret-mode wall time + max error of the hand-tuned Pallas kernels
    vs their jnp oracles (correctness-grade numbers, not TPU perf)."""
    from repro.kernels import ops
    from repro.kernels.ref import rmsnorm_ref, softmax_ref

    rows = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(64, 256).astype("f4"))
    g = jnp.asarray(rng.randn(256).astype("f4"))
    for name, fn, ref in (
        ("softmax", lambda: ops.softmax(x, block_rows=32), lambda: softmax_ref(x)),
        ("rmsnorm", lambda: ops.rmsnorm(x, g, block_rows=32), lambda: rmsnorm_ref(x, g)),
    ):
        jax.block_until_ready(fn())  # warm/compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        t = (time.perf_counter() - t0) / 3
        err = float(jnp.max(jnp.abs(fn() - ref())))
        rows.append((f"kernel/{name}", t * 1e6, f"maxerr={err:.1e}"))
    return rows


def bench_stitching():
    """Multi-phase stitched lowering: launches with stitching on vs off on
    the schedule-break graph, plus per-graph phase/interface/pack counters
    wherever the planner used the stitched machinery."""
    from .graphs import stitch_pipeline_graph

    rows = []
    for name, (module, comp, lib) in compiled_all().items():
        s = comp.stats
        if s.stitch_lowered_kernels == 0 and s.planner_packs == 0:
            continue
        rows.append(
            (f"stitch/{name}", 0.0,
             f"lowered={s.stitch_lowered_kernels} "
             f"phases={s.stitch_phases_total} "
             f"iface_bytes={s.stitch_interface_bytes} "
             f"packs={s.planner_packs}")
        )
    on = compiled_all()["StitchPipe"][1].stats
    off = compile_module(
        stitch_pipeline_graph(), replace(OPTS, enable_stitching=False)
    ).stats
    k_on = on.stitched_kernels + on.standalone_kernels
    k_off = off.stitched_kernels + off.standalone_kernels
    rows.append(
        ("stitch/StitchPipe/launch_reduction", 0.0,
         f"stitched={k_on} split={k_off} saved={k_off - k_on}")
    )
    return rows


def bench_frontend():
    """jaxpr-frontend parity: ``repro.stitch`` on plain-jnp functions vs the
    hand-built StitchIR modules of the same computations — kernel counts
    must match and the per-shape plan cache must hold (second same-shape
    call performs no recompile)."""
    from repro import stitch

    from .graphs import JNP_FAMILIES

    rows = []
    rng = np.random.RandomState(0)
    for name, fam in JNP_FAMILIES.items():
        hand = compile_module(fam["module"](), OPTS)
        fn = stitch(fam["fn"], options=replace(OPTS, **fam["options"]))
        args = fam["args"](rng)
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = fn(*args)                      # plan-cache hit: no recompile
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        t_warm = time.perf_counter() - t0
        hk = hand.stats.stitched_kernels + hand.stats.standalone_kernels
        sk = fn.stats.stitched_kernels + fn.stats.standalone_kernels
        rows.append(
            (f"frontend/{name}/kernels", 0.0,
             f"hand={hk} stitched={sk} library={fn.stats.library_calls} "
             f"compiles={fn.num_compiles}")
        )
        rows.append(
            (f"frontend/{name}/call", t_warm * 1e6,
             f"cold_us={t_cold * 1e6:.0f} "
             f"cache_speedup={t_cold / max(t_warm, 1e-9):.1f}x")
        )
    return rows


def bench_sharded():
    """Shard-aware compilation (the multi-device rows): tensor-parallel NMT
    and Stacked compiled to ONE multi-device ExecutionPlan on an 8-device
    host-platform mesh.  Per row: per-device kernel/launch counts vs the
    single-device plan of the same computation (the ceiling compare.py
    gates on), bitwise parity with the jax.jit-under-shard_map oracle, and
    the number of all-reduce breaks with stitched kernels on both sides."""
    from jax.sharding import Mesh

    from repro import stitch
    from repro.core.shard import wrap_shard_map

    from .graphs import TP_FAMILIES

    devs = jax.devices()
    if len(devs) < 8:
        raise RuntimeError(
            "bench_sharded needs 8 devices — run via `python -m "
            "benchmarks.run` so the host-platform flag applies before jax init"
        )
    mesh = Mesh(np.array(devs[:8]).reshape(8), ("model",))
    rows = []
    rng = np.random.RandomState(0)
    for name, fam in TP_FAMILIES.items():
        args = fam["args"](rng)
        specs = fam["specs"]()
        opts = replace(OPTS, **fam["options"])
        single = stitch(fam["fn"], options=opts, name=f"{name}_single")
        single(*args)
        ss = single.stats
        tp = stitch(
            functools.partial(fam["fn"], axis="model"),
            options=opts, name=name, mesh=mesh, **specs,
        )
        out = tp(*args)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        oracle = jax.jit(
            wrap_shard_map(
                functools.partial(fam["fn"], axis="model"),
                mesh, specs["in_specs"], specs["out_specs"],
            )
        )(*args)
        parity = int(all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(out),
                            jax.tree_util.tree_leaves(oracle), strict=False)
        ))
        t0 = time.perf_counter()
        out = tp(*args)                      # plan-cache hit: no recompile
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        t_warm = time.perf_counter() - t0
        st = tp.stats
        perdev = st.stitched_kernels + st.standalone_kernels
        single_k = ss.stitched_kernels + ss.standalone_kernels
        rows.append(
            (f"sharded/{name}/kernels", 0.0,
             f"perdev={perdev} single={single_k} coll={st.collective_calls} "
             f"breaks={st.collective_breaks_spanned} "
             f"launches={st.traced_dispatches_per_call} "
             f"compiles={tp.num_compiles}")
        )
        rows.append(
            (f"sharded/{name}/parity", 0.0,
             f"bitwise={parity} sharded_instrs={st.sharded_instrs} "
             f"mode={st.replay_mode}")
        )
        rows.append((f"sharded/{name}/call", t_warm * 1e6, "devices=8"))
    return rows


def bench_serve_runtime():
    """Runtime launch accounting (the serving analogue of Fig. 7): chunked
    batched prefill — O(ceil(S/chunk)) masked decode launches per prompt —
    vs the per-token oracle at O(S); plus the traced ExecutionPlan replay
    (jitted segments per call) vs the eager per-step loop on every
    benchmark graph."""
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serve import Request, ServeEngine

    rows = []
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, seed=0)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=s) for s in (5, 9, 16, 23)]
    chunk = 8
    launches = {}
    tok_s = {}
    for mode, ck in (("pertoken", 1), ("chunked", chunk)):
        # warm the shared jitted decode fns on a throwaway engine so the
        # one-time trace+compile stays out of the measured window
        warm = ServeEngine(
            cfg, params, pool_size=2, max_len=64, prefill_chunk=ck
        )
        warm.admit(Request(rid=-1, prompt=prompts[0], max_new_tokens=2))
        warm.run_until_done()      # prefill fn + one tick = both decode fns
        eng = ServeEngine(
            cfg, params, pool_size=2, max_len=64, prefill_chunk=ck
        )
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            eng.admit(Request(rid=i, prompt=p, max_new_tokens=4))
        eng.run_until_done()
        dt = time.perf_counter() - t0
        launches[mode] = eng.prefill_launches
        tok_s[mode] = eng.tokens_generated / dt
    rows.append(
        ("serve_runtime/prefill_launches", 0.0,
         f"pertoken={launches['pertoken']} chunked={launches['chunked']} "
         f"chunk={chunk} saved={launches['pertoken'] - launches['chunked']}")
    )
    rows.append(
        ("serve_runtime/prefill_throughput", 0.0,
         f"pertoken_tok_s={tok_s['pertoken']:.1f} "
         f"chunked_tok_s={tok_s['chunked']:.1f}")
    )
    eager = traced = 0
    for name, (module, comp, lib) in compiled_all().items():
        s = comp.stats
        eager += s.eager_dispatches_per_call
        traced += s.traced_dispatches_per_call
        rows.append(
            (f"serve_runtime/{name}/replay", 0.0,
             f"eager={s.eager_dispatches_per_call} "
             f"traced={s.traced_dispatches_per_call} "
             f"donated={s.donated_buffers}")
        )
    rows.append(
        ("serve_runtime/replay_dispatches", 0.0,
         f"eager={eager} traced={traced} saved={eager - traced}")
    )
    return rows


def _serve_traffic_rows(prefix, *, width, slot_pool, max_len, block_size,
                        num_blocks, prefill_chunk, trace_cfgs, max_ticks):
    """Paged continuous batching vs the contiguous slot ring under the SAME
    offered load and the SAME total KV budget (``num_blocks * block_size ==
    slot_pool * max_len`` tokens).  Each arrival process lands one row
    group: concurrency (paged in-flight vs the slot ceiling), throughput,
    TTFT/latency percentiles, and KV-block pressure counters."""
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serve import (
        PagedServeEngine,
        Request,
        ServeEngine,
        generate_trace,
        run_trace,
    )

    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, seed=0)

    def paged():
        return PagedServeEngine(
            cfg, params, decode_width=width, max_len=max_len,
            block_size=block_size, num_blocks=num_blocks,
            prefill_chunk=prefill_chunk,
        )

    def slot():
        return ServeEngine(
            cfg, params, pool_size=slot_pool, max_len=max_len,
            prefill_chunk=prefill_chunk,
        )

    # warm the shared jitted decode fns so one-time trace+compile stays
    # out of the measured replay windows
    warm_prompt = np.arange(1, 5, dtype=np.int32)
    for eng in (paged(), slot()):
        eng.admit(Request(rid=-1, prompt=warm_prompt, max_new_tokens=2))
        eng.run_until_done(max_ticks=200)

    rows = []
    for tc in trace_cfgs:
        trace = generate_trace(tc)
        pe = paged()
        pr = run_trace(pe, trace, max_ticks=max_ticks)
        se = slot()
        sr = run_trace(se, trace, max_ticks=max_ticks)
        kind = tc.arrival
        ratio = pr.max_inflight / max(1, sr.max_inflight)
        rows.append(
            (f"{prefix}/{kind}/inflight", 0.0,
             f"paged={pr.max_inflight} slot={sr.max_inflight} "
             f"ratio={ratio:.1f} mean={pr.mean_inflight:.1f} "
             f"width={width} slot_pool={slot_pool}")
        )
        rows.append(
            (f"{prefix}/{kind}/tokens_per_s", pr.duration_s * 1e6,
             f"paged={pr.tokens_per_s:.0f} slot={sr.tokens_per_s:.0f}")
        )
        rows.append(
            (f"{prefix}/{kind}/ttft_ms", pr.ttft_p99_ms * 1e3,
             f"p50={pr.ttft_p50_ms:.2f} p99={pr.ttft_p99_ms:.2f} "
             f"slot_p50={sr.ttft_p50_ms:.2f} slot_p99={sr.ttft_p99_ms:.2f}")
        )
        rows.append(
            (f"{prefix}/{kind}/latency_ms", pr.latency_p99_ms * 1e3,
             f"p50={pr.latency_p50_ms:.2f} p99={pr.latency_p99_ms:.2f} "
             f"slot_p50={sr.latency_p50_ms:.2f} "
             f"slot_p99={sr.latency_p99_ms:.2f}")
        )
        kv = pe.stats().get("kv_blocks", {})
        rows.append(
            (f"{prefix}/{kind}/kv_blocks", 0.0,
             f"peak={kv.get('peak_in_use', 0)} total={num_blocks} "
             f"peak_util={kv.get('peak_utilization', 0.0):.2f} "
             f"mean_util={kv.get('mean_utilization', 0.0):.2f} "
             f"preempt={pr.preemptions} "
             f"alloc_failures={pr.kv_alloc_failures}")
        )
        rows.append(
            (f"{prefix}/{kind}/completed", 0.0,
             f"paged={pr.completed} slot={sr.completed} total={pr.total}")
        )
    return rows


def bench_serve_traffic():
    """Traffic-trace gate: the paged engine must sustain >= 4x the slot
    engine's concurrency at equal-or-better throughput under the same KV
    budget (compare.py hard-fails on the ratio= field of these rows)."""
    from repro.serve import TraceConfig

    return _serve_traffic_rows(
        "serve_traffic",
        width=32, slot_pool=4, max_len=64, block_size=4, num_blocks=64,
        prefill_chunk=8, max_ticks=100_000,
        trace_cfgs=[
            TraceConfig(
                num_requests=192, arrival="poisson",
                mean_interarrival_ticks=0.25, prompt_len_lo=3,
                prompt_len_hi=10, max_new_lo=4, max_new_hi=8,
                vocab_size=256, seed=0,
            ),
            TraceConfig(
                num_requests=192, arrival="bursty", burst_size=32,
                burst_gap_ticks=24.0, prompt_len_lo=3, prompt_len_hi=10,
                max_new_lo=4, max_new_hi=8, vocab_size=256, seed=1,
            ),
        ],
    )


def bench_serve_traffic_smoke():
    """Tiny bursty trace for CI's fast lane: exercises paged admission,
    block paging and the scheduler end-to-end in seconds; gated only on
    completion (wall-clock rows too noisy at this size to gate)."""
    from repro.serve import TraceConfig

    return _serve_traffic_rows(
        "serve_traffic_smoke",
        width=8, slot_pool=2, max_len=32, block_size=4, num_blocks=16,
        prefill_chunk=4, max_ticks=20_000,
        trace_cfgs=[
            TraceConfig(
                num_requests=24, arrival="bursty", burst_size=8,
                burst_gap_ticks=12.0, prompt_len_lo=3, prompt_len_hi=8,
                max_new_lo=3, max_new_hi=6, vocab_size=256, seed=2,
            ),
        ],
    )


# --autotune-graphs: None = every bench graph (full baseline runs); CI's
# fast lane narrows this to two graphs for an interpret-mode smoke signal.
AUTOTUNE_GRAPHS = None


def bench_autotune():
    """Measured-cost autotuning loop (core/measure.py): per graph, a cold
    autotune compile times every unique kernel (interpret mode on CPU), then
    a warm compile re-plans against the store.  The model_error_pct rows put
    the analytic LatencyModel's error per graph into baseline.json — in
    interpret mode the 'device' is the Pallas interpreter, so errors are
    large and only their *drift* is meaningful (compare.py warns past ±25
    points)."""
    from repro.core import MeasuredCostStore
    from repro.core.measure import device_fingerprint

    rows = []
    names = AUTOTUNE_GRAPHS or list(ALL_GRAPHS)
    opts = replace(OPTS, autotune=True, measure_repeats=3)
    for name in names:
        module_fn = ALL_GRAPHS[name]
        store = MeasuredCostStore(
            device_fp=device_fingerprint(interpret=opts.interpret)
        )
        cold = compile_module(module_fn(), opts, measured_store=store)
        warm = compile_module(module_fn(), opts, measured_store=store)
        s = warm.stats
        err = s.model_error_pct
        rows.append(
            (f"autotune/{name}/model_error_pct", 0.0,
             round(err, 1) if err is not None else "n/a")
        )
        rows.append(
            (f"autotune/{name}/store", 0.0,
             f"measured={cold.stats.measurements_taken} "
             f"warm_hits={s.measured_hits} "
             f"warm_measured={s.measurements_taken} "
             f"kernels={s.stitched_kernels + s.standalone_kernels}")
        )
    return rows


def bench_train_step():
    """Control flow + gradients through the frontend (ISSUE 8): a scan
    decode loop and a full AdamW train step (value_and_grad + optimizer
    towers) each compile as ONE stitched plan with zero fallbacks.  The
    train-step row carries stitched-vs-unfused launch counts; the decode
    row carries traced-vs-eager replay dispatches.  Loss parity against
    jax.jit is checked bitwise over a short trajectory and baked into the
    row — compare.py hard-fails on fallbacks, on stitched >= unfused, and
    on parity=0."""
    from repro import stitch
    from repro.train import AdamWConfig, adamw_init, make_stitched_train_step
    from repro.train.optimizer import adamw_update

    fopts = StitchOptions(max_blocks=32)
    rows = []
    rng = np.random.default_rng(0)

    # --- scan decode loop: one call_loop plan, traced replay wins ---------
    def decode(h, w):
        def step(c, _):
            c = jnp.tanh(c @ w)
            return c, c.sum(axis=-1)

        return jax.lax.scan(step, h, None, length=8)

    h = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 32), scale=0.2), jnp.float32)
    fn = stitch(decode, options=fopts)
    out = fn(h, w)
    ref = jax.jit(decode)(h, w)
    parity = int(all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(ref), strict=False)
    ))
    s = fn.stats
    rows.append(
        ("control_flow/decode_loop/replay", 0.0,
         f"traced={s.traced_dispatches_per_call} "
         f"eager={s.eager_dispatches_per_call} "
         f"fallbacks={fn.num_fallbacks} loops={s.loop_calls} "
         f"parity={parity}")
    )

    # --- whole train step as one plan ------------------------------------
    def loss_fn(params, batch):
        x, y = batch
        hid = jnp.tanh(x @ params["w1"] + params["b1"])
        pred = hid @ params["w2"] + params["b2"]
        return jnp.mean((pred - y) ** 2)

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=4, total_steps=64)
    step = make_stitched_train_step(loss_fn, opt_cfg, options=fopts)

    def ref_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    jref = jax.jit(ref_step)

    params = {
        "w1": jnp.asarray(rng.normal(size=(16, 32), scale=0.1), jnp.float32),
        "b1": jnp.zeros((32,), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(32, 8), scale=0.1), jnp.float32),
        "b2": jnp.zeros((8,), jnp.float32),
    }
    p_a = jax.tree.map(jnp.copy, params)
    p_b = jax.tree.map(jnp.copy, params)
    s_a, s_b = adamw_init(p_a), adamw_init(p_b)

    bitwise, t_warm = 1, 0.0
    for i in range(5):
        batch = (
            jnp.asarray(rng.normal(size=(64, 16)), jnp.float32),
            jnp.asarray(rng.normal(size=(64, 8)), jnp.float32),
        )
        t0 = time.perf_counter()
        p_a, s_a, m_a = step(p_a, s_a, batch)
        jax.block_until_ready(jax.tree_util.tree_leaves(p_a))
        dt = time.perf_counter() - t0
        if i > 0:
            t_warm += dt / 4
        p_b, s_b, m_b = jref(p_b, s_b, batch)
        if not np.array_equal(np.asarray(m_a["loss"]), np.asarray(m_b["loss"])):
            bitwise = 0

    st = step.stats
    stitched = st.stitched_kernels + st.standalone_kernels
    rows.append(
        ("train_step/kernels", 0.0,
         f"stitched={stitched} unfused={st.xla_baseline_kernels} "
         f"fallbacks={step.num_fallbacks} compiles={step.num_compiles}")
    )
    rows.append(
        ("train_step/loss_parity", 0.0, f"bitwise={bitwise} steps=5")
    )
    rows.append(("train_step/step", t_warm * 1e6, "donated=params+opt_state"))
    return rows


ALL_BENCHES = [
    bench_fusion_ratio,
    bench_speedup,
    bench_dispatch_wall_time,
    bench_smem_stats,
    bench_breakdown,
    bench_footprint,
    bench_compile_cache,
    bench_fusion_planner,
    bench_stitching,
    bench_stitched_kernels,
    bench_frontend,
    bench_sharded,
    bench_train_step,
    bench_serve_runtime,
    bench_serve_traffic,
    bench_serve_traffic_smoke,
    bench_autotune,
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated bench-name substrings (e.g. fusion_planner)",
    )
    ap.add_argument(
        "--json-out",
        default=None,
        help="also write rows as JSON (CI uploads this as an artifact)",
    )
    ap.add_argument(
        "--repeat",
        type=int,
        default=1,
        metavar="N",
        help="run each bench N times after one warmup run and report the "
        "median us_per_call per row (derived from the last run) — measured "
        "rows in baseline.json need this to be stable enough to gate on",
    )
    ap.add_argument(
        "--autotune",
        action="store_true",
        help="include the measured-cost autotuning bench even when --only "
        "selects other benches",
    )
    ap.add_argument(
        "--autotune-graphs",
        default=None,
        metavar="NAMES",
        help="comma-separated graph names for bench_autotune "
        "(default: every bench graph)",
    )
    args = ap.parse_args(argv)
    if args.repeat < 1:
        ap.error(f"--repeat must be >= 1, got {args.repeat}")
    global AUTOTUNE_GRAPHS
    if args.autotune_graphs is not None:
        names = [g.strip() for g in args.autotune_graphs.split(",") if g.strip()]
        unknown = [g for g in names if g not in ALL_GRAPHS]
        if not names or unknown:
            ap.error(
                f"--autotune-graphs: unknown graph(s) "
                f"{', '.join(unknown) or args.autotune_graphs!r}; "
                f"valid: {', '.join(ALL_GRAPHS)}"
            )
        AUTOTUNE_GRAPHS = names
    wanted = None
    if args.only is not None:
        wanted = [w.strip() for w in args.only.split(",") if w.strip()]
        valid = [b.__name__ for b in ALL_BENCHES]
        unknown = [
            w for w in wanted if not any(w in name for name in valid)
        ]
        if not wanted or unknown:
            ap.error(
                f"--only matched nothing for {', '.join(sorted(unknown)) or args.only!r}; "
                f"valid bench names: {', '.join(valid)}"
            )
        if args.autotune and not any(w in "bench_autotune" for w in wanted):
            wanted.append("autotune")
    rows = []
    print("name,us_per_call,derived")
    for bench in ALL_BENCHES:
        if wanted and not any(w in bench.__name__ for w in wanted):
            continue
        if args.repeat > 1:
            bench()                          # warmup: traces/compiles settle
            runs = [bench() for _ in range(args.repeat)]
            by_name = {}
            for run_rows in runs:
                for name, us, _ in run_rows:
                    by_name.setdefault(name, []).append(us)
            bench_rows = [
                (name, float(np.median(by_name[name])), derived)
                for name, _, derived in runs[-1]
            ]
        else:
            bench_rows = bench()
        for name, us, derived in bench_rows:
            rows.append({"name": name, "us_per_call": us, "derived": derived})
            print(f"{name},{us:.2f},{derived}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
