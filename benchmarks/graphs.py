"""The paper's six benchmark workloads (Table 2) re-created in StitchIR.

LR / W2V / RNN / BiRNN are the public tensorflow-examples models the paper
uses; Speech and NMT are modeled on the paper's description of its in-house
workloads (Speech: "complex interaction patterns among reduce, transpose,
concat, and elementwise ops"; NMT: the Figure-3 attention softmax×BatchDot
subgraph on marginal batched shapes, fused per the user decision).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GraphBuilder, Module


def random_feeds(module: Module, rng) -> dict:
    """Random feeds for every module parameter (int32 params get small
    first-dim-bounded indices, floats get uniform(-1, 1)) — the ONE feed
    builder shared by the benchmark harness and the test suites
    (``tests/conftest.make_feeds`` delegates here)."""
    out = {}
    for p in module.parameters:
        if np.dtype(p.dtype) == np.int32:
            out[p.name] = rng.randint(
                0, max(2, p.shape[0] if p.shape else 2), size=p.shape
            ).astype(np.int32)
        else:
            out[p.name] = rng.uniform(-1, 1, size=p.shape).astype(
                np.dtype(p.dtype)
            )
    return out


LR_DIM = (64, 16)          # batch, features
W2V_DIM = (64, 32, 512)    # batch, embed dim, vocab
RNN_STEPS = 6
SPEECH_DIM = (8, 50, 40)   # batch, frames, filters
NMT_DIM = (4, 8, 32, 16)   # batch, heads, seq, head_dim


def lr_graph() -> Module:
    """Logistic-regression training step: fwd + grads + SGD updates."""
    b = GraphBuilder("LR")
    B, D = LR_DIM
    x = b.parameter("x", (B, D), jnp.float32)
    y = b.parameter("y", (B, 1), jnp.float32)
    W = b.parameter("W", (D, 1), jnp.float32)
    bias = b.parameter("b", (1,), jnp.float32)
    z = b.dot(x, W)                                    # LC
    p = b.sigmoid(z + b.broadcast(bias, (B, 1), (1,)))
    e = p - y
    xt = b.transpose(x, (1, 0))
    dW = b.dot(xt, e)                                  # LC
    _W2 = W - dW * 0.1                                 # update kernel
    db = b.reduce(e, (0, 1), "mean")
    _b2 = bias - b.broadcast(db, (1,), ()) * 0.1
    # loss for logging: -(y log p + (1-y) log(1-p))
    lp = b.log(b.maximum(p, 1e-6))
    ln = b.log(b.maximum(1.0 - p, 1e-6))
    _loss = b.reduce(0.0 - (y * lp + (1.0 - y) * ln), (0, 1), "mean")
    return b.module


def w2v_graph() -> Module:
    """Word2vec negative-sampling step: gathers + elementwise grads."""
    b = GraphBuilder("W2V")
    B, D, V = W2V_DIM
    t_in = b.parameter("emb_in", (V, D), jnp.float32)
    t_out = b.parameter("emb_out", (V, D), jnp.float32)
    idx = b.parameter("center", (B,), jnp.int32)
    ctx = b.parameter("context", (B,), jnp.int32)
    lbl = b.parameter("label", (B,), jnp.float32)
    ein = b.gather(t_in, idx)                          # (B, D)
    eout = b.gather(t_out, ctx)
    score = b.reduce(ein * eout, (1,), "sum")          # (B,)
    p = b.sigmoid(score)
    g = p - lbl
    gb = b.broadcast(g, (B, D), (0,))
    _d_in = ein - gb * eout * 0.05                     # updated rows
    _d_out = eout - gb * ein * 0.05
    return b.module


def _rnn_cell(b, x_t, h, Wx, Wh, bias, tag):
    a = b.dot(x_t, Wx)                                 # LC
    c = b.dot(h, Wh)                                   # LC
    s = a + c + b.broadcast(bias, a.shape, (1,))
    return b.tanh(s)


def rnn_graph(steps: int = RNN_STEPS, name="RNN") -> Module:
    b = GraphBuilder(name)
    B, D, H = 16, 24, 32
    Wx = b.parameter("Wx", (D, H), jnp.float32)
    Wh = b.parameter("Wh", (H, H), jnp.float32)
    bias = b.parameter("b", (H,), jnp.float32)
    h = b.parameter("h0", (B, H), jnp.float32)
    for t in range(steps):
        x_t = b.parameter(f"x{t}", (B, D), jnp.float32)
        h = _rnn_cell(b, x_t, h, Wx, Wh, bias, t)
    Wo = b.parameter("Wo", (H, 8), jnp.float32)
    logits = b.dot(h, Wo)                              # LC
    _probs = b.softmax(logits, dim=-1)
    return b.module


def birnn_graph(steps: int = RNN_STEPS) -> Module:
    b = GraphBuilder("BiRNN")
    B, D, H = 16, 24, 32
    xs = [b.parameter(f"x{t}", (B, D), jnp.float32) for t in range(steps)]
    hf = b.parameter("hf0", (B, H), jnp.float32)
    hb = b.parameter("hb0", (B, H), jnp.float32)
    Wxf = b.parameter("Wxf", (D, H), jnp.float32)
    Whf = b.parameter("Whf", (H, H), jnp.float32)
    bf = b.parameter("bf", (H,), jnp.float32)
    Wxb = b.parameter("Wxb", (D, H), jnp.float32)
    Whb = b.parameter("Whb", (H, H), jnp.float32)
    bb = b.parameter("bb", (H,), jnp.float32)
    for t in range(steps):
        hf = _rnn_cell(b, xs[t], hf, Wxf, Whf, bf, f"f{t}")
    for t in reversed(range(steps)):
        hb = _rnn_cell(b, xs[t], hb, Wxb, Whb, bb, f"b{t}")
    hcat = b.concat([hf, hb], dim=1)                   # (B, 2H)
    Wo = b.parameter("Wo", (2 * H, 8), jnp.float32)
    _out = b.softmax(b.dot(hcat, Wo), dim=-1)
    return b.module


def speech_graph() -> Module:
    """Acoustic frontend head: square/log/reduce/transpose/concat mix."""
    b = GraphBuilder("Speech")
    B, T, F = SPEECH_DIM
    x = b.parameter("frames", (B, T, F), jnp.float32)
    mel_w = b.parameter("mel", (F, F), jnp.float32)
    power = b.square(x)
    flat = b.reshape(power, (B * T, F))
    mel = b.dot(flat, mel_w)                           # LC
    lg = b.log(b.maximum(b.reshape(mel, (B, T, F)), 1e-6))
    # per-utterance mean/var normalization (column reduces over time)
    mu = b.reduce(lg, (1,), "mean")                    # (B, F)
    mub = b.broadcast(mu, (B, T, F), (0, 2))
    cen = lg - mub
    var = b.reduce(b.square(cen), (1,), "mean")
    inv = b.rsqrt(var + 1e-5)
    norm = cen * b.broadcast(inv, (B, T, F), (0, 2))
    # transpose to feature-major and append a scaled copy (delta stand-in)
    tr = b.transpose(norm, (0, 2, 1))                  # (B, F, T)
    delta = tr * 0.5 + 0.1
    feats = b.concat([tr, delta], dim=1)               # (B, 2F, T)
    gate = b.sigmoid(feats)
    _out = b.reduce(gate * feats, (2,), "mean")        # (B, 2F)
    return b.module


def nmt_graph(fuse_dot: bool = True) -> Module:
    """The paper's Figure-3 subgraph: softmax stitched with BatchMatMul."""
    b = GraphBuilder("NMT")
    B, H, S, D = NMT_DIM
    q = b.parameter("q", (B, H, S, D), jnp.float32)
    k = b.parameter("k", (B, H, S, D), jnp.float32)
    v = b.parameter("v", (B, H, S, D), jnp.float32)
    bias = b.parameter("bias", (S, S), jnp.float32)
    kt = b.transpose(k, (0, 1, 3, 2))
    scores = b.dot(q, kt, fusable=fuse_dot)            # marginal batched shape
    scaled = scores * (1.0 / D ** 0.5) + b.broadcast(bias, scores.shape, (2, 3))
    p = b.softmax(scaled, dim=-1)
    ctx = b.dot(p, v, fusable=fuse_dot)                # Dot.1 of Figure 3
    _out = b.tanh(ctx)
    return b.module


def stacked_transformer_graph(num_layers: int = 8) -> Module:
    """N structurally-identical pre-norm transformer-ish blocks separated by
    library MatMuls — the repeated-layer serving workload the kernel cache
    targets: every middle layer's fusion has the same fusion signature."""
    b = GraphBuilder("Stacked")
    B, D = 16, 64
    x = b.parameter("x", (B, D), jnp.float32)
    for layer in range(num_layers):
        g = b.parameter(f"g{layer}", (D,), jnp.float32)
        W = b.parameter(f"W{layer}", (D, D), jnp.float32)
        ms = b.reduce(b.square(x), (1,), "mean")
        inv = b.rsqrt(ms + 1e-6)
        normed = x * b.broadcast(inv, (B, D), (0,)) * b.broadcast(g, (B, D), (1,))
        h = b.dot(normed, W)                           # LC: layer boundary
        x = x + b.silu(h)
    return b.module


def reduce_towers_graph(num_towers: int = 6) -> Module:
    """Adversarial for greedy fusion (reduce-heavy): N independent
    square/scale/reduce towers whose sinks are *reduces*, not elementwise
    ops — so the paper's ElementwiseFusion never groups them and Algorithm 1
    commits one kernel per tower.  The towers are tiny, so launch overhead
    dominates; the cost-guided planner's horizontal-merge pass packs them
    into one multi-root kernel."""
    b = GraphBuilder("ReduceTowers")
    B, D = 32, 64
    for i in range(num_towers):
        x = b.parameter(f"x{i}", (B, D), jnp.float32)
        s = b.parameter(f"s{i}", (B, D), jnp.float32)
        e = b.square(x * 0.5 + s)
        _ = b.reduce(e * e, (0, 1), "sum")
    return b.module


def broadcast_towers_graph(num_towers: int = 5) -> Module:
    """Adversarial for greedy fusion (broadcast/replication-heavy): each
    tower broadcasts a small per-feature gain across a wide activation,
    normalizes by a mid-tower reduce, broadcasts back to the wide shape, and
    ends in a *reshape* sink (invisible to ElementwiseFusion, which only
    groups elementwise sinks).  Greedy commits one maximal kernel per tower,
    each carrying the reduce and two widening broadcasts; the planner
    explores split-at-reduce / split-before-broadcast partitions per tower
    and packs the towers into fewer kernels via horizontal merge."""
    b = GraphBuilder("BcastHeavy")
    B, D = 16, 32
    for i in range(num_towers):
        x = b.parameter(f"x{i}", (B, D), jnp.float32)
        g = b.parameter(f"g{i}", (D,), jnp.float32)
        scaled = x * b.broadcast(g, (B, D), (1,))
        m = b.reduce(scaled, (1,), "mean")             # (B,)
        cen = scaled - b.broadcast(m, (B, D), (0,))
        _ = b.reshape(b.sigmoid(cen), (B * D,))        # flat sink
    return b.module


def stitch_pipeline_graph() -> Module:
    """Adversarial for single-schedule fusion (schedule-break-heavy): a wide
    row-softmax feeds a full 2-D transpose and a tail normalization.  The
    softmax intermediate (512x320 f32, 640KB) exceeds the replicate limit,
    so no single block schedule crosses the transpose — the paper-faithful
    compiler splits here into three kernels.  Multi-phase stitching lowers
    the whole pipeline as ONE kernel: the softmax phase materializes its
    output in a full VMEM staging buffer and the transpose phase re-tiles
    it under its own sub-schedule (arXiv:1911.11576 / 2009.10924)."""
    b = GraphBuilder("StitchPipe")
    B, D = 512, 320
    x = b.parameter("x", (B, D), jnp.float32)
    g = b.parameter("g", (D,), jnp.float32)
    scaled = x * b.broadcast(g, (B, D), (1,))
    mx = b.reduce(scaled, (1,), "max")
    e = b.exp(scaled - b.broadcast(mx, (B, D), (0,)))
    s = b.reduce(e, (1,), "sum")
    p = e / b.broadcast(s, (B, D), (0,))
    t = b.transpose(p, (1, 0))                         # (D, B): the break
    _out = b.tanh(t) * 0.5
    return b.module


# --------------------------------------------------------------------------
# Plain-jnp family (jaxpr-frontend parity): the same computations written as
# ordinary jax.numpy functions — zero GraphBuilder calls — captured through
# ``repro.stitch``.  Each entry pairs the jnp function with the hand-built
# module above so benchmarks and tests can assert the frontend reproduces
# the hand-built plans (same kernel counts, outputs allclose to jax.jit).
# --------------------------------------------------------------------------


def nmt_fn(q, k, v, bias):
    """Figure-3 attention (softmax stitched with BatchMatMul) in plain jnp —
    mirrors ``nmt_graph``."""
    d = q.shape[-1]
    kt = jnp.swapaxes(k, -1, -2)
    scores = jnp.matmul(q, kt)
    scaled = scores * (1.0 / d ** 0.5) + bias
    mx = jnp.max(scaled, axis=-1, keepdims=True)
    e = jnp.exp(scaled - mx)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.tanh(jnp.matmul(p, v))


def nmt_args(rng):
    B, H, S, D = NMT_DIM
    return (
        rng.randn(B, H, S, D).astype("f4"),
        rng.randn(B, H, S, D).astype("f4"),
        rng.randn(B, H, S, D).astype("f4"),
        rng.randn(S, S).astype("f4"),
    )


def stacked_fn(x, gains, weights):
    """Pre-norm transformer-ish blocks in plain jnp — mirrors
    ``stacked_transformer_graph`` (dots stay library calls: compile with
    ``fuse_dot=False``)."""
    for g, W in zip(gains, weights, strict=False):
        ms = jnp.mean(jnp.square(x), axis=1, keepdims=True)
        inv = jax.lax.rsqrt(ms + 1e-6)
        normed = x * inv * g[None, :]
        x = x + jax.nn.silu(jnp.matmul(normed, W))
    return x


def stacked_args(rng, num_layers: int = 8):
    B, D = 16, 64
    return (
        rng.randn(B, D).astype("f4"),
        [rng.randn(D).astype("f4") for _ in range(num_layers)],
        [rng.randn(D, D).astype("f4") for _ in range(num_layers)],
    )


def reduce_towers_fn(xs, ss):
    """Independent square/scale/reduce towers in plain jnp — mirrors
    ``reduce_towers_graph`` (the horizontal-merge adversary)."""
    outs = []
    for x, s in zip(xs, ss, strict=False):
        e = jnp.square(x * 0.5 + s)
        outs.append(jnp.sum(e * e))
    return tuple(outs)


def reduce_towers_args(rng, num_towers: int = 6):
    B, D = 32, 64
    return (
        [rng.randn(B, D).astype("f4") for _ in range(num_towers)],
        [rng.randn(B, D).astype("f4") for _ in range(num_towers)],
    )


# --------------------------------------------------------------------------
# Tensor-parallel family (shard-aware compilation): the same workloads with
# Megatron-style TP placements.  Each function takes ``axis``: None gives the
# single-device reference plan (the per-device-kernel ceiling in compare.py),
# an axis name gives the shard_map body with the ``lax.psum`` all-reduce.
# The collective always lands immediately after a library dot, so it breaks
# no fusion group: per-device kernel counts match the single-device plan,
# and the stitched kernels on both sides of the psum span the break.
# --------------------------------------------------------------------------


def nmt_tp_fn(q, k, v, bias, wo, axis=None):
    """Head-parallel attention + row-parallel output projection.  ``q/k/v``
    shard the head dim, ``wo`` the flattened head*dim rows; the psum after
    the projection dot merges the per-head partial outputs."""
    B, H, S, D = q.shape
    kt = jnp.swapaxes(k, -1, -2)
    scores = jnp.matmul(q, kt) * (1.0 / D ** 0.5) + bias
    mx = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - mx)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    ctx = jnp.tanh(jnp.matmul(p, v))
    # flatten to an explicit 2-D projection: a 3-D matmul would make jax
    # insert a reshape between the dot and the psum, stranding it as its own
    # kernel on the sharded side (single-device fuses it into the tail)
    flat = jnp.reshape(jnp.transpose(ctx, (0, 2, 1, 3)), (B * S, H * D))
    y = jnp.matmul(flat, wo)
    if axis is not None:
        y = jax.lax.psum(y, axis)
    return y * jax.nn.sigmoid(y)


#: the TP variant doubles the head count so each of the 8 shards keeps a
#: real head dim (H=1 per shard would make jax squeeze the batched dots
#: into a different graph than the single-device reference plan)
NMT_TP_DIM = (4, 16, 32, 16)


def nmt_tp_args(rng):
    B, H, S, D = NMT_TP_DIM
    return (
        rng.randn(B, H, S, D).astype("f4"),
        rng.randn(B, H, S, D).astype("f4"),
        rng.randn(B, H, S, D).astype("f4"),
        rng.randn(S, S).astype("f4"),
        rng.randn(H * D, D).astype("f4"),
    )


def nmt_tp_specs():
    from jax.sharding import PartitionSpec as P

    return dict(
        in_specs=(
            P(None, "model"), P(None, "model"), P(None, "model"),
            P(), P("model", None),
        ),
        out_specs=P(),
    )


def stacked_tp_fn(x, gains, w1s, w2s, axis=None):
    """Megatron MLP blocks: W1 column-parallel, W2 row-parallel, one psum
    per layer merging the partial block outputs into the residual stream."""
    for g, W1, W2 in zip(gains, w1s, w2s, strict=False):
        ms = jnp.mean(jnp.square(x), axis=1, keepdims=True)
        inv = jax.lax.rsqrt(ms + 1e-6)
        normed = x * inv * g[None, :]
        y = jnp.matmul(jax.nn.silu(jnp.matmul(normed, W1)), W2)
        if axis is not None:
            y = jax.lax.psum(y, axis)
        x = x + y
    return x


def stacked_tp_args(rng, num_layers: int = 8):
    B, D, F = 16, 64, 128
    return (
        rng.randn(B, D).astype("f4"),
        [rng.randn(D).astype("f4") for _ in range(num_layers)],
        [rng.randn(D, F).astype("f4") for _ in range(num_layers)],
        [rng.randn(F, D).astype("f4") for _ in range(num_layers)],
    )


def stacked_tp_specs(num_layers: int = 8):
    from jax.sharding import PartitionSpec as P

    return dict(
        in_specs=(
            P(),
            [P()] * num_layers,
            [P(None, "model")] * num_layers,
            [P("model", None)] * num_layers,
        ),
        out_specs=P(),
    )


#: tensor-parallel families: fn(..., axis=) + args + the shard_map specs +
#: the StitchOptions overrides both the sharded and the single-device
#: reference compile use (library dots keep the collective off any fusion
#: group's interior).
TP_FAMILIES = {
    "NMT_TP": {
        "fn": nmt_tp_fn, "args": nmt_tp_args, "specs": nmt_tp_specs,
        "options": {"fuse_dot": False},
    },
    "Stacked_TP": {
        "fn": stacked_tp_fn, "args": stacked_tp_args,
        "specs": stacked_tp_specs, "options": {"fuse_dot": False},
    },
}


#: frontend-parity families: jnp fn + example args + the hand-built module
#: it must reproduce + the StitchOptions overrides the frontend compiles
#: under (e.g. Stacked keeps its dots as library calls via fuse_dot=False,
#: matching the hand-built graph's ``fusable=False`` dots).
JNP_FAMILIES = {
    "NMT": {
        "fn": nmt_fn, "args": nmt_args, "module": nmt_graph, "options": {},
    },
    "Stacked": {
        "fn": stacked_fn, "args": stacked_args,
        "module": stacked_transformer_graph, "options": {"fuse_dot": False},
    },
    "ReduceTowers": {
        "fn": reduce_towers_fn, "args": reduce_towers_args,
        "module": reduce_towers_graph, "options": {},
    },
}


ALL_GRAPHS = {
    "LR": lr_graph,
    "W2V": w2v_graph,
    "RNN": rnn_graph,
    "BiRNN": birnn_graph,
    "Speech": speech_graph,
    "NMT": nmt_graph,
    "Stacked": stacked_transformer_graph,
    "ReduceTowers": reduce_towers_graph,
    "BcastHeavy": broadcast_towers_graph,
    "StitchPipe": stitch_pipeline_graph,
}
