from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_init_specs, adamw_update, lr_at
from .trainer import (
    FailureInjector,
    StragglerWatchdog,
    Trainer,
    TrainerConfig,
    cross_entropy,
    make_loss_fn,
    make_stitched_train_step,
    make_train_step,
)
