"""AdamW + LR schedules from scratch (no optax in this environment).

The optimizer state is a pytree mirroring the params (m, v in f32 —
sharded identically to the params by the same sharding rules, giving
ZeRO-style partitioned optimizer state under the fsdp axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: Any                   # f32 pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"          # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def _decay_mask(path: Tuple, leaf) -> bool:
    """No weight decay on norms/biases/scalars (1-D and smaller)."""
    return getattr(leaf, "ndim", 0) >= 2


def adamw_init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_init_specs(param_specs) -> AdamWState:
    """ShapeDtypeStruct mirror for dry runs."""
    def sds(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(sds, param_specs),
        v=jax.tree.map(sds, param_specs),
    )


def adamw_update(
    cfg: AdamWConfig, params, grads, state: AdamWState
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask((), p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=False)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
