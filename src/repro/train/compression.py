"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick for the 1000+-node posture).

Two composable transforms:

  * bf16 reduction — cast grads to bf16 before the all-reduce, accumulate
    back in f32 (2x DCN bytes saved; the standard cross-pod trick).
  * int8 error-feedback — per-tensor symmetric int8 quantization with a
    residual carried to the next step (1-bit-Adam-style EF), 4x bytes saved;
    the residual guarantees the quantization error is compensated, which the
    convergence test in tests/test_train.py verifies on a quadratic.

On the wire these wrap the gradient pytree right before ``psum``; under pjit
the cast itself shrinks the all-reduce payload (GSPMD reduces in the cast
dtype).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any            # f32 pytree like grads


def ef_init(params) -> EFState:
    return EFState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8_ef(grads, state: EFState) -> Tuple[Any, Any, EFState]:
    """Returns (quantized pytree of (q, scale), dequantized grads for the
    local update path, new EF state)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize_int8(x)
        deq = _dequantize_int8(q, s)
        return (q, s), deq, x - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r, strict=False)]
    wire = jax.tree.unflatten(td, [o[0] for o in outs])
    deq = jax.tree.unflatten(td, [o[1] for o in outs])
    new_res = jax.tree.unflatten(td, [o[2] for o in outs])
    return wire, deq, EFState(new_res)


def bf16_compress(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def bf16_decompress(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def wire_bytes(tree) -> int:
    import numpy as np

    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
