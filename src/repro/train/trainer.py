"""Loss, train-step builder (with microbatch gradient accumulation via scan),
and the fault-tolerant training driver.

``make_train_step`` returns a pure jittable function over GLOBAL logical
shapes — pjit shards it by the in/out shardings from
``repro.distributed.sharding``.  The driver (``Trainer``) adds
checkpointing/auto-resume, the straggler watchdog, and failure injection.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..models import forward
from ..models import layers as L
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


def cross_entropy_sums(logits: jax.Array, labels: jax.Array, vocab_size: int):
    """logits (..., Vp) f32; labels (...) int32 (-1 = ignore).
    Returns (sum nll, count).  Vocab padding columns are masked out."""
    Vp = logits.shape[-1]
    col = jnp.arange(Vp)
    mask_cols = col < vocab_size
    logits = jnp.where(mask_cols, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1)) + m[..., 0]
    lbl = jnp.clip(labels, 0, Vp - 1)
    picked = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask), jnp.sum(mask)


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int):
    total, denom = cross_entropy_sums(logits, labels, vocab_size)
    return total / jnp.maximum(denom, 1.0)


def _chunk_len(S: int, target: int) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def make_loss_fn(cfg):
    """Chunked vocab-parallel CE: the (B, S, Vp) logits tensor is never
    materialized — the unembed matmul + CE run per sequence chunk inside a
    scan (the peak is (B, chunk, Vp/model-shards) per device)."""

    def loss_fn(params, batch):
        hidden = forward(params, batch, cfg, return_hidden=True)  # (B, S, d)
        labels = batch["labels"]
        B, S, d = hidden.shape
        c = _chunk_len(S, cfg.loss_chunk)
        nc = S // c
        if nc <= 1:
            logits = L.unembed(params["embed"], hidden).astype(jnp.float32)
            return cross_entropy(logits, labels, cfg.vocab_size)
        h = jnp.moveaxis(hidden.reshape(B, nc, c, d), 1, 0)       # (nc,B,c,d)
        lab = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)

        def step(carry, xs):
            tot, cnt = carry
            hc, lc = xs
            logits = L.unembed(params["embed"], hc).astype(jnp.float32)
            t, n = cross_entropy_sums(logits, lc, cfg.vocab_size)
            return (tot + t, cnt + n), None

        (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)), (h, lab))
        return tot / jnp.maximum(cnt, 1.0)

    return loss_fn


def make_train_step(
    cfg,
    opt_cfg: AdamWConfig,
    accum_steps: int = 1,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``accum_steps > 1`` the global batch is split on the leading axis
    and gradients accumulate through a ``lax.scan`` — constant HLO size and
    donated accumulators (XLA overlaps each microbatch's reduce-scatter with
    the next microbatch's backward under GSPMD).
    """
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state: AdamWState, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )

            def acc_step(carry, mb):
                gsum, lsum = carry
                lval, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + lval), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_stitched_train_step(
    loss_fn: Callable,
    opt_cfg: AdamWConfig,
    options=None,
    **stitch_kwargs,
):
    """Compile ``value_and_grad(loss_fn)`` + the AdamW update as ONE stitched plan.

    The whole training step — forward, backward, gradient clipping, LR
    schedule and the per-leaf elementwise optimizer-update towers — is
    captured through ``repro.stitch`` and planned together, so the update
    math fuses with the tail of the backward pass instead of launching one
    kernel per leaf.  ``params`` and ``opt_state`` buffers are donated, as
    in the ``jax.jit`` path.

    ``loss_fn(params, batch) -> scalar`` must be stitchable (no gather /
    ``take_along_axis``); the production chunked-CE loss from
    ``make_loss_fn`` is not, but MLP/MSE-style losses are — see
    ``examples/train_stitched.py``.

    Returns a ``StitchedFunction`` with the ``make_train_step`` signature:
    ``(params, opt_state, batch) -> (params, opt_state, metrics)``.
    """
    from ..frontend import stitch

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    stitch_kwargs.setdefault("name", "train_step")
    stitch_kwargs.setdefault("donate_argnums", (0, 1))
    return stitch(train_step, options=options, **stitch_kwargs)


# ======================================================================
# fault-tolerant driver
# ======================================================================
@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    straggler_threshold: float = 3.0     # x median step time


class StragglerWatchdog:
    """EMA step-time monitor; flags steps slower than k x the running
    median.  On a real fleet the flag triggers backup-task dispatch; here it
    feeds the trainer's metrics and the fault-tolerance tests."""

    def __init__(self, threshold: float = 3.0, window: int = 50):
        self.threshold = threshold
        self.times: list = []
        self.window = window
        self.flagged: list = []

    def observe(self, step: int, dt: float) -> bool:
        import statistics

        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
                return True
        return False


class FailureInjector:
    """Deterministic failure injection for restart tests."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


class Trainer:
    def __init__(
        self,
        cfg,
        opt_cfg: AdamWConfig,
        tcfg: TrainerConfig,
        data_iter_factory: Callable[[int], Any],
        checkpoint_manager=None,
        train_step: Optional[Callable] = None,
        failure_injector: Optional[FailureInjector] = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data_iter_factory = data_iter_factory
        self.ckpt = checkpoint_manager
        self.train_step = train_step or jax.jit(
            make_train_step(cfg, opt_cfg), donate_argnums=(0, 1)
        )
        self.watchdog = StragglerWatchdog(tcfg.straggler_threshold)
        self.injector = failure_injector
        self.history: list = []

    def run(self, params, opt_state=None, start_step: int = 0):
        opt_state = opt_state if opt_state is not None else adamw_init(params)
        step = start_step
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest(params, opt_state)
            if restored is not None:
                params, opt_state, step = restored
        data = self.data_iter_factory(step)
        while step < self.tcfg.total_steps:
            if self.injector is not None:
                self.injector.maybe_fail(step)
            batch = next(data)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self.watchdog.observe(step, dt)
            self.history.append({"step": step, "loss": loss, "dt": dt, "straggler": slow})
            step += 1
            if self.ckpt is not None and step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(step, params, opt_state)
        if self.ckpt is not None:
            self.ckpt.save(step, params, opt_state)
        return params, opt_state, step
