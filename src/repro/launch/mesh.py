"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run process sets
``--xla_force_host_platform_device_count=512`` before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod single-pod, or 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 device)."""
    return jax.make_mesh((data, model), ("data", "model"))
