"""Exact static cost analysis by walking the jaxpr.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so for
scan-over-layers programs it understates FLOPs by ~L×.  The jaxpr still
carries every scan's static ``length``, so walking it gives exact logical
FLOPs (scan-aware, remat-aware — recomputation appears in the differentiated
jaxpr) and a fusion-approximate HBM byte count.

Conventions (documented in EXPERIMENTS.md):
  * dot_general: 2·prod(out)·prod(contract) FLOPs; bytes = in + out.
  * elementwise / reduce: 1 FLOP per output (resp. input) element;
    bytes = output only (consumers fuse — a deliberate *approximation*).
  * data movement (reshape/broadcast/slice/gather/...): bytes = output.
  * scan: body × length.  while: body × 1 (none in this codebase).
  * numbers are GLOBAL logical costs; divide by chip count for per-chip
    roofline terms (replicated compute is not charged — noted).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore

_EW = {
    "add", "sub", "mul", "div", "max", "min", "pow", "exp", "log", "tanh",
    "logistic", "erf", "rsqrt", "sqrt", "neg", "abs", "sign", "floor",
    "ceil", "round", "integer_pow", "select_n", "ne", "eq", "ge", "gt",
    "le", "lt", "and", "or", "not", "xor", "clamp", "rem", "atan2",
    "cos", "sin", "cbrt", "expm1", "log1p", "square", "nextafter",
    "real", "imag", "add_any", "copy", "convert_element_type",
    "stop_gradient",
    "is_finite", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "population_count", "clz", "erf_inv",
}
_REDUCE = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "reduce_precision",
}
_MOVE = {
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "slice",
    "concatenate", "pad", "rev", "gather", "dynamic_slice",
    "dynamic_update_slice", "iota", "scatter", "scatter-add", "scatter_add",
    "expand_dims", "split",
}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


def _nelems(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64))
    except Exception:  # noqa: BLE001
        return 0


def _sub_jaxprs(params: Dict[str, Any]):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr"):
        if key in params:
            yield key, params[key]
    if "branches" in params:
        for b in params["branches"]:
            yield "branch", b


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


_ZERO = {"flops": 0.0, "bytes": 0.0, "bytes_min": 0.0, "dot_flops": 0.0}


def jaxpr_cost(jaxpr) -> Dict[str, float]:
    """Returns global logical {"flops", "dot_flops", "bytes", "bytes_min"}.

    ``bytes`` charges every primitive's output (unfused UPPER bound on HBM
    traffic); ``bytes_min`` charges only kernel-boundary ops — dots,
    reduces, gathers/scatters/sorts/concats — assuming XLA fuses all
    elementwise/movement chains into their consumers (LOWER bound).  Real
    traffic lies between; the roofline table reports both.
    """
    jaxpr = _as_jaxpr(jaxpr)
    flops = 0.0
    byts = 0.0
    byts_min = 0.0
    dot_flops = 0.0
    # convert provenance: a dot operand produced by convert_element_type is
    # read from HBM at its SOURCE dtype (the convert fuses into the read) —
    # this is what credits int8 KV caches / bf16 params with their real
    # bandwidth, not the f32 compute dtype.
    src_bytes: Dict[Any, int] = {}
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        out_elems = sum(_nelems(v.aval) for v in eqn.outvars)
        out_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(
            _nbytes(v.aval) for v in eqn.invars if hasattr(v, "aval")
        )
        if name == "convert_element_type" and len(eqn.outvars) == 1:
            iv = eqn.invars[0]
            if hasattr(iv, "aval"):
                src_bytes[eqn.outvars[0]] = src_bytes.get(iv, _nbytes(iv.aval))
        if name == "dot_general":
            dims = eqn.params["dimension_numbers"]
            (lc, _rc), _ = dims
            lhs = eqn.invars[0].aval
            k = 1
            for d in lc:
                k *= lhs.shape[d]
            f = 2.0 * out_elems * k
            flops += f
            dot_flops += f
            in_real = sum(
                src_bytes.get(v, _nbytes(v.aval))
                for v in eqn.invars
                if hasattr(v, "aval")
            )
            byts += in_real + out_bytes
            byts_min += in_real + out_bytes
        elif name == "scan":
            length = eqn.params.get("length", 1)
            sub = jaxpr_cost(eqn.params["jaxpr"])
            flops += sub["flops"] * length
            dot_flops += sub["dot_flops"] * length
            byts += sub["bytes"] * length
            byts_min += sub["bytes_min"] * length
        elif name == "while":
            sub = jaxpr_cost(eqn.params["body_jaxpr"])
            flops += sub["flops"]
            dot_flops += sub["dot_flops"]
            byts += sub["bytes"]
            byts_min += sub["bytes_min"]
        elif name in ("cond",):
            best = dict(_ZERO)
            for b in eqn.params["branches"]:
                sub = jaxpr_cost(b)
                if sub["flops"] >= best["flops"]:
                    best = sub
            flops += best["flops"]
            dot_flops += best["dot_flops"]
            byts += best["bytes"]
            byts_min += best["bytes_min"]
        elif name in _EW:
            flops += out_elems
            byts += out_bytes
        elif name in _REDUCE or name.startswith("reduce_") or name.startswith("cum"):
            flops += sum(
                _nelems(v.aval) for v in eqn.invars if hasattr(v, "aval")
            )
            byts += in_bytes + out_bytes
            byts_min += in_bytes + out_bytes
        elif name in ("gather", "dynamic_slice"):
            # charge the MOVED bytes, not the whole source buffer (a scan
            # body slicing one layer from an (L, ...) stack reads one layer)
            byts += 2 * out_bytes
            byts_min += 2 * out_bytes
        elif name in ("scatter", "scatter-add", "scatter_add",
                      "dynamic_update_slice"):
            upd = (
                _nbytes(eqn.invars[-1].aval)
                if hasattr(eqn.invars[-1], "aval")
                else out_bytes
            )
            byts += 2 * upd
            byts_min += 2 * upd
        elif name in ("concatenate", "sort", "top_k"):
            byts += in_bytes + out_bytes
            byts_min += in_bytes + out_bytes
            if name in ("sort", "top_k"):
                n = max(out_elems, 1)
                flops += n * max(1, int(np.log2(n)))
        elif name in _MOVE:
            byts += out_bytes
        else:
            recursed = False
            for _, sub_j in _sub_jaxprs(eqn.params):
                sub = jaxpr_cost(sub_j)
                flops += sub["flops"]
                dot_flops += sub["dot_flops"]
                byts += sub["bytes"]
                byts_min += sub["bytes_min"]
                recursed = True
            if not recursed:
                byts += out_bytes
    return {"flops": flops, "bytes": byts, "bytes_min": byts_min,
            "dot_flops": dot_flops}


def fn_cost(fn, *args) -> Dict[str, float]:
    closed = jax.make_jaxpr(fn)(*args)
    cost = jaxpr_cost(closed)
    # top-level I/O: params/inputs read once, outputs written once
    io = sum(_nbytes(v.aval) for v in closed.jaxpr.invars) + sum(
        _nbytes(v.aval) for v in closed.jaxpr.outvars
    )
    cost["bytes"] += io
    cost["bytes_min"] += io
    return cost
