"""Serving launcher: batched decode on a selected architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 8 --max-new 16 [--reduced] [--prefill-chunk 16]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..models import count_params, init_params
from ..serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pool", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens per prefill launch (1 = per-token)")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg)
    params = init_params(cfg, seed=0)
    print(f"[serve] {cfg.name}: {count_params(params):,} params, "
          f"pool={args.pool}, max_len={args.max_len}, "
          f"prefill_chunk={args.prefill_chunk}")
    engine = ServeEngine(cfg, params, pool_size=args.pool,
                         max_len=args.max_len,
                         prefill_chunk=args.prefill_chunk)
    rng = np.random.RandomState(0)
    reqs = [
        Request(rid=i, prompt=rng.randint(1, cfg.vocab_size, size=rng.randint(4, 12)),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    ticks = 0
    # admit() parks overflow on the engine's wait queue; ticks drain it
    for r in reqs:
        engine.admit(r)
    while (engine.wait_queue or engine.active_slots) and ticks < 2000:
        engine.tick()
        ticks += 1
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens or []) for r in reqs)
    for r in reqs:
        print(f"[req {r.rid:3d}] prompt={len(r.prompt):3d} "
              f"new={len(r.out_tokens or []):3d} "
              f"wait={1e3 * (r.queue_wait_s or 0):7.1f}ms "
              f"ttft={1e3 * (r.ttft_s or 0):7.1f}ms "
              f"latency={1e3 * (r.latency_s or 0):7.1f}ms "
              f"tok/s={r.tokens_per_s or 0:6.1f}")
    st = engine.stats()
    print(f"[serve] {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print(f"[serve] launches: prefill={st['prefill_launches']} "
          f"(per-token would be {st['prefill_tokens']}), "
          f"decode={st['decode_launches']}; "
          f"decode_cache={st['decode_cache']}")


if __name__ == "__main__":
    main()
