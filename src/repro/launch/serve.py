"""Serving launcher: batched decode on a selected architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 8 --max-new 16 [--reduced] [--engine paged|slot] \
        [--block-size 16] [--num-blocks N] [--ttft-slo-ms 50]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..models import count_params, init_params
from ..serve import PagedServeEngine, Request, ServeEngine, SLOConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", choices=("paged", "slot"), default="paged",
                    help="paged = continuous batching over KV blocks "
                    "(default); slot = contiguous per-slot rings")
    ap.add_argument("--pool", type=int, default=4,
                    help="slot engine: batch slots; paged engine: decode "
                    "width (rows per batched launch)")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens per prefill launch (1 = per-token)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged engine: total KV blocks (default: "
                    "pool * ceil(ring/block_size), i.e. no memory pressure)")
    ap.add_argument("--ttft-slo-ms", type=float, default=None,
                    help="paged engine: prioritize prefill when a request's "
                    "projected TTFT would overrun this")
    ap.add_argument("--decode-slo-ms", type=float, default=None,
                    help="paged engine: force a decode launch when the gap "
                    "since the last one exceeds this")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg)
    params = init_params(cfg, seed=0)
    if args.engine == "paged":
        slo = None
        if args.ttft_slo_ms is not None or args.decode_slo_ms is not None:
            slo = SLOConfig(
                ttft_slo_s=(args.ttft_slo_ms / 1e3
                            if args.ttft_slo_ms is not None else None),
                decode_slo_s=(args.decode_slo_ms / 1e3
                              if args.decode_slo_ms is not None else None),
            )
        engine = PagedServeEngine(
            cfg, params, decode_width=args.pool, max_len=args.max_len,
            block_size=args.block_size, num_blocks=args.num_blocks,
            prefill_chunk=args.prefill_chunk, slo=slo,
        )
        kv = (f"blocks={engine.num_blocks}x{engine.block_size}"
              if engine.allocator is not None else "no-kv(ssm)")
        print(f"[serve] {cfg.name}: {count_params(params):,} params, "
              f"paged width={args.pool}, max_len={args.max_len}, {kv}, "
              f"prefill_chunk={args.prefill_chunk}")
    else:
        engine = ServeEngine(cfg, params, pool_size=args.pool,
                             max_len=args.max_len,
                             prefill_chunk=args.prefill_chunk)
        print(f"[serve] {cfg.name}: {count_params(params):,} params, "
              f"slot pool={args.pool}, max_len={args.max_len}, "
              f"prefill_chunk={args.prefill_chunk}")
    rng = np.random.RandomState(0)
    reqs = [
        Request(rid=i, prompt=rng.randint(1, cfg.vocab_size, size=rng.randint(4, 12)),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    # admit() parks overflow on the engine's FIFO wait queue; ticks drain it
    for r in reqs:
        engine.admit(r)
    remaining = engine.run_until_done(max_ticks=20_000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens or []) for r in reqs)
    for r in reqs:
        print(f"[req {r.rid:3d}] prompt={len(r.prompt):3d} "
              f"new={len(r.out_tokens or []):3d} "
              f"wait={1e3 * (r.queue_wait_s or 0):7.1f}ms "
              f"ttft={1e3 * (r.ttft_s or 0):7.1f}ms "
              f"latency={1e3 * (r.latency_s or 0):7.1f}ms "
              f"tok/s={r.tokens_per_s or 0:6.1f}")
    st = engine.stats()
    print(f"[serve] {sum(r.done for r in reqs)}/{len(reqs)} done "
          f"({remaining} unfinished), "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print(f"[serve] launches: prefill={st['prefill_launches']} "
          f"(per-token would be {st['prefill_tokens']}), "
          f"decode={st['decode_launches']}; "
          f"decode_cache={st['decode_cache']}")
    if "kv_blocks" in st:
        kv = st["kv_blocks"]
        print(f"[serve] kv blocks: peak={kv['peak_in_use']}/{kv['num_blocks']} "
              f"(util {kv['peak_utilization']:.2f}), "
              f"alloc={kv['allocated_total']} freed={kv['freed_total']} "
              f"preemptions={st['preemptions']} "
              f"max_inflight={st['max_inflight']}")


if __name__ == "__main__":
    main()
