"""Serving launcher: batched decode on a selected architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 8 --max-new 16 [--reduced]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..models import count_params, init_params
from ..serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pool", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg)
    params = init_params(cfg, seed=0)
    print(f"[serve] {cfg.name}: {count_params(params):,} params, "
          f"pool={args.pool}, max_len={args.max_len}")
    engine = ServeEngine(cfg, params, pool_size=args.pool, max_len=args.max_len)
    rng = np.random.RandomState(0)
    reqs = [
        Request(rid=i, prompt=rng.randint(1, cfg.vocab_size, size=rng.randint(4, 12)),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    pending = list(reqs)
    t0 = time.perf_counter()
    ticks = 0
    while (pending or any(r is not None for r in engine.slot_req)) and ticks < 2000:
        while pending and engine.admit(pending[0]):
            pending.pop(0)
        engine.tick()
        ticks += 1
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens or []) for r in reqs)
    print(f"[serve] {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
