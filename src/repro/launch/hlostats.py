"""Optimized-HLO statistics: collective-byte census with while-loop
trip-count scaling.

``compiled.cost_analysis()``/plain text grep count a ``while`` body ONCE,
but a scan-of-layers body executes L times.  This walker parses the HLO
module into computations, extracts each while's trip count from its
condition (induction var compared against a constant), and accumulates
collective result-bytes multiplied by the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-_]+)\s*\(.*\)\s*->.*{\s*$")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _bytes_of_segment(seg: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(seg):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for p in dims.split(","):
            if p:
                n *= int(p)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.strip()
        m = _COMP_HEAD.match(s)
        if m:
            cur = m.group(1).lstrip("%")
            comps[cur] = []
            if s.startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)
    return comps, entry


def _trip_count(cond_lines: List[str]) -> int:
    """Scan-lowered conditions compare the induction var to a constant."""
    const = None
    for ln in cond_lines:
        if "compare(" in ln and ("direction=LT" in ln or "direction=GT" in ln):
            pass
    for ln in cond_lines:
        m = _CONST_RE.search(ln)
        if m:
            v = int(m.group(1))
            const = v if const is None else max(const, v)
    return const if const else 1


def collective_bytes(hlo: str) -> Dict[str, float]:
    comps, entry = parse_computations(hlo)
    if entry is None and comps:
        entry = list(comps)[-1]

    def local_and_calls(name: str):
        coll: Dict[str, int] = {}
        calls: List[Tuple[str, int]] = []
        for ln in comps.get(name, ()):
            if "=" not in ln:
                continue
            for kind in _COLL_KINDS:
                tok = kind + "("
                idx = ln.find(tok)
                # guard: "-start(" variants
                if idx < 0:
                    idx2 = ln.find(kind + "-start(")
                    if idx2 >= 0:
                        idx = idx2
                        tok = kind + "-start("
                if idx < 0:
                    continue
                head = ln.split("=", 1)[1][: idx - ln.find("=") - 1]
                b = _bytes_of_segment(head)
                if b:
                    coll[kind] = coll.get(kind, 0) + b
                break
            if " while(" in ln or ln.startswith("while(") or "= while" in ln or re.search(r"\bwhile\(", ln):
                mb = re.search(r"body=(%?[\w\.\-_]+)", ln)
                mc = re.search(r"condition=(%?[\w\.\-_]+)", ln)
                if mb and mc:
                    trips = _trip_count(comps.get(mc.group(1).lstrip("%"), []))
                    calls.append((mb.group(1).lstrip("%"), trips))
            else:
                for key in ("calls=", "body=", "branch_computations={"):
                    if key in ln:
                        for nm in re.findall(r"(?:calls=|body=)(%?[\w\.\-_]+)", ln):
                            calls.append((nm.lstrip("%"), 1))
                        break
        return coll, calls

    memo: Dict[str, Dict[str, float]] = {}
    visiting = set()

    def volume(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in visiting:
            return {}
        visiting.add(name)
        coll, calls = local_and_calls(name)
        total = {k: float(v) for k, v in coll.items()}
        for callee, trips in calls:
            sub = volume(callee)
            for k, v in sub.items():
                total[k] = total.get(k, 0.0) + v * trips
        visiting.discard(name)
        memo[name] = total
        return total

    return volume(entry) if entry else {}
