import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes with ShapeDtypeStruct stand-ins (no allocation).

The two lines above MUST run before any other import (jax locks the device
count on first init).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]

Outputs per cell: memory_analysis (proves it fits), cost_analysis (FLOPs /
bytes for the roofline), and the collective-byte census parsed from the
optimized HLO — all persisted to experiments/dryrun/*.json, which
launch/roofline.py turns into EXPERIMENTS.md tables.
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHITECTURES, SHAPES, get_config
from ..distributed.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    params_shardings,
)
from ..models import decode_step, forward, init_cache, param_specs
from ..train import AdamWConfig, adamw_init_specs, make_train_step
from .mesh import make_production_mesh

# ----------------------------------------------------------------- specs
def _sds_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(tuple(x.shape), x.dtype), tree
    )


def input_specs(arch: str, shape_name: str, cfg=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell —
    weak-type-correct, shardable, no device allocation."""
    cfg = cfg or get_config(arch)
    sh = SHAPES[shape_name]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    i32 = jnp.int32
    d = cfg.d_model
    if kind in ("train", "prefill"):
        if cfg.family == "vlm":
            text = S - cfg.num_patches
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, text), i32),
                "labels": jax.ShapeDtypeStruct((B, text), i32),
                "patches": jax.ShapeDtypeStruct((B, cfg.num_patches, d), cfg.jax_dtype),
            }
        elif cfg.family == "audio":
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
                "frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, d), cfg.jax_dtype),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a seq_len cache
    cache = _sds_tree(
        jax.eval_shape(lambda: init_cache(cfg, B, S))
    )
    return {
        "tokens": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((B,), i32),
        "cache": cache,
    }


def cell_is_skipped(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return (
            "skipped: pure full-attention arch — 524k dense-attention decode "
            "is quadratic-cost with no sub-quadratic mechanism in this "
            "config (DESIGN.md §Arch-applicability)"
        )
    return None


# ------------------------------------------------------------- lowering
_STASH_BUDGET = 6e9  # target per-device remat-carry bytes for train cells


def auto_accum(cfg, B: int, S: int, mesh) -> int:
    """Gradient-accumulation steps so the per-device scan-carry stash
    (L x microbatch x S x d x 2B) fits the budget: microbatch shrinks to
    ~1 seq/device for the widest/deepest models."""
    from ..distributed.sharding import batch_axes, axis_size

    shards = axis_size(mesh, batch_axes(mesh, B)) or 1
    b_local = max(1, B // shards)
    stash_per_seq = cfg.num_layers * S * cfg.d_model * 2
    seqs = max(1, int(_STASH_BUDGET // max(stash_per_seq, 1)))
    accum = max(1, -(-b_local // seqs))        # ceil
    if cfg.family == "moe":
        # MoE dispatch tensors scale with microbatch tokens:
        # E*C*d ~ 1.25*k*T_micro*d; keep the f32 worst case under ~3 GB.
        disp = 1.25 * cfg.moe_top_k * B * S * cfg.d_model * 4
        accum = max(accum, -(-int(disp) // int(3e9)))
    accum = min(accum, b_local)
    while b_local % accum:
        accum += 1
    return min(accum, b_local)


def build_cell(arch: str, shape_name: str, mesh, accum_steps: int = 0):
    """Returns (jitted_fn, example_args, raw_fn) for the cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, kind = sh["global_batch"], sh["kind"]
    if kind == "train":
        # sequence-parallel residual stream: shards the remat stash
        cfg = dataclasses.replace(cfg, activation_sharding="sp")
    if kind == "prefill":
        # SP for prefill too: shards the (B, 32k, d) residual stream
        cfg = dataclasses.replace(cfg, activation_sharding="sp")
    if kind == "decode" and cfg.family != "ssm":
        # int8 KV cache (§Perf A2/A4): halves cache bandwidth + footprint
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if accum_steps == 0 and kind == "train":
        accum_steps = auto_accum(cfg, B, sh["seq_len"], mesh)
    accum_steps = max(1, accum_steps)
    specs = input_specs(arch, shape_name, cfg)
    pspecs = param_specs(cfg)
    pshard = params_shardings(pspecs, mesh)

    if kind == "train":
        ospecs = adamw_init_specs(pspecs)
        oshard = opt_state_shardings(ospecs, pshard, mesh)
        bshard = batch_shardings(specs, mesh, B)
        ocfg = AdamWConfig(total_steps=10000)
        step = make_train_step(cfg, ocfg, accum_steps=accum_steps)
        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        return fn, (pspecs, ospecs, specs), step

    if kind == "prefill":
        from jax.sharding import NamedSharding, PartitionSpec as P

        from ..distributed.sharding import batch_axes

        bshard = batch_shardings(specs, mesh, B)
        baxes = batch_axes(mesh, B)
        bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
        out_sh = NamedSharding(mesh, P(bspec, "model"))  # vocab-parallel

        def prefill(params, batch):
            # serving prefill: next-token logits for the LAST position only
            # (all-position logits are a training-loss construct); the
            # hidden-state constraint pins batch sharding through the layer
            # scan (GSPMD otherwise replicates the whole residual stream)
            from ..models import layers as mlayers

            hidden = forward(params, batch, cfg, return_hidden=True)
            hidden = jax.lax.with_sharding_constraint(
                hidden, P(bspec, None, None)
            )
            return mlayers.unembed(
                params["embed"], hidden[:, -1]
            ).astype(jnp.float32)

        fn = jax.jit(prefill, in_shardings=(pshard, bshard), out_shardings=out_sh)
        return fn, (pspecs, specs), prefill

    # decode
    cshard = cache_shardings(specs["cache"], mesh, B)
    bshard = batch_shardings(
        {"tokens": specs["tokens"], "pos": specs["pos"]}, mesh, B
    )

    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg)

    fn = jax.jit(
        serve_step,
        in_shardings=(pshard, cshard, bshard["tokens"], bshard["pos"]),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
    )
    return fn, (pspecs, specs["cache"], specs["tokens"], specs["pos"]), serve_step


_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b"
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-kind byte census of every collective op in the optimized HLO.

    The optimized-HLO printer elides operand types, so we size each
    collective by its RESULT shape(s) (the segment between '=' and the op
    name; tuples — e.g. all-to-all — contribute every element).  For
    all-reduce/all-to-all/collective-permute result bytes == operand bytes;
    for all-gather it is the (post-gather) wire volume each device receives;
    for reduce-scatter the result understates the input by the group size —
    acceptable for a relative roofline term and noted in EXPERIMENTS.md.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        head, _, rest = line.partition("=")
        # only count op definitions, not operand references on other lines
        idx = rest.find(m.group(0) + "(")
        if idx < 0:
            continue
        result_seg = rest[:idx]
        kind = m.group(1)
        total = 0
        for dm in _SHAPE_RE.finditer(result_seg):
            dt, dims = dm.group(1), dm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for p in dims.split(","):
                if p:
                    n *= int(p)
            total += n * _DTYPE_BYTES[dt]
        if total:
            out[kind] = out.get(kind, 0) + total
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             accum_steps: int = 0, verbose: bool = True) -> Dict[str, Any]:
    skip = cell_is_skipped(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16", "skip": skip}
    from . import hlostats
    from .costmodel import fn_cost

    mesh = make_production_mesh(multi_pod=multi_pod)
    sh = SHAPES[shape_name]
    if accum_steps == 0 and sh["kind"] == "train":
        cfg0 = dataclasses.replace(get_config(arch), activation_sharding="sp")
        accum_steps = auto_accum(cfg0, sh["global_batch"], sh["seq_len"], mesh)
    t0 = time.time()
    with mesh:
        fn, args, raw_fn = build_cell(arch, shape_name, mesh, accum_steps)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        jcost = fn_cost(raw_fn, *args)       # exact scan-aware logical cost
    coll = hlostats.collective_bytes(hlo)    # trip-count-scaled census
    coll_flat = collective_bytes_from_hlo(hlo)   # unscaled cross-check
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": int(np.prod(list(mesh.shape.values()))),
        # exact static (global logical) costs from the jaxpr walker
        "flops": float(jcost["flops"]),
        "dot_flops": float(jcost["dot_flops"]),
        "bytes_accessed": float(jcost["bytes"]),
        # XLA's own numbers (while bodies counted once — cross-check only)
        "xla_flops": float(cost.get("flops", 0.0)),
        "xla_bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_bytes_unscaled": coll_flat,
        "memory": {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "accum_steps": accum_steps,
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} x {shape_name}: "
              f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"coll={sum(coll.values()):.3e}B "
              f"temp={rec['memory']['temp_size_in_bytes']/2**30:.2f}GiB "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print("  memory_analysis:", rec["memory"])
        print("  cost_analysis(xla): flops=%.4e bytes=%.4e" % (rec["xla_flops"], rec["xla_bytes_accessed"]))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--accum-steps", type=int, default=0)  # 0 = auto
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = sorted(ARCHITECTURES) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                cells.append((arch, shape, mp))

    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {tag}")
            continue
        try:
            rec = run_cell(arch, shape, mp, args.accum_steps)
        except Exception as e:  # noqa: BLE001 — record the failure
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "error": repr(e)[:2000]}
            print(f"[FAIL] {tag}: {e}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
