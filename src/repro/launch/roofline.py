"""Roofline analysis from the dry-run artifacts (brief §Roofline).

Per (arch × shape) on the single-pod mesh, three terms in SECONDS:

    compute    = FLOPs / (chips × peak)         peak = 197 TF/s bf16 MXU
    memory     = bytes / (chips × 819 GB/s HBM)
    collective = collective_bytes / (chips × 50 GB/s ICI per link)

FLOPs/bytes come from the exact scan-aware jaxpr walker (global logical
costs — see launch/costmodel.py conventions); collective bytes come from the
trip-count-scaled optimized-HLO census.  The dominant term is the
bottleneck; MODEL_FLOPS = 6·N·D (train, dense), 6·N_active·D (MoE),
2·N·D (inference) and the MODEL_FLOPS/HLO_FLOPs ratio exposes
remat/redundancy waste.

    PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from ..configs import SHAPES, get_config
from ..core.latency import TPU_V5E, LatencyModel

# Derived from the single DeviceSpec in core/latency.py — these module
# names are kept for existing importers but no longer drift independently.
_MODEL = LatencyModel(TPU_V5E)
PEAK_FLOPS = TPU_V5E.peak_flops_bf16     # bf16 per chip
HBM_BW = TPU_V5E.hbm_bw                  # per chip
ICI_BW = TPU_V5E.ici_bw                  # per link


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    n = cfg.active_param_count_estimate()
    if kind == "train":
        return 6.0 * n * B * S
    if kind == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B        # decode: one token per sequence


def analyze(rec: Dict) -> Optional[Dict]:
    if "skip" in rec or "error" in rec:
        return None
    chips = rec["num_devices"]
    flops = rec["flops"]
    byts_hi = rec["bytes_accessed"]
    byts_lo = rec.get("bytes_min", byts_hi)
    byts = (byts_lo * byts_hi) ** 0.5 if byts_lo else byts_hi  # geo-mean est.
    coll = sum(rec.get("collective_bytes", {}).values())
    t_c = _MODEL.compute_time(flops, chips)
    t_m = _MODEL.memory_time(byts, chips)
    t_x = _MODEL.collective_time(coll, chips)
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"])
    bound = max(t_c, t_m, t_x)
    # roofline fraction: useful-model-FLOP time over the bound time
    useful_t = _MODEL.compute_time(mf, chips)
    return {
        **rec,
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_memory_lo_s": _MODEL.memory_time(byts_lo, chips),
        "t_memory_hi_s": _MODEL.memory_time(byts_hi, chips),
        "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": useful_t / bound if bound else 0.0,
    }


def _advice(row: Dict) -> str:
    d = row["dominant"]
    if d == "memory":
        if row["shape"].startswith("decode") or row["shape"].startswith("long"):
            return "decode is weight/cache-bandwidth bound: batch more requests per chip or quantize KV/weights"
        return "reduce activation re-reads: larger fused kernels (stitching), bf16 stash, fewer remat passes"
    if d == "compute":
        if row["useful_ratio"] < 0.6:
            return "compute includes remat recompute: relax remat policy / save dots"
        return "near compute roof: raise MXU utilization via tile-aligned shapes"
    return "collective-bound: overlap reduce-scatter with backward, compress grads, reorder sharding axes"


def build_table(dir_: str, mesh: str = "16x16") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh:
            continue
        rows.append(analyze(rec) or rec)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "MODEL_FLOPS | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skip" in r:
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — | "
                f"{r['skip'].split(':')[0]} |"
            )
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        out.append(
            "| {arch} | {shape} | {tc:.2e} s | {tm:.2e} s | {tx:.2e} s | "
            "**{dom}** | {mf:.2e} | {ur:.2f} | {rf:.3f} |".format(
                arch=r["arch"], shape=r["shape"], tc=r["t_compute_s"],
                tm=r["t_memory_s"], tx=r["t_collective_s"], dom=r["dominant"],
                mf=r["model_flops"], ur=r["useful_ratio"],
                rf=r["roofline_fraction"],
            )
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = build_table(args.dir, args.mesh)
    print(to_markdown(rows))
    print()
    for r in rows:
        if "dominant" in r:
            print(f"{r['arch']:>24s} x {r['shape']:<12s}: {_advice(r)}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
