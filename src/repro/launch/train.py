"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 100 --batch 8 --seq 64 [--reduced] [--ckpt-dir /path]

On a real TPU fleet this binary runs once per host (jax.distributed
initializes from the TPU environment); the mesh comes from
``make_production_mesh`` and every step is pjit-sharded by
``repro.distributed.sharding``.  On CPU (``--reduced``) it trains a reduced
config end-to-end with the identical code path minus the mesh.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, reduced_config
from ..data import SyntheticLM
from ..distributed.sharding import batch_shardings, params_shardings, opt_state_shardings
from ..models import count_params, init_params
from ..train import AdamWConfig, Trainer, TrainerConfig, adamw_init, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config (default off-TPU)")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--mesh", default=None,
                    help="data,model e.g. 16,16 (default: single device)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg)
        print(f"[train] reduced config for {args.arch} on {jax.default_backend()}")

    params = init_params(cfg, seed=0)
    print(f"[train] params: {count_params(params):,}")
    step_fn = make_train_step(
        cfg, AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                         total_steps=args.steps),
        accum_steps=args.accum,
    )

    if args.mesh:
        data, model = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((data, model), ("data", "model"))
        pshard = params_shardings(params, mesh)
        params = jax.device_put(params, pshard)
        opt = adamw_init(params)
        oshard = opt_state_shardings(opt, pshard, mesh)
        train_step = jax.jit(
            step_fn, in_shardings=(pshard, oshard, None),
            out_shardings=(pshard, oshard, None), donate_argnums=(0, 1),
        )
    else:
        train_step = jax.jit(step_fn, donate_argnums=(0, 1))

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=50)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    trainer = Trainer(
        cfg, ocfg, tcfg,
        lambda start: SyntheticLM(cfg, args.seq, args.batch, seed=0).iterate(start),
        ckpt, train_step=train_step,
    )
    params, _, step = trainer.run(params)
    losses = [h["loss"] for h in trainer.history]
    if losses:
        print(f"[train] done at step {step}; loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
