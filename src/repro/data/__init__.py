from .pipeline import PrefetchIterator, SyntheticLM, make_data_iterator
