"""Deterministic synthetic LM data pipeline.

Deterministic per (seed, step, shard) — a restart at step k regenerates
exactly the batch a failed run would have seen (the checkpoint stores only
the step cursor, and resume is bit-exact; tests/test_fault_tolerance.py
asserts this).  Host-sharded: each data-parallel host materializes only its
slice.  A background thread prefetches ``prefetch`` batches ahead.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np


class SyntheticLM:
    """Markov-ish token stream with a learnable structure (loss can go
    well below uniform): token t+1 = (a * t + noise) % vocab."""

    def __init__(self, cfg, seq_len: int, global_batch: int, seed: int = 0,
                 shard: int = 0, num_shards: int = 1):
        assert global_batch % num_shards == 0
        self.cfg = cfg
        self.seq_len = seq_len
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard = shard

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 977 + self.shard) % (2 ** 31)
        )
        V = cfg.vocab_size
        B, S = self.local_batch, self.seq_len
        start = rng.randint(0, V, size=(B, 1))
        steps = rng.randint(1, 7, size=(B, 1))
        pos = np.arange(S + 1)[None, :]
        stream = (start + steps * pos + (pos ** 2 % 3)) % min(V, 4096)
        tokens = stream[:, :-1].astype(np.int32)
        labels = stream[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if cfg.family == "vlm":
            out["patches"] = rng.randn(B, cfg.num_patches, cfg.d_model).astype(
                np.float32
            ) * 0.02
        if cfg.family == "audio":
            out["frames"] = rng.randn(B, cfg.encoder_seq, cfg.d_model).astype(
                np.float32
            ) * 0.02
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch (overlaps host data gen with device step)."""

    def __init__(self, source: Iterator, prefetch: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._src = source
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._src:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_data_iterator(cfg, seq_len: int, global_batch: int, seed: int = 0,
                       shard: int = 0, num_shards: int = 1,
                       start_step: int = 0, prefetch: int = 2):
    src = SyntheticLM(cfg, seq_len, global_batch, seed, shard, num_shards)
    return PrefetchIterator(src.iterate(start_step), prefetch)
