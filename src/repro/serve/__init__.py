from .base import BaseEngine
from .engine import (
    PagedServeEngine,
    Request,
    ServeEngine,
    decode_cache_size,
    decode_cache_stats,
)
from .paged import BlockAllocator, blocks_for_tokens
from .scheduler import Scheduler, SLOConfig
from .traffic import TraceConfig, TraceEntry, TrafficReport, generate_trace, run_trace
