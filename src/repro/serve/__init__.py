from .engine import Request, ServeEngine, decode_cache_size, decode_cache_stats
