"""Batched serving engine: prefill + decode with KV/SSM caches and
continuous slot-based batching.

The engine keeps a fixed pool of batch slots.  A request claims a free
slot, is prefilled (token-by-token through the shared batched decode step
with a write mask so other slots are untouched), then every ``tick`` runs
ONE batched decode step for the whole pool with per-slot positions.  New
requests join between ticks — continuous batching without recompilation
(pool size and max_len are static).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


# Jitted decode steps are shared across engines with the same (config, pool)
# — the serving-layer analogue of the compiler's fusion-signature kernel
# dedup: N replica engines trace/compile the hot-path function once.
_DECODE_CACHE: Dict[Tuple[str, int], Callable] = {}


def _decode_fn(cfg, pool_size: int) -> Tuple[Callable, bool]:
    key = (repr(cfg), pool_size)
    hit = key in _DECODE_CACHE
    if not hit:
        _DECODE_CACHE[key] = jax.jit(
            lambda p, c, t, pos, act: decode_step(p, c, t, pos, cfg, act)
        )
    return _DECODE_CACHE[key], hit


def decode_cache_size() -> int:
    return len(_DECODE_CACHE)


class ServeEngine:
    def __init__(self, cfg, params, pool_size: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.pool = pool_size
        self.max_len = max_len
        self.cache = init_cache(cfg, pool_size, max_len)
        self.slot_req: List[Optional[Request]] = [None] * pool_size
        self.slot_pos = np.zeros(pool_size, np.int32)
        self.slot_remaining = np.zeros(pool_size, np.int32)
        self.slot_last = np.zeros(pool_size, np.int32)
        self._decode, self.decode_cache_hit = _decode_fn(cfg, pool_size)
        self.ticks = 0
        self.tokens_generated = 0
        self.requests_completed = 0

    @property
    def active_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is not None]

    # ------------------------------------------------------------ admit
    def admit(self, req: Request) -> bool:
        for s in range(self.pool):
            if self.slot_req[s] is None:
                self.slot_req[s] = req
                req.out_tokens = []
                self._prefill(s, req)
                return True
        return False

    def _prefill(self, slot: int, req: Request):
        toks = req.prompt.astype(np.int32)
        active = np.zeros(self.pool, bool)
        active[slot] = True
        logits = None
        for i, t in enumerate(toks):
            tok_vec = np.zeros(self.pool, np.int32)
            tok_vec[slot] = t
            pos = self.slot_pos.copy()
            pos[slot] = i
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tok_vec),
                jnp.asarray(pos), jnp.asarray(active),
            )
        self.slot_pos[slot] = len(toks)
        self.slot_remaining[slot] = req.max_new_tokens
        nxt = int(np.argmax(np.asarray(logits)[slot, : self.cfg.vocab_size]))
        req.out_tokens.append(nxt)
        self.slot_last[slot] = nxt
        self.slot_remaining[slot] -= 1
        self.tokens_generated += 1
        if self.slot_remaining[slot] <= 0:
            req.done = True
            self.slot_req[slot] = None
            self.requests_completed += 1

    # ------------------------------------------------------------- tick
    def tick(self):
        """One batched decode step for all active slots (per-slot pos)."""
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return
        toks = self.slot_last.copy()
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.slot_pos), jnp.asarray(active),
        )
        arr = np.asarray(logits)
        for s in np.nonzero(active)[0]:
            r = self.slot_req[s]
            nxt = int(np.argmax(arr[s, : self.cfg.vocab_size]))
            r.out_tokens.append(nxt)
            self.slot_last[s] = nxt
            self.slot_pos[s] += 1
            self.slot_remaining[s] -= 1
            self.tokens_generated += 1
            if self.slot_remaining[s] <= 0 or self.slot_pos[s] >= self.max_len - 1:
                r.done = True
                self.slot_req[s] = None
                self.requests_completed += 1
        self.ticks += 1

    def run_until_done(self, max_ticks: int = 2000):
        t = 0
        while any(r is not None for r in self.slot_req) and t < max_ticks:
            self.tick()
            t += 1
