"""Batched serving engines: prefill + decode with KV/SSM caches and
continuous batching.

Two engines share the jitted decode substrate:

* :class:`ServeEngine` — the contiguous **slot-ring** engine: a fixed pool
  of batch slots, each with a reserved ``max_len`` KV ring.  Prefill runs
  synchronously inside ``admit`` (in token chunks); every ``tick`` is one
  batched decode step.  Kept as the token-parity oracle — it is the
  simplest thing that is correct.

* :class:`PagedServeEngine` — the **paged continuous-batching** engine:
  KV lives in fixed-size blocks handed out by a free-list
  :class:`~repro.serve.paged.BlockAllocator`; requests own block tables,
  not slots, so concurrency is bounded by *actual* context footprint
  instead of worst-case ``pool_size * max_len`` reservation.  A
  :class:`~repro.serve.scheduler.Scheduler` admits and retires requests
  every step and interleaves batched prefill chunks with decode batches
  under a TTFT/latency SLO budget; block exhaustion preempts the
  latest-admitted request (freed blocks + front-of-queue requeue, resumed
  by recomputation — greedy decode makes the resumed token stream
  identical).

Greedy sampling happens INSIDE the jitted step for both engines: each
launch returns a ``(pool,)`` int32 token vector, not ``(pool, vocab)``
logits — the per-token device→host transfer on the decode hot path is a
handful of ints.

Admission validates prompts: empty prompts are rejected outright, and
prompts that would scatter past the KV capacity (``len(prompt) >
max_len - 1``) are rejected instead of silently corrupting the cache.
Each rejected request is counted once, however many times a retry loop
re-submits it.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_chunk, decode_step, init_cache, init_paged_cache
from .paged import BlockAllocator, blocks_for_tokens
from .scheduler import (
    DECODE_ACTION,
    PREFILL,
    PREFILL_ACTION,
    RUNNING,
    Scheduler,
    SLOConfig,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False
    # per-request latency/throughput accounting (perf_counter stamps)
    t_submit: Optional[float] = None   # first admit() attempt (queue entry)
    t_admit: Optional[float] = None    # slot claimed, prefill started
    t_first: Optional[float] = None    # first generated token (TTFT end)
    t_done: Optional[float] = None     # request finished

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token, from submission (includes queue wait)."""
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        if not self.out_tokens or self.latency_s in (None, 0.0):
            return None
        return len(self.out_tokens) / self.latency_s


# Jitted decode steps are shared across engines with the same
# (config, pool[, chunk]) — the serving-layer analogue of the compiler's
# fusion-signature kernel dedup: N replica engines trace/compile each
# hot-path function once.  LRU-bounded: a long-lived server process cycling
# through configs/pool sizes must not grow this without limit.
_DECODE_CACHE: "OrderedDict[Tuple, Callable]" = OrderedDict()
_DECODE_CACHE_CAP = 8
_DECODE_CACHE_EVICTIONS = 0


def _cached_jit(key: Tuple, build: Callable[[], Callable]) -> Tuple[Callable, bool]:
    global _DECODE_CACHE_EVICTIONS
    hit = key in _DECODE_CACHE
    if hit:
        _DECODE_CACHE.move_to_end(key)
    else:
        _DECODE_CACHE[key] = build()
        while len(_DECODE_CACHE) > _DECODE_CACHE_CAP:
            _DECODE_CACHE.popitem(last=False)   # evict least-recently-used
            _DECODE_CACHE_EVICTIONS += 1
    return _DECODE_CACHE[key], hit


def _greedy(logits, cfg):
    """Greedy sampling INSIDE the jitted step: ships a (B,) int32 vector
    to the host instead of (B, padded_vocab) f32 logits every launch."""
    return jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)


def _decode_fn(cfg, pool_size: int) -> Tuple[Callable, bool]:
    def build():
        def fn(p, c, t, pos, act):
            logits, c2 = decode_step(p, c, t, pos, cfg, act)
            return _greedy(logits, cfg), c2

        return jax.jit(fn)

    return _cached_jit(("step", repr(cfg), pool_size), build)


def _decode_chunk_fn(cfg, pool_size: int, chunk: int) -> Tuple[Callable, bool]:
    def build():
        def fn(p, c, t, pos, act, lens):
            logits, c2 = decode_chunk(p, c, t, pos, cfg, act, lens)
            return _greedy(logits, cfg), c2

        return jax.jit(fn)

    return _cached_jit(("chunk", repr(cfg), pool_size, chunk), build)


def _paged_decode_fn(cfg, width: int, ring: int, block_size: int,
                     num_blocks: int) -> Tuple[Callable, bool]:
    def build():
        def fn(p, c, t, pos, act, bt):
            logits, c2 = decode_step(p, c, t, pos, cfg, act, bt, ring)
            return _greedy(logits, cfg), c2

        return jax.jit(fn)

    return _cached_jit(
        ("paged_step", repr(cfg), width, ring, block_size, num_blocks), build
    )


def _paged_chunk_fn(cfg, width: int, chunk: int, ring: int, block_size: int,
                    num_blocks: int) -> Tuple[Callable, bool]:
    def build():
        def fn(p, c, t, pos, act, lens, bt):
            logits, c2 = decode_chunk(p, c, t, pos, cfg, act, lens, bt, ring)
            return _greedy(logits, cfg), c2

        return jax.jit(fn)

    return _cached_jit(
        ("paged_chunk", repr(cfg), width, chunk, ring, block_size, num_blocks),
        build,
    )


def decode_cache_size() -> int:
    return len(_DECODE_CACHE)


def decode_cache_stats() -> Dict[str, int]:
    return {
        "size": len(_DECODE_CACHE),
        "cap": _DECODE_CACHE_CAP,
        "evictions": _DECODE_CACHE_EVICTIONS,
    }


class _ValidationMixin:
    """Prompt validation + once-per-request rejection accounting, shared by
    both engines."""

    def _init_validation(self):
        self.requests_rejected = 0
        self._rejected_ids: set = set()
        self._rejected_refs: List[Request] = []   # pin ids against reuse

    def _count_rejection(self, req: Request) -> None:
        # retrying admit() with the same invalid request must not inflate
        # the counter: one rejected request == one rejection
        if id(req) not in self._rejected_ids:
            self._rejected_ids.add(id(req))
            self._rejected_refs.append(req)
            self.requests_rejected += 1

    def _validate(self, req: Request) -> None:
        n = len(req.prompt)
        if n == 0:
            self._count_rejection(req)
            raise ValueError(
                f"request {req.rid}: empty prompt — there is no position to "
                "decode from; send at least one (e.g. BOS) token"
            )
        if n > self.max_len - 1:
            self._count_rejection(req)
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds the KV cache "
                f"(max_len={self.max_len}, limit {self.max_len - 1}) — it "
                "would silently wrap the ring and corrupt earlier positions"
            )


def _run_until_done(engine, max_ticks: int, strict: bool) -> int:
    t = 0
    while engine.busy and t < max_ticks:
        engine.tick()
        t += 1
    remaining = engine.unfinished_requests
    if remaining:
        msg = (
            f"run_until_done stopped at max_ticks={max_ticks} with "
            f"{remaining} request(s) still in flight or queued — the run "
            f"is TRUNCATED, not complete"
        )
        if strict:
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
    return remaining


class ServeEngine(_ValidationMixin):
    """The contiguous slot-ring engine (token-parity oracle)."""

    def __init__(self, cfg, params, pool_size: int = 4, max_len: int = 512,
                 prefill_chunk: int = 16):
        self.cfg = cfg
        self.params = params
        self.pool = pool_size
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.cache = init_cache(cfg, pool_size, max_len)
        self.slot_req: List[Optional[Request]] = [None] * pool_size
        self.slot_pos = np.zeros(pool_size, np.int32)
        self.slot_remaining = np.zeros(pool_size, np.int32)
        self.slot_last = np.zeros(pool_size, np.int32)
        self._decode, self.decode_cache_hit = _decode_fn(cfg, pool_size)
        self._decode_chunk = None
        if self.prefill_chunk > 1:
            self._decode_chunk, _ = _decode_chunk_fn(
                cfg, pool_size, self.prefill_chunk
            )
        self.wait_queue: "deque[Request]" = deque()
        self.ticks = 0
        self.tokens_generated = 0
        self.requests_completed = 0
        self._init_validation()
        self.prefill_launches = 0        # decode calls spent on prefill
        self.prefill_tokens = 0          # prompt tokens prefilled
        self.decode_launches = 0         # batched tick decode calls

    @property
    def active_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is not None]

    @property
    def inflight(self) -> int:
        """Requests currently holding cache state (occupied slots)."""
        return sum(r is not None for r in self.slot_req)

    @property
    def busy(self) -> bool:
        return bool(self.wait_queue) or self.inflight > 0

    @property
    def unfinished_requests(self) -> int:
        return len(self.wait_queue) + self.inflight

    def stats(self) -> Dict[str, object]:
        """Serving counters: launch accounting + queue depth.

        ``prefill_launches`` vs ``prefill_tokens`` is the chunked-prefill
        win: the per-token loop would spend one launch per prompt token.
        """
        return {
            "ticks": self.ticks,
            "tokens_generated": self.tokens_generated,
            "requests_completed": self.requests_completed,
            "requests_rejected": self.requests_rejected,
            "prefill_launches": self.prefill_launches,
            "prefill_tokens": self.prefill_tokens,
            "decode_launches": self.decode_launches,
            "prefill_chunk": self.prefill_chunk,
            "queue_depth": len(self.wait_queue),
            "decode_cache": decode_cache_stats(),
        }

    # ------------------------------------------------------------ admit
    def admit(self, req: Request) -> bool:
        """Place ``req`` in a free slot (True) or park it on the FIFO wait
        queue (False — it is NOT dropped; ticks drain the queue as slots
        free up).  Invalid prompts raise ValueError and are never queued.
        """
        self._validate(req)
        # retry-loop callers (`while pending and admit(pending[0])`) may
        # re-admit a request that is already generating in a slot or
        # already finished — never place or queue those again, or a done
        # request would be re-prefilled and re-generated
        if req.done or any(r is req for r in self.slot_req):
            return False
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        # FIFO fairness + no double-placement: queued requests claim freed
        # slots before this one (draining also places req itself if it was
        # already at the front of the queue)
        self._drain_queue()
        if any(r is req for r in self.slot_req):
            return True
        for s in range(self.pool):
            if self.slot_req[s] is None:
                self._place(s, req)
                return True
        if not any(q is req for q in self.wait_queue):
            self.wait_queue.append(req)
        return False

    def _place(self, slot: int, req: Request) -> None:
        self.slot_req[slot] = req
        req.out_tokens = []
        req.t_admit = time.perf_counter()
        self._reset_slot_state(slot)
        self._prefill(slot, req)

    def _reset_slot_state(self, slot: int) -> None:
        """Zero the per-slot recurrent state before a new occupant.

        Attention KV needs no reset — the length mask hides stale rows —
        but SSM/conv state is UNMASKED recurrent carry: without this, a
        mamba/hybrid slot leaks the previous request's state into the next
        one (wrong tokens on every slot reuse)."""
        if "mamba" in self.cache:
            self.cache["mamba"] = jax.tree.map(
                lambda a: a.at[:, slot].set(0), self.cache["mamba"]
            )

    def _drain_queue(self) -> None:
        while self.wait_queue:
            head = self.wait_queue[0]
            if head.done or any(r is head for r in self.slot_req):
                self.wait_queue.popleft()   # stale entry — never re-place
                continue
            free = next(
                (s for s, r in enumerate(self.slot_req) if r is None), None
            )
            if free is None:
                return
            self._place(free, self.wait_queue.popleft())

    # ---------------------------------------------------------- prefill
    def _prefill(self, slot: int, req: Request):
        toks = np.asarray(req.prompt).astype(np.int32)
        if self.prefill_chunk > 1:
            out_toks = self._prefill_chunked(slot, toks)
        else:
            out_toks = self._prefill_per_token(slot, toks)
        self.prefill_tokens += len(toks)
        self.slot_pos[slot] = len(toks)
        self.slot_remaining[slot] = req.max_new_tokens
        nxt = int(np.asarray(out_toks)[slot])
        req.out_tokens.append(nxt)
        req.t_first = time.perf_counter()
        self.slot_last[slot] = nxt
        self.slot_remaining[slot] -= 1
        self.tokens_generated += 1
        # same stop rule as tick: out of budget, or the next decode write
        # would land past the KV ring
        if (
            self.slot_remaining[slot] <= 0
            or self.slot_pos[slot] >= self.max_len - 1
        ):
            self._finish(slot)

    def _prefill_chunked(self, slot: int, toks: np.ndarray):
        """One masked batched decode launch per ``prefill_chunk`` tokens."""
        C = self.prefill_chunk
        active = np.zeros(self.pool, bool)
        active[slot] = True
        out = None
        for start in range(0, len(toks), C):
            part = toks[start:start + C]
            tok_mat = np.zeros((self.pool, C), np.int32)
            tok_mat[slot, : len(part)] = part
            lengths = np.zeros(self.pool, np.int32)
            lengths[slot] = len(part)
            pos = self.slot_pos.copy()
            pos[slot] = start
            out, self.cache = self._decode_chunk(
                self.params, self.cache, jnp.asarray(tok_mat),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(lengths),
            )
            self.prefill_launches += 1
        return out

    def _prefill_per_token(self, slot: int, toks: np.ndarray):
        """The chunk-size-1 oracle: one decode launch per prompt token."""
        active = np.zeros(self.pool, bool)
        active[slot] = True
        out = None
        for i, t in enumerate(toks):
            tok_vec = np.zeros(self.pool, np.int32)
            tok_vec[slot] = t
            pos = self.slot_pos.copy()
            pos[slot] = i
            out, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tok_vec),
                jnp.asarray(pos), jnp.asarray(active),
            )
            self.prefill_launches += 1
        return out

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done = True
        req.t_done = time.perf_counter()
        self.slot_req[slot] = None
        self.requests_completed += 1

    # ------------------------------------------------------------- tick
    def tick(self) -> None:
        """Drain the wait queue into free slots, then one batched decode
        step for all active slots (per-slot pos)."""
        self._drain_queue()
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return
        toks = self.slot_last.copy()
        out, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.slot_pos), jnp.asarray(active),
        )
        self.decode_launches += 1
        arr = np.asarray(out)
        for s in np.nonzero(active)[0]:
            r = self.slot_req[s]
            nxt = int(arr[s])
            r.out_tokens.append(nxt)
            self.slot_last[s] = nxt
            self.slot_pos[s] += 1
            self.slot_remaining[s] -= 1
            self.tokens_generated += 1
            if self.slot_remaining[s] <= 0 or self.slot_pos[s] >= self.max_len - 1:
                self._finish(s)
        self.ticks += 1

    def run_until_done(self, max_ticks: int = 2000, strict: bool = False) -> int:
        """Tick until idle or ``max_ticks``.  Returns the number of
        requests still unfinished (0 == complete); a truncated run warns,
        or raises RuntimeError with ``strict=True`` — harnesses must not
        mistake a truncated run for a completed one."""
        return _run_until_done(self, max_ticks, strict)


# ======================================================================
# paged continuous batching
# ======================================================================
@dataclasses.dataclass
class _Row:
    """One decode-batch row of the paged engine (NOT a KV reservation —
    KV lives in blocks owned by the request's block table)."""
    req: Optional[Request] = None
    state: str = ""
    ctx: Optional[np.ndarray] = None   # tokens to feed: prompt [+ resumed out]
    fed: int = 0                       # prefill progress into ctx
    pos: int = 0                       # next absolute write position
    blocks: List[int] = dataclasses.field(default_factory=list)
    last_tok: int = 0
    remaining: int = 0
    admit_seq: int = -1


class PagedServeEngine(_ValidationMixin):
    """Continuous batching over paged KV memory.

    ``decode_width`` is the batched-launch width (how many requests decode
    per tick); KV memory is ``num_blocks * block_size`` tokens TOTAL,
    shared by every in-flight request through per-request block tables.
    With the same KV budget as a slot engine (``pool * max_len`` tokens),
    short-context traffic sustains many times ``pool`` in-flight requests.
    """

    def __init__(self, cfg, params, decode_width: int = 16,
                 max_len: int = 512, block_size: int = 16,
                 num_blocks: Optional[int] = None, prefill_chunk: int = 16,
                 slo: Optional[SLOConfig] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if decode_width <= 0:
            raise ValueError(f"decode_width must be positive, got {decode_width}")
        self.cfg = cfg
        self.params = params
        self.width = decode_width
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self._clock = clock
        # logical ring capacity in tokens (sliding-window archs reuse
        # blocks cyclically past the window) — same formula as init_cache
        self.kv_ring = (
            max_len if not cfg.sliding_window
            else min(cfg.sliding_window, max_len)
        )
        self.needs_kv = cfg.family != "ssm"
        self.block_size = block_size
        self.blocks_per_req = (
            blocks_for_tokens(self.kv_ring, block_size, self.kv_ring)
            if self.needs_kv else 0
        )
        if not self.needs_kv:
            num_blocks = 0
        elif num_blocks is None:
            # no-pressure default: worst case for every row
            num_blocks = decode_width * self.blocks_per_req
        if self.needs_kv and num_blocks < self.blocks_per_req:
            raise ValueError(
                f"num_blocks={num_blocks} cannot hold even one max-length "
                f"context ({self.blocks_per_req} blocks of {block_size}) — "
                "a lone request could deadlock"
            )
        self.num_blocks = num_blocks
        self.allocator = (
            BlockAllocator(num_blocks, block_size) if self.needs_kv else None
        )
        self.cache = init_paged_cache(cfg, num_blocks, block_size, decode_width)
        # logical->physical tables, parking-filled (physical id num_blocks)
        self._parking = num_blocks
        self._table = np.full(
            (decode_width, max(1, self.blocks_per_req)), self._parking,
            np.int32,
        )
        self.rows = [_Row() for _ in range(decode_width)]
        self.sched = Scheduler(slo, clock)
        self._decode, self.decode_cache_hit = _paged_decode_fn(
            cfg, decode_width, self.kv_ring, block_size, num_blocks
        )
        self._chunk, _ = _paged_chunk_fn(
            cfg, decode_width, self.prefill_chunk, self.kv_ring, block_size,
            num_blocks,
        )
        self._admit_seq = 0
        self.ticks = 0
        self.tokens_generated = 0
        self.requests_completed = 0
        self._init_validation()
        self.prefill_launches = 0
        self.prefill_tokens = 0
        self.decode_launches = 0
        self.max_inflight = 0
        self._inflight_ticks = 0
        self._util_ticks = 0.0

    # ------------------------------------------------------- properties
    @property
    def inflight(self) -> int:
        """Requests currently holding rows/blocks (prefilling or running)."""
        return sum(r.req is not None for r in self.rows)

    @property
    def busy(self) -> bool:
        return bool(self.sched.waiting) or self.inflight > 0

    @property
    def unfinished_requests(self) -> int:
        return len(self.sched.waiting) + self.inflight

    @property
    def wait_queue(self):
        """Launcher compatibility: the scheduler's FIFO wait queue."""
        return self.sched.waiting

    def stats(self) -> Dict[str, object]:
        st: Dict[str, object] = {
            "ticks": self.ticks,
            "tokens_generated": self.tokens_generated,
            "requests_completed": self.requests_completed,
            "requests_rejected": self.requests_rejected,
            "prefill_launches": self.prefill_launches,
            "prefill_tokens": self.prefill_tokens,
            "decode_launches": self.decode_launches,
            "prefill_chunk": self.prefill_chunk,
            "decode_width": self.width,
            "queue_depth": len(self.sched.waiting),
            "inflight": self.inflight,
            "max_inflight": self.max_inflight,
            "mean_inflight": self._inflight_ticks / max(1, self.ticks),
            "preemptions": self.sched.preemptions,
            "scheduler": self.sched.stats(),
            "decode_cache": decode_cache_stats(),
        }
        if self.allocator is not None:
            kv = self.allocator.stats()
            kv["mean_utilization"] = self._util_ticks / max(1, self.ticks)
            st["kv_blocks"] = kv
        return st

    # ------------------------------------------------------------ admit
    def admit(self, req: Request) -> bool:
        """Asynchronous admission: True == the request owns a decode row
        and will prefill over the next ticks; False == parked on the FIFO
        wait queue (never dropped).  No model launch happens here — prefill
        is the scheduler's job, interleaved with decode under the SLO."""
        self._validate(req)
        if req.done or any(r.req is req for r in self.rows):
            return False
        if req.t_submit is None:
            req.t_submit = self._clock()
        self._admit_from_queue()
        if any(r.req is req for r in self.rows):
            return True
        # FIFO: nobody overtakes a still-backed-up queue
        if not self.sched.waiting and self._try_place(req):
            return True
        if not any(q is req for q in self.sched.waiting):
            self.sched.enqueue(req)
        return False

    def _admit_from_queue(self) -> None:
        while self.sched.waiting:
            head = self.sched.waiting[0]
            if head.done or any(r.req is head for r in self.rows):
                self.sched.waiting.popleft()   # stale entry
                continue
            if not self._try_place(head):
                return                         # head-of-line blocks: FIFO
            self.sched.waiting.popleft()

    def _try_place(self, req: Request) -> bool:
        free_row = next(
            (i for i, r in enumerate(self.rows) if r.req is None), None
        )
        if free_row is None:
            return False
        ctx_len = len(req.prompt) + len(req.out_tokens or ())
        if self.allocator is not None:
            needed = blocks_for_tokens(ctx_len, self.block_size, self.kv_ring)
            # admission gate: the whole context must fit in FREE blocks now,
            # or prefill would immediately preempt someone (churn)
            if not self.allocator.can_alloc(needed):
                return False
        self._place_row(free_row, req)
        return True

    def _place_row(self, idx: int, req: Request) -> None:
        row = self.rows[idx]
        if req.out_tokens is None:
            req.out_tokens = []
        if req.t_admit is None:
            req.t_admit = self._clock()
        # resumed-after-preemption requests re-feed prompt + everything
        # already emitted (recompute preemption): greedy decode makes the
        # continuation token-identical to the uninterrupted run
        row.req = req
        row.state = PREFILL
        row.ctx = np.concatenate(
            [np.asarray(req.prompt, np.int32).ravel(),
             np.asarray(req.out_tokens, np.int32)]
        ).astype(np.int32)
        row.fed = 0
        row.pos = 0
        row.blocks = []
        row.last_tok = 0
        row.remaining = req.max_new_tokens - len(req.out_tokens)
        row.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.sched.admitted += 1
        self._table[idx, :] = self._parking
        self._reset_row_state(idx)

    def _reset_row_state(self, idx: int) -> None:
        """Zero per-row recurrent (SSM/conv) state for a new occupant —
        the unmasked carry would otherwise leak across requests."""
        if "mamba" in self.cache:
            self.cache["mamba"] = jax.tree.map(
                lambda a: a.at[:, idx].set(0), self.cache["mamba"]
            )

    # ------------------------------------------------------ block paging
    def _ensure_blocks(self, idx: int, tokens_upto: int) -> None:
        """Grow ``idx``'s block table to cover ``tokens_upto`` context
        tokens, preempting the latest-admitted other request on exhaustion
        (eager-release + recompute, vLLM-style)."""
        if self.allocator is None:
            return
        row = self.rows[idx]
        needed = blocks_for_tokens(tokens_upto, self.block_size, self.kv_ring)
        while len(row.blocks) < needed:
            got = self.allocator.alloc(needed - len(row.blocks))
            if got is None:
                if not self._preempt_latest(exclude=idx):
                    raise RuntimeError(
                        "KV block pool exhausted with nothing left to "
                        "preempt — num_blocks < blocks_per_req?"
                    )
                continue
            start = len(row.blocks)
            row.blocks.extend(got)
            self._table[idx, start:start + len(got)] = got

    def _preempt_latest(self, exclude: int) -> bool:
        victims = [
            (self.rows[i].admit_seq, i)
            for i, r in enumerate(self.rows)
            if r.req is not None and i != exclude
        ]
        if not victims:
            return False
        _, idx = max(victims)
        self._preempt(idx)
        return True

    def _preempt(self, idx: int) -> None:
        row = self.rows[idx]
        req = row.req
        if self.allocator is not None and row.blocks:
            self.allocator.free(row.blocks)
        self._clear_row(idx)
        # front of the queue: FIFO by submission survives preemption
        self.sched.requeue_front(req)

    def _clear_row(self, idx: int) -> None:
        self.rows[idx] = _Row()
        self._table[idx, :] = self._parking

    # ------------------------------------------------------------- tick
    def tick(self) -> None:
        """One scheduler step: retire/admit, then ONE batched launch —
        a prefill chunk for every prefilling row, or a decode step for
        every running row — picked under the SLO budget."""
        self._admit_from_queue()
        prefill_rows = [
            i for i, r in enumerate(self.rows)
            if r.req is not None and r.state == PREFILL
        ]
        running_rows = [
            i for i, r in enumerate(self.rows)
            if r.req is not None and r.state == RUNNING
        ]
        oldest_wait = None
        chunks_rem = 0
        if prefill_rows:
            oldest = min(prefill_rows, key=lambda i: self.rows[i].admit_seq)
            r = self.rows[oldest]
            now = self._clock()
            oldest_wait = now - (r.req.t_submit if r.req.t_submit is not None
                                 else now)
            chunks_rem = -(-(len(r.ctx) - r.fed) // self.prefill_chunk)
        action = self.sched.choose(
            len(prefill_rows), len(running_rows), oldest_wait, chunks_rem
        )
        if action == PREFILL_ACTION:
            self._prefill_launch(prefill_rows)
        elif action == DECODE_ACTION:
            self._decode_launch(running_rows)
        self.ticks += 1
        infl = self.inflight
        self.max_inflight = max(self.max_inflight, infl)
        self._inflight_ticks += infl
        if self.allocator is not None:
            self._util_ticks += self.allocator.utilization

    # ---------------------------------------------------------- prefill
    def _prefill_launch(self, prefill_rows: List[int]) -> None:
        """ONE masked ``decode_chunk`` launch advancing EVERY prefilling
        row by up to ``prefill_chunk`` of its own prompt tokens (per-row
        pos + ragged lengths) — batched prefill across requests, not just
        within one."""
        C = self.prefill_chunk
        for i in list(prefill_rows):
            row = self.rows[i]
            if row.req is None or row.state != PREFILL:
                continue    # preempted by an earlier row's allocation
            part_len = min(C, len(row.ctx) - row.fed)
            self._ensure_blocks(i, row.fed + part_len)
        launched = [
            i for i in prefill_rows
            if self.rows[i].req is not None and self.rows[i].state == PREFILL
        ]
        if not launched:
            return
        tok_mat = np.zeros((self.width, C), np.int32)
        lens = np.zeros(self.width, np.int32)
        posv = np.zeros(self.width, np.int32)
        act = np.zeros(self.width, bool)
        for i in launched:
            row = self.rows[i]
            part = row.ctx[row.fed:row.fed + C]
            tok_mat[i, : len(part)] = part
            lens[i] = len(part)
            posv[i] = row.fed
            act[i] = True
        t0 = self._clock()
        out, self.cache = self._chunk(
            self.params, self.cache, jnp.asarray(tok_mat), jnp.asarray(posv),
            jnp.asarray(act), jnp.asarray(lens), jnp.asarray(self._table),
        )
        toks = np.asarray(out)
        self.sched.observe_launch(PREFILL_ACTION, self._clock() - t0)
        self.prefill_launches += 1
        for i in launched:
            row = self.rows[i]
            row.fed += int(lens[i])
            self.prefill_tokens += int(lens[i])
            if row.fed >= len(row.ctx):
                # prefill complete: the chunk's last-valid-position logits
                # already produced this row's next token inside the jit
                row.pos = row.fed
                row.state = RUNNING
                if row.req.t_first is None:
                    row.req.t_first = self._clock()
                self._append_token(i, int(toks[i]))

    # ----------------------------------------------------------- decode
    def _decode_launch(self, running_rows: List[int]) -> None:
        for i in list(running_rows):
            row = self.rows[i]
            if row.req is None or row.state != RUNNING:
                continue
            self._ensure_blocks(i, row.pos + 1)
        launched = [
            i for i in running_rows
            if self.rows[i].req is not None and self.rows[i].state == RUNNING
        ]
        if not launched:
            return
        toks = np.zeros(self.width, np.int32)
        posv = np.zeros(self.width, np.int32)
        act = np.zeros(self.width, bool)
        for i in launched:
            row = self.rows[i]
            toks[i] = row.last_tok
            posv[i] = row.pos
            act[i] = True
        t0 = self._clock()
        out, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(posv),
            jnp.asarray(act), jnp.asarray(self._table),
        )
        arr = np.asarray(out)
        self.sched.observe_launch(DECODE_ACTION, self._clock() - t0)
        self.decode_launches += 1
        for i in launched:
            self.rows[i].pos += 1
            self._append_token(i, int(arr[i]))

    def _append_token(self, idx: int, tok: int) -> None:
        row = self.rows[idx]
        req = row.req
        req.out_tokens.append(tok)
        row.last_tok = tok
        row.remaining -= 1
        self.tokens_generated += 1
        # same stop rule as the slot engine: budget exhausted, or the next
        # write would land past the context capacity
        if row.remaining <= 0 or row.pos >= self.max_len - 1:
            self._finish_row(idx)

    def _finish_row(self, idx: int) -> None:
        row = self.rows[idx]
        req = row.req
        req.done = True
        req.t_done = self._clock()
        self.requests_completed += 1
        if self.allocator is not None and row.blocks:
            self.allocator.free(row.blocks)   # eager release, like the
            # executor's last-use buffer-slot frees
        self._clear_row(idx)

    def run_until_done(self, max_ticks: int = 5000, strict: bool = False) -> int:
        """Tick until idle or ``max_ticks``.  Returns the number of
        requests still unfinished (0 == complete); a truncated run warns,
        or raises RuntimeError with ``strict=True``."""
        return _run_until_done(self, max_ticks, strict)
