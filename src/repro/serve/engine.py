"""Batched serving engine: prefill + decode with KV/SSM caches and
continuous slot-based batching.

The engine keeps a fixed pool of batch slots.  A request claims a free
slot and is prefilled in **token chunks**: one masked batched
``decode_chunk`` call per ``prefill_chunk`` prompt tokens — O(ceil(S/C))
decode launches for a length-S prompt instead of the O(S) per-token loop
(kept as the chunk-size-1 oracle).  Then every ``tick`` runs ONE batched
decode step for the whole pool with per-slot positions.  New requests join
between ticks — continuous batching without recompilation (pool size,
chunk size and max_len are static).  When the pool is full, ``admit``
parks the request on a FIFO wait queue drained at the start of each tick
instead of dropping it.

Admission validates prompts: empty prompts are rejected outright, and
prompts that would scatter past the KV ring (``len(prompt) > max_len - 1``)
are rejected instead of silently corrupting the cache.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_chunk, decode_step, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False
    # per-request latency/throughput accounting (perf_counter stamps)
    t_submit: Optional[float] = None   # first admit() attempt (queue entry)
    t_admit: Optional[float] = None    # slot claimed, prefill started
    t_first: Optional[float] = None    # first generated token (TTFT end)
    t_done: Optional[float] = None     # request finished

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token, from submission (includes queue wait)."""
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        if not self.out_tokens or self.latency_s in (None, 0.0):
            return None
        return len(self.out_tokens) / self.latency_s


# Jitted decode steps are shared across engines with the same
# (config, pool[, chunk]) — the serving-layer analogue of the compiler's
# fusion-signature kernel dedup: N replica engines trace/compile each
# hot-path function once.  LRU-bounded: a long-lived server process cycling
# through configs/pool sizes must not grow this without limit.
_DECODE_CACHE: "OrderedDict[Tuple, Callable]" = OrderedDict()
_DECODE_CACHE_CAP = 8
_DECODE_CACHE_EVICTIONS = 0


def _cached_jit(key: Tuple, build: Callable[[], Callable]) -> Tuple[Callable, bool]:
    global _DECODE_CACHE_EVICTIONS
    hit = key in _DECODE_CACHE
    if hit:
        _DECODE_CACHE.move_to_end(key)
    else:
        _DECODE_CACHE[key] = build()
        while len(_DECODE_CACHE) > _DECODE_CACHE_CAP:
            _DECODE_CACHE.popitem(last=False)   # evict least-recently-used
            _DECODE_CACHE_EVICTIONS += 1
    return _DECODE_CACHE[key], hit


def _decode_fn(cfg, pool_size: int) -> Tuple[Callable, bool]:
    return _cached_jit(
        ("step", repr(cfg), pool_size),
        lambda: jax.jit(
            lambda p, c, t, pos, act: decode_step(p, c, t, pos, cfg, act)
        ),
    )


def _decode_chunk_fn(cfg, pool_size: int, chunk: int) -> Tuple[Callable, bool]:
    return _cached_jit(
        ("chunk", repr(cfg), pool_size, chunk),
        lambda: jax.jit(
            lambda p, c, t, pos, act, lens: decode_chunk(
                p, c, t, pos, cfg, act, lens
            )
        ),
    )


def decode_cache_size() -> int:
    return len(_DECODE_CACHE)


def decode_cache_stats() -> Dict[str, int]:
    return {
        "size": len(_DECODE_CACHE),
        "cap": _DECODE_CACHE_CAP,
        "evictions": _DECODE_CACHE_EVICTIONS,
    }


class ServeEngine:
    def __init__(self, cfg, params, pool_size: int = 4, max_len: int = 512,
                 prefill_chunk: int = 16):
        self.cfg = cfg
        self.params = params
        self.pool = pool_size
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.cache = init_cache(cfg, pool_size, max_len)
        self.slot_req: List[Optional[Request]] = [None] * pool_size
        self.slot_pos = np.zeros(pool_size, np.int32)
        self.slot_remaining = np.zeros(pool_size, np.int32)
        self.slot_last = np.zeros(pool_size, np.int32)
        self._decode, self.decode_cache_hit = _decode_fn(cfg, pool_size)
        self._decode_chunk = None
        if self.prefill_chunk > 1:
            self._decode_chunk, _ = _decode_chunk_fn(
                cfg, pool_size, self.prefill_chunk
            )
        self.wait_queue: "deque[Request]" = deque()
        self.ticks = 0
        self.tokens_generated = 0
        self.requests_completed = 0
        self.requests_rejected = 0       # invalid prompts (never queued)
        self.prefill_launches = 0        # decode calls spent on prefill
        self.prefill_tokens = 0          # prompt tokens prefilled
        self.decode_launches = 0         # batched tick decode calls

    @property
    def active_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is not None]

    def stats(self) -> Dict[str, object]:
        """Serving counters: launch accounting + queue depth.

        ``prefill_launches`` vs ``prefill_tokens`` is the chunked-prefill
        win: the per-token loop would spend one launch per prompt token.
        """
        return {
            "ticks": self.ticks,
            "tokens_generated": self.tokens_generated,
            "requests_completed": self.requests_completed,
            "requests_rejected": self.requests_rejected,
            "prefill_launches": self.prefill_launches,
            "prefill_tokens": self.prefill_tokens,
            "decode_launches": self.decode_launches,
            "prefill_chunk": self.prefill_chunk,
            "queue_depth": len(self.wait_queue),
            "decode_cache": decode_cache_stats(),
        }

    # ------------------------------------------------------------ admit
    def admit(self, req: Request) -> bool:
        """Place ``req`` in a free slot (True) or park it on the FIFO wait
        queue (False — it is NOT dropped; ticks drain the queue as slots
        free up).  Invalid prompts raise ValueError and are never queued.
        """
        self._validate(req)
        # retry-loop callers (`while pending and admit(pending[0])`) may
        # re-admit a request that is already generating in a slot or
        # already finished — never place or queue those again, or a done
        # request would be re-prefilled and re-generated
        if req.done or any(r is req for r in self.slot_req):
            return False
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        # FIFO fairness + no double-placement: queued requests claim freed
        # slots before this one (draining also places req itself if it was
        # already at the front of the queue)
        self._drain_queue()
        if any(r is req for r in self.slot_req):
            return True
        for s in range(self.pool):
            if self.slot_req[s] is None:
                self._place(s, req)
                return True
        if not any(q is req for q in self.wait_queue):
            self.wait_queue.append(req)
        return False

    def _validate(self, req: Request) -> None:
        n = len(req.prompt)
        if n == 0:
            self.requests_rejected += 1
            raise ValueError(
                f"request {req.rid}: empty prompt — there is no position to "
                "decode from; send at least one (e.g. BOS) token"
            )
        if n > self.max_len - 1:
            self.requests_rejected += 1
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds the KV cache "
                f"(max_len={self.max_len}, limit {self.max_len - 1}) — it "
                "would silently wrap the ring and corrupt earlier positions"
            )

    def _place(self, slot: int, req: Request) -> None:
        self.slot_req[slot] = req
        req.out_tokens = []
        req.t_admit = time.perf_counter()
        self._prefill(slot, req)

    def _drain_queue(self) -> None:
        while self.wait_queue:
            head = self.wait_queue[0]
            if head.done or any(r is head for r in self.slot_req):
                self.wait_queue.popleft()   # stale entry — never re-place
                continue
            free = next(
                (s for s, r in enumerate(self.slot_req) if r is None), None
            )
            if free is None:
                return
            self._place(free, self.wait_queue.popleft())

    # ---------------------------------------------------------- prefill
    def _prefill(self, slot: int, req: Request):
        toks = np.asarray(req.prompt).astype(np.int32)
        if self.prefill_chunk > 1:
            logits = self._prefill_chunked(slot, toks)
        else:
            logits = self._prefill_per_token(slot, toks)
        self.prefill_tokens += len(toks)
        self.slot_pos[slot] = len(toks)
        self.slot_remaining[slot] = req.max_new_tokens
        nxt = int(np.argmax(np.asarray(logits)[slot, : self.cfg.vocab_size]))
        req.out_tokens.append(nxt)
        req.t_first = time.perf_counter()
        self.slot_last[slot] = nxt
        self.slot_remaining[slot] -= 1
        self.tokens_generated += 1
        # same stop rule as tick: out of budget, or the next decode write
        # would land past the KV ring
        if (
            self.slot_remaining[slot] <= 0
            or self.slot_pos[slot] >= self.max_len - 1
        ):
            self._finish(slot)

    def _prefill_chunked(self, slot: int, toks: np.ndarray):
        """One masked batched decode launch per ``prefill_chunk`` tokens."""
        C = self.prefill_chunk
        active = np.zeros(self.pool, bool)
        active[slot] = True
        logits = None
        for start in range(0, len(toks), C):
            part = toks[start:start + C]
            tok_mat = np.zeros((self.pool, C), np.int32)
            tok_mat[slot, : len(part)] = part
            lengths = np.zeros(self.pool, np.int32)
            lengths[slot] = len(part)
            pos = self.slot_pos.copy()
            pos[slot] = start
            logits, self.cache = self._decode_chunk(
                self.params, self.cache, jnp.asarray(tok_mat),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(lengths),
            )
            self.prefill_launches += 1
        return logits

    def _prefill_per_token(self, slot: int, toks: np.ndarray):
        """The chunk-size-1 oracle: one decode launch per prompt token."""
        active = np.zeros(self.pool, bool)
        active[slot] = True
        logits = None
        for i, t in enumerate(toks):
            tok_vec = np.zeros(self.pool, np.int32)
            tok_vec[slot] = t
            pos = self.slot_pos.copy()
            pos[slot] = i
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tok_vec),
                jnp.asarray(pos), jnp.asarray(active),
            )
            self.prefill_launches += 1
        return logits

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done = True
        req.t_done = time.perf_counter()
        self.slot_req[slot] = None
        self.requests_completed += 1

    # ------------------------------------------------------------- tick
    def tick(self):
        """Drain the wait queue into free slots, then one batched decode
        step for all active slots (per-slot pos)."""
        self._drain_queue()
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return
        toks = self.slot_last.copy()
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.slot_pos), jnp.asarray(active),
        )
        self.decode_launches += 1
        arr = np.asarray(logits)
        for s in np.nonzero(active)[0]:
            r = self.slot_req[s]
            nxt = int(np.argmax(arr[s, : self.cfg.vocab_size]))
            r.out_tokens.append(nxt)
            self.slot_last[s] = nxt
            self.slot_pos[s] += 1
            self.slot_remaining[s] -= 1
            self.tokens_generated += 1
            if self.slot_remaining[s] <= 0 or self.slot_pos[s] >= self.max_len - 1:
                self._finish(s)
        self.ticks += 1

    def run_until_done(self, max_ticks: int = 2000):
        t = 0
        while (
            self.wait_queue or any(r is not None for r in self.slot_req)
        ) and t < max_ticks:
            self.tick()
            t += 1
