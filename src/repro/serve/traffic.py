"""Traffic-trace generation and replay for the serving engines.

A trace is a deterministic (seeded) list of requests with tick-indexed
arrival times — Poisson for steady load, or bursty (Poisson bursts of
back-to-back arrivals) to stress admission, queueing and preemption.
Arrivals are in TICK units, not wall-clock, so a replay is scheduling-
deterministic: the same trace against the same engine admits the same
requests at the same ticks regardless of host speed.  Wall-clock enters
only through the latency stamps (TTFT / latency percentiles).

``run_trace`` drives any engine exposing ``admit / tick / busy /
inflight`` (both the slot-ring and the paged engine do), which is how the
benchmark compares the two under identical offered load.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from .engine import Request


@dataclasses.dataclass
class TraceConfig:
    """Knobs for a synthetic request trace (all randomness seeded)."""
    num_requests: int = 64
    arrival: str = "poisson"          # "poisson" | "bursty"
    mean_interarrival_ticks: float = 1.0   # poisson: mean gap between arrivals
    burst_size: int = 8               # bursty: requests per burst
    burst_gap_ticks: float = 12.0     # bursty: mean gap between bursts
    prompt_len_lo: int = 4            # prompt lengths ~ U[lo, hi]
    prompt_len_hi: int = 12
    max_new_lo: int = 4               # generation budgets ~ U[lo, hi]
    max_new_hi: int = 8
    vocab_size: int = 256
    seed: int = 0

    def __post_init__(self):
        if self.arrival not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival process {self.arrival!r}")
        if self.num_requests <= 0:
            raise ValueError("num_requests must be positive")
        if not (0 < self.prompt_len_lo <= self.prompt_len_hi):
            raise ValueError("need 0 < prompt_len_lo <= prompt_len_hi")
        if not (0 < self.max_new_lo <= self.max_new_hi):
            raise ValueError("need 0 < max_new_lo <= max_new_hi")


@dataclasses.dataclass
class TraceEntry:
    rid: int
    arrive_tick: int
    prompt: np.ndarray
    max_new_tokens: int


def generate_trace(cfg: TraceConfig) -> List[TraceEntry]:
    rng = np.random.default_rng(cfg.seed)
    # arrival ticks first, so prompt sampling never perturbs timing
    if cfg.arrival == "poisson":
        gaps = rng.exponential(cfg.mean_interarrival_ticks, cfg.num_requests)
        ticks = np.floor(np.cumsum(gaps)).astype(int)
    else:  # bursty: whole bursts arrive back-to-back on one tick
        ticks_l: List[int] = []
        t = 0
        while len(ticks_l) < cfg.num_requests:
            n = min(cfg.burst_size, cfg.num_requests - len(ticks_l))
            ticks_l.extend([t] * n)
            t += max(1, int(rng.exponential(cfg.burst_gap_ticks)))
        ticks = np.asarray(ticks_l)
    entries = []
    for rid in range(cfg.num_requests):
        plen = int(rng.integers(cfg.prompt_len_lo, cfg.prompt_len_hi + 1))
        mnew = int(rng.integers(cfg.max_new_lo, cfg.max_new_hi + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        entries.append(TraceEntry(rid, int(ticks[rid]), prompt, mnew))
    return entries


@dataclasses.dataclass
class TrafficReport:
    """Replay outcome: completion, latency percentiles, concurrency and
    memory-pressure counters."""
    completed: int
    total: int
    unfinished: int
    ticks: int
    duration_s: float
    tokens_out: int
    tokens_per_s: float
    ttft_p50_ms: float
    ttft_p99_ms: float
    latency_p50_ms: float
    latency_p99_ms: float
    queue_wait_p50_ms: float
    queue_wait_p99_ms: float
    max_inflight: int
    mean_inflight: float
    preemptions: int = 0
    kv_peak_utilization: float = 0.0
    kv_mean_utilization: float = 0.0
    kv_alloc_failures: int = 0

    def summary(self) -> str:
        return (
            f"{self.completed}/{self.total} done in {self.ticks} ticks "
            f"({self.duration_s * 1e3:.1f} ms): {self.tokens_per_s:.0f} tok/s, "
            f"ttft p50/p99 {self.ttft_p50_ms:.2f}/{self.ttft_p99_ms:.2f} ms, "
            f"latency p50/p99 {self.latency_p50_ms:.2f}/"
            f"{self.latency_p99_ms:.2f} ms, inflight max/mean "
            f"{self.max_inflight}/{self.mean_inflight:.1f}, "
            f"preempt {self.preemptions}, kv util peak/mean "
            f"{self.kv_peak_utilization:.2f}/{self.kv_mean_utilization:.2f}"
        )


def _pct(vals: List[float], q: float) -> float:
    if not vals:
        return float("nan")
    return float(np.percentile(np.asarray(vals), q))


def run_trace(engine, trace: List[TraceEntry], max_ticks: int = 100_000,
              strict: bool = False) -> TrafficReport:
    """Replay ``trace`` against ``engine``: before each tick, admit every
    entry whose arrival tick has come (FIFO within a tick), then tick.
    Runs until all requests finish or ``max_ticks`` (strict=True raises on
    truncation)."""
    pending = sorted(trace, key=lambda e: (e.arrive_tick, e.rid))
    reqs: List[Request] = [
        Request(e.rid, e.prompt, max_new_tokens=e.max_new_tokens)
        for e in pending
    ]
    queue = list(zip(pending, reqs, strict=False))
    inflight_sum = 0
    max_inflight = 0
    t0 = time.perf_counter()
    tick = 0
    while tick < max_ticks:
        while queue and queue[0][0].arrive_tick <= tick:
            _, req = queue.pop(0)
            engine.admit(req)
        if not queue and not engine.busy:
            break
        engine.tick()
        cur = engine.inflight
        inflight_sum += cur
        max_inflight = max(max_inflight, cur)
        tick += 1
    duration = time.perf_counter() - t0
    unfinished = len(queue) + engine.unfinished_requests
    if unfinished and strict:
        raise RuntimeError(
            f"trace truncated at max_ticks={max_ticks}: {unfinished} of "
            f"{len(trace)} request(s) unfinished"
        )
    done = [r for r in reqs if r.done]
    tokens_out = sum(len(r.out_tokens or ()) for r in reqs)
    ttfts = [r.ttft_s * 1e3 for r in done if r.ttft_s is not None]
    lats = [r.latency_s * 1e3 for r in done if r.latency_s is not None]
    waits = [r.queue_wait_s * 1e3 for r in done if r.queue_wait_s is not None]
    st = engine.stats()
    kv = st.get("kv_blocks") or {}
    return TrafficReport(
        completed=len(done),
        total=len(reqs),
        unfinished=unfinished,
        ticks=tick,
        duration_s=duration,
        tokens_out=tokens_out,
        tokens_per_s=tokens_out / duration if duration > 0 else 0.0,
        ttft_p50_ms=_pct(ttfts, 50),
        ttft_p99_ms=_pct(ttfts, 99),
        latency_p50_ms=_pct(lats, 50),
        latency_p99_ms=_pct(lats, 99),
        queue_wait_p50_ms=_pct(waits, 50),
        queue_wait_p99_ms=_pct(waits, 99),
        max_inflight=max_inflight,
        mean_inflight=inflight_sum / max(1, tick),
        preemptions=int(st.get("preemptions", 0)),
        kv_peak_utilization=float(kv.get("peak_utilization", 0.0)),
        kv_mean_utilization=float(kv.get("mean_utilization", 0.0)),
        kv_alloc_failures=int(kv.get("alloc_failures", 0)),
    )
