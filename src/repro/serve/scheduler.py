"""Continuous-batching scheduler: admission, prefill/decode interleaving
under an SLO budget, and preemption policy.

Every engine tick the scheduler (a) drains the FIFO wait queue into free
decode rows for which enough KV blocks exist, and (b) picks ONE launch:
a batched prefill chunk (advances every prefilling row by up to
``prefill_chunk`` prompt tokens in a single masked ``decode_chunk`` call)
or a batched decode step (one token for every running row).  Prefill is no
longer synchronous inside ``admit`` — a long prompt can no longer stall
every in-flight decode for its whole length.

Arbitration between the two is the TTFT-vs-latency tradeoff:

  * ``decode_slo_s`` — if the gap since the last decode launch exceeds it,
    decode wins (running requests' inter-token latency is protected);
  * ``ttft_slo_s`` — if the oldest prefilling request's projected finish
    (measured wait + EMA-estimated remaining chunk time) would overrun
    ``safety * ttft_slo_s``, prefill wins;
  * neither at risk (or both SLOs None, the default): strict alternation,
    which is deterministic in ticks — what the traffic benchmark gates on.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

# request lifecycle states (engine-side rows carry these)
WAITING = "waiting"
PREFILL = "prefill"
RUNNING = "running"
DONE = "done"

PREFILL_ACTION = "prefill"
DECODE_ACTION = "decode"
IDLE_ACTION = "idle"


@dataclasses.dataclass
class SLOConfig:
    """Latency targets steering the prefill/decode interleave.

    ``None`` disables an SLO term; with both None the scheduler strictly
    alternates prefill and decode launches (tick-deterministic)."""
    ttft_slo_s: Optional[float] = None    # submit -> first token target
    decode_slo_s: Optional[float] = None  # max gap between decode launches
    safety: float = 0.8                   # act at safety * ttft_slo_s

    def __post_init__(self):
        for name in ("ttft_slo_s", "decode_slo_s"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive or None, got {v}")
        if not (0 < self.safety <= 1):
            raise ValueError(f"safety must be in (0, 1], got {self.safety}")


class Scheduler:
    """Policy state for one engine: wait queue + interleave arbitration.

    The engine owns device state (cache, rows, block tables); the
    scheduler owns the queue and the prefill-vs-decode decision so policy
    is testable with a fake clock and no model at all."""

    def __init__(self, slo: Optional[SLOConfig] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.slo = slo or SLOConfig()
        self.clock = clock
        self.waiting: "deque" = deque()
        self.last_action = DECODE_ACTION   # so the first contested pick prefills
        self.last_decode_t: Optional[float] = None
        self.ema_prefill_s: Optional[float] = None
        self.ema_decode_s: Optional[float] = None
        self.admitted = 0
        self.preemptions = 0
        self.prefill_launches_chosen = 0
        self.decode_launches_chosen = 0
        self.ttft_overrides = 0            # SLO forced prefill over decode
        self.decode_overrides = 0          # SLO forced decode over prefill

    # ------------------------------------------------------------ queue
    def enqueue(self, req) -> None:
        self.waiting.append(req)

    def requeue_front(self, req) -> None:
        """Preempted requests rejoin at the FRONT: they were admitted (and
        therefore submitted) before anything still waiting — FIFO order by
        submission survives preemption."""
        self.waiting.appendleft(req)
        self.preemptions += 1

    # ----------------------------------------------------- measurements
    def observe_launch(self, action: str, seconds: float) -> None:
        """EMA of per-launch wall time, feeding the TTFT projection."""
        attr = "ema_prefill_s" if action == PREFILL_ACTION else "ema_decode_s"
        prev = getattr(self, attr)
        setattr(self, attr, seconds if prev is None
                else 0.7 * prev + 0.3 * seconds)

    # ------------------------------------------------------ arbitration
    def choose(self, n_prefill: int, n_running: int,
               oldest_prefill_wait_s: Optional[float] = None,
               chunks_remaining: int = 0) -> str:
        """Pick this tick's launch. ``oldest_prefill_wait_s`` is
        now - t_submit for the oldest request still prefilling;
        ``chunks_remaining`` its remaining prefill chunks."""
        if n_prefill == 0 and n_running == 0:
            return IDLE_ACTION
        if n_prefill == 0:
            action = DECODE_ACTION
        elif n_running == 0:
            action = PREFILL_ACTION
        else:
            action = None
            now = self.clock()
            if (
                self.slo.decode_slo_s is not None
                and self.last_decode_t is not None
                and now - self.last_decode_t > self.slo.decode_slo_s
            ):
                action = DECODE_ACTION
                self.decode_overrides += 1
            elif (
                self.slo.ttft_slo_s is not None
                and oldest_prefill_wait_s is not None
            ):
                projected = oldest_prefill_wait_s + chunks_remaining * (
                    self.ema_prefill_s or 0.0
                )
                if projected > self.slo.safety * self.slo.ttft_slo_s:
                    action = PREFILL_ACTION
                    self.ttft_overrides += 1
            if action is None:   # neither SLO at risk: strict alternation
                action = (
                    PREFILL_ACTION
                    if self.last_action == DECODE_ACTION
                    else DECODE_ACTION
                )
        if action == DECODE_ACTION:
            self.last_decode_t = self.clock()
            self.decode_launches_chosen += 1
        else:
            self.prefill_launches_chosen += 1
        self.last_action = action
        return action

    def stats(self) -> dict:
        return {
            "queue_depth": len(self.waiting),
            "admitted": self.admitted,
            "preemptions": self.preemptions,
            "prefill_launches_chosen": self.prefill_launches_chosen,
            "decode_launches_chosen": self.decode_launches_chosen,
            "ttft_overrides": self.ttft_overrides,
            "decode_overrides": self.decode_overrides,
        }
