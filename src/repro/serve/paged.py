"""Paged KV memory for the serving layer.

The contiguous engine reserves ``max_len`` KV rows per slot up front, so
KV memory — not compute — caps concurrency at ``pool_size``.  Here the KV
cache is a pool of fixed-size blocks (``block_size`` tokens each) handed
out by a free-list :class:`BlockAllocator`; each request owns only the
blocks its actual context occupies, recorded in a logical->physical block
table that the jitted decode gathers through (``models.init_paged_cache``
/ ``decode_step(block_tables=...)``).

This is the serving-side analogue of the compiler's VMEM planning
(``core/memory.py``): a flat slot table, explicit ALLOC/FREE bookkeeping,
and eager release the moment a value (here: a finished request's context)
is dead.  The allocator is deliberately strict — double-assignment,
double-free and foreign-block frees raise instead of corrupting cache
state that would only surface as wrong tokens much later.
"""
from __future__ import annotations

from typing import Dict, List, Optional


def blocks_for_tokens(num_tokens: int, block_size: int, ring: int) -> int:
    """Blocks needed to hold ``num_tokens`` context tokens in a logical
    ring of ``ring`` token positions (sliding-window reuse caps it)."""
    return -(-min(num_tokens, ring) // block_size)


class BlockAllocator:
    """Fixed-size KV block pool with a LIFO free list.

    Physical ids are ``0 .. num_blocks-1``; the serving engine reserves
    physical index ``num_blocks`` as the parking block (masked writes),
    which is not this allocator's to hand out.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError(
                f"need positive num_blocks/block_size, got "
                f"{num_blocks}/{block_size}"
            )
        self.num_blocks = num_blocks
        self.block_size = block_size
        # reversed so .pop() hands out ascending ids first (stable tests)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._in_use: set = set()
        self.allocated_total = 0
        self.freed_total = 0
        self.peak_in_use = 0
        self.alloc_failures = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return len(self._in_use)

    @property
    def utilization(self) -> float:
        return len(self._in_use) / self.num_blocks

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """All-or-nothing: ``n`` blocks or None (counted as a failure —
        the scheduler's cue to preempt)."""
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            if b in self._in_use:      # free list corrupt — fail loudly
                raise RuntimeError(f"block {b} double-assigned")
            self._in_use.add(b)
        self.allocated_total += n
        self.peak_in_use = max(self.peak_in_use, len(self._in_use))
        return blocks

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._in_use:
                raise RuntimeError(
                    f"freeing block {b} that is not allocated "
                    f"(double free or foreign block)"
                )
            self._in_use.remove(b)
            self._free.append(b)
        self.freed_total += len(blocks)

    def check_consistent(self) -> None:
        """Test hook: free list and in-use set must partition the pool."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise RuntimeError("free list contains duplicates")
        if free & self._in_use:
            raise RuntimeError("block both free and in use")
        if free | self._in_use != set(range(self.num_blocks)):
            raise RuntimeError("blocks leaked from the pool")

    def stats(self) -> Dict[str, object]:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "in_use": self.num_in_use,
            "free": self.num_free,
            "peak_in_use": self.peak_in_use,
            "peak_utilization": self.peak_in_use / self.num_blocks,
            "allocated_total": self.allocated_total,
            "freed_total": self.freed_total,
            "alloc_failures": self.alloc_failures,
        }
