"""``BaseEngine`` — the protocol both serve engines satisfy.

``ServeEngine`` (contiguous per-request KV buffers) and ``PagedServeEngine``
(paged KV memory, PR 7) grew the same driving surface; this protocol pins it
so callers can hold either engine behind one type:

  * ``admit(req) -> bool``     — accept a request if capacity allows
  * ``tick() -> None``         — one scheduler step (prefill and/or decode)
  * ``run_until_done(max_ticks, strict) -> int`` — drive to completion,
    returning the number of ticks consumed
  * ``stats() -> dict``        — engine counters for reporting/benchmarks

``isinstance(engine, BaseEngine)`` works at runtime (structural check).
"""
from __future__ import annotations

from typing import Dict, Protocol, runtime_checkable

from .engine import Request


@runtime_checkable
class BaseEngine(Protocol):
    """Structural type of a serve engine (see module docstring)."""

    def admit(self, req: Request) -> bool:
        ...

    def tick(self) -> None:
        ...

    def run_until_done(self, max_ticks: int = 2000, strict: bool = False) -> int:
        ...

    def stats(self) -> Dict[str, object]:
        ...
