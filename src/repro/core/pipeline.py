"""The FusionStitching pass pipeline — paper Fig. 4 as explicit passes.

``compile_module`` used to be one monolithic function; here every stage of
the paper's pipeline is a ``Pass`` over a shared ``CompilationState``
artifact, so stages can be tested, timed, and reordered in isolation:

    FusionPass     deep fusion (§3.2) with the ScheduleConsistencyChecker
    SchedulePass   per-fusion schedule tuning (§4.3) with fusion-signature
                   kernel-cache lookup — structurally identical fusions
                   (stacked transformer layers) tune once
    MemoryPass     VMEM scratch planning (§5.1) with the memory-infeasible
                   feedback loop back into tuning (shrink + retune)
    CodegenPass    IrEmitterStitched Pallas emission (§5.2), deduplicated:
                   one emitted kernel per unique fusion signature
    FinalizePass   execution-plan construction + CompileStats

The memory feedback edge of Fig. 4 is preserved: MemoryPass re-invokes the
tuner when a fusion must shrink to fit the scratch budget, and members it
drops are demoted to standalone kernels (never silently lost).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .codegen import StitchedKernel, emit_fusion
from .fusion import (
    FusedComputation,
    FusionConfig,
    FusionPlan,
    FusionScorer,
    deep_fuse,
)
from .ir import Instruction, Module
from .memory import MemoryInfeasible, plan_memory
from .perf_library import PerfLibrary
from .schedule import Unsatisfiable, any_satisfiable, resolve_schedules
from .signature import CacheEntry, KernelCache, fusion_signature
from .tuning import TunedPlan, score, tune


@dataclass
class PlannedFusion:
    """One fusion instance bound to its (possibly shared) cache entry."""

    fusion: FusedComputation
    entry: CacheEntry
    is_representative: bool          # this instance built the entry
    kernel: Optional[StitchedKernel] = None
    tuned_from_disk: bool = False

    @property
    def cache_hit(self) -> bool:
        return not self.is_representative


@dataclass
class CompilationState:
    """The artifact every pass reads and extends."""

    module: Module
    options: "StitchOptions"              # noqa: F821 — compiler facade type
    library: PerfLibrary
    kernel_cache: KernelCache
    fusion_plan: Optional[FusionPlan] = None
    planned: List[PlannedFusion] = field(default_factory=list)
    demoted: List[Instruction] = field(default_factory=list)
    pass_times: Dict[str, float] = field(default_factory=dict)
    # filled by FinalizePass
    executable: Optional[object] = None
    stats: Optional[object] = None


class Pass:
    name = "pass"

    def run(self, state: CompilationState) -> None:
        raise NotImplementedError


class PassPipeline:
    def __init__(self, passes: List[Pass]):
        self.passes = list(passes)

    def run(self, state: CompilationState) -> CompilationState:
        for p in self.passes:
            t0 = time.perf_counter()
            p.run(state)
            state.pass_times[p.name] = time.perf_counter() - t0
        return state


# --------------------------------------------------------------------------
# Passes
# --------------------------------------------------------------------------


class FusionPass(Pass):
    """Deep fusion with the schedule+memory consistency checker (Fig. 4),
    cost-guided by the shared LatencyModel when ``options.planner`` is
    ``"cost"`` (candidate partitions + horizontal merging)."""

    name = "fusion"

    def run(self, state: CompilationState) -> None:
        opts = state.options

        def consistency(roots, members) -> bool:
            sol = any_satisfiable(
                members,
                roots,
                replicate_limit=opts.replicate_limit,
                max_blocks=opts.max_blocks,
            )
            if sol is None:
                return False
            try:
                plan_memory(members, roots, sol, opts.vmem_limit)
            except MemoryInfeasible:
                return False
            return True

        scorer = None
        if opts.planner == "cost":
            # the planner scores with the SAME model the tuner's PerfLibrary
            # uses as its miss handler — one LatencyModel per compile
            scorer = FusionScorer(
                model=state.library.model,
                replicate_limit=opts.replicate_limit,
                max_blocks=opts.max_blocks,
                vmem_limit=opts.vmem_limit,
            )
        fcfg = FusionConfig(
            fuse_dot=opts.fuse_dot,
            ew_footprint_limit=opts.ew_footprint_limit,
            max_fusion_ops=opts.max_fusion_ops,
            consistency=consistency,
            planner=opts.planner,
            scorer=scorer,
            # the consistency closure above IS the scorer's feasibility
            # check under the same limits — don't solve everything twice
            scorer_covers_consistency=scorer is not None,
        )
        state.fusion_plan = deep_fuse(state.module, fcfg)


def _options_fingerprint(opts) -> str:
    """Compile-options salt for cache keys: a kernel tuned/emitted under one
    (interpret, memory-budget, blocks, planner) regime must never serve a
    compile running under another, even through a shared or persistent
    cache.  The planner mode is part of the fingerprint because the planner
    decides *partitions*: a signature that names a greedy-built structure
    must not resurrect under a differently-partitioned compile."""
    return (
        f"i{int(opts.interpret)}:v{opts.vmem_limit}:r{opts.replicate_limit}"
        f":b{opts.max_blocks}:p{opts.planner}:"
    )


class SchedulePass(Pass):
    """Tune each fusion's schedule; deduplicate by fusion signature.

    A cache hit binds the instance to the existing entry: no tuning, no
    memory planning, no emission for this instance.  A persistent-store hit
    (warm process) skips the tuning search but still resolves/validates the
    recorded root schedules against this fusion.
    """

    name = "schedule"

    def run(self, state: CompilationState) -> None:
        opts = state.options
        cache = state.kernel_cache
        salt = _options_fingerprint(opts)
        for fusion in state.fusion_plan.fusions:
            sig = salt + fusion_signature(fusion)
            if opts.dedup_kernels:
                entry = cache.get(sig)
                if entry is not None:
                    state.planned.append(PlannedFusion(fusion, entry, False))
                    continue
            tuned, from_disk = self._tune(state, fusion, sig)
            if tuned is None:
                state.demoted.extend(fusion.members)
                continue
            roots = fusion.roots
            entry = CacheEntry(
                signature=sig,
                solution=tuned.solution,
                memory=None,
                cost_s=tuned.cost_s,
                root_scheds=[tuned.solution.root_scheds[r.id] for r in roots],
            )
            if opts.dedup_kernels:
                cache.put(entry)
            state.planned.append(
                PlannedFusion(fusion, entry, True, tuned_from_disk=from_disk)
            )

    def _tune(self, state, fusion, sig):
        opts = state.options
        members, roots = fusion.members, fusion.roots
        if opts.dedup_kernels:
            hint = state.kernel_cache.tuning_hint(sig)
            if hint is not None and len(hint) == len(roots):
                try:
                    sol = resolve_schedules(
                        members,
                        roots,
                        {r.id: s for r, s in zip(roots, hint)},
                        opts.replicate_limit,
                    )
                    return TunedPlan(sol, score(members, sol, state.library)), True
                except Unsatisfiable:
                    pass  # stale record — fall back to the full search
        tuned = tune(
            members,
            roots,
            state.library,
            max_blocks=opts.max_blocks,
            replicate_limit=opts.replicate_limit,
        )
        return tuned, False


class MemoryPass(Pass):
    """VMEM scratch planning with the §5.1.2 feedback loop: on
    MemoryInfeasible, drop the deepest member, re-tune, retry.  Dropped
    members are demoted to standalone kernels."""

    name = "memory"

    def run(self, state: CompilationState) -> None:
        dead = set()  # entries whose representative proved unfusable
        kept: List[PlannedFusion] = []
        for p in state.planned:
            if not p.is_representative:
                if id(p.entry) in dead:
                    # the shared plan died — this instance runs standalone too
                    state.demoted.extend(p.fusion.members)
                    continue
                kept.append(p)  # shares the representative's plan
                continue
            if self._plan(state, p):
                kept.append(p)
            else:
                dead.add(id(p.entry))
                if state.options.dedup_kernels:
                    state.kernel_cache.remove(p.entry.signature)
        state.planned = kept

    def _plan(self, state, p: PlannedFusion) -> bool:
        opts = state.options
        fusion, entry = p.fusion, p.entry
        members, roots = fusion.members, fusion.roots
        tuned: Optional[TunedPlan] = TunedPlan(entry.solution, entry.cost_s)
        dropped: List[Instruction] = []
        while tuned is not None:
            try:
                mem = plan_memory(members, roots, tuned.solution, opts.vmem_limit)
            except MemoryInfeasible:
                if len(members) <= 1:
                    tuned = None
                    break
                dropped.append(members[-1])
                members = members[:-1]
                fusion = FusedComputation(members, name=fusion.name)
                roots = fusion.roots
                tuned = tune(
                    members,
                    roots,
                    state.library,
                    max_blocks=opts.max_blocks,
                    replicate_limit=opts.replicate_limit,
                )
                continue
            # success
            state.demoted.extend(dropped)
            p.fusion = fusion
            entry.solution = tuned.solution
            entry.cost_s = tuned.cost_s
            entry.memory = mem
            entry.root_scheds = [
                tuned.solution.root_scheds[r.id] for r in roots
            ]
            entry.kept_members = len(members)
            if dropped and opts.dedup_kernels:
                # the persisted record (written pre-shrink by SchedulePass)
                # no longer describes the structure its signature hashes
                state.kernel_cache.discard_disk(entry.signature)
            return True
        # unfusable after all: every member (kept + dropped) runs standalone
        state.demoted.extend(fusion.members)
        state.demoted.extend(dropped)
        return False


class CodegenPass(Pass):
    """Emit one Pallas kernel per unique signature; bind instances.

    Representatives are planned before their hits (SchedulePass order), so
    an entry's kernel always exists by the time an instance binds to it.
    """

    name = "codegen"

    def run(self, state: CompilationState) -> None:
        for p in state.planned:
            entry = p.entry
            if p.is_representative:
                kernel = emit_fusion(
                    p.fusion, entry.solution, entry.memory,
                    interpret=state.options.interpret,
                )
                entry.kernel = kernel
                p.kernel = kernel
            else:
                # the representative may have shrunk under memory feedback;
                # apply the identical shrink to this instance before binding
                kept_n = entry.kept_members or len(p.fusion.members)
                if kept_n < len(p.fusion.members):
                    state.demoted.extend(p.fusion.members[kept_n:])
                    p.fusion = FusedComputation(
                        p.fusion.members[:kept_n], name=p.fusion.name
                    )
                p.kernel = entry.kernel.bind(p.fusion)


class FinalizePass(Pass):
    """Assemble the final FusionPlan, the planned executable, and stats."""

    name = "finalize"

    def run(self, state: CompilationState) -> None:
        # imported here: compiler is the facade above this module
        from .compiler import build_outputs

        build_outputs(state)


def default_pipeline() -> PassPipeline:
    return PassPipeline(
        [FusionPass(), SchedulePass(), MemoryPass(), CodegenPass(), FinalizePass()]
    )
