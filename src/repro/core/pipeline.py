"""The FusionStitching pass pipeline — paper Fig. 4 as explicit passes.

``compile_module`` used to be one monolithic function; here every stage of
the paper's pipeline is a ``Pass`` over a shared ``CompilationState``
artifact, so stages can be tested, timed, and reordered in isolation:

    FusionPass     deep fusion (§3.2) with the ScheduleConsistencyChecker
    SchedulePass   per-fusion schedule tuning (§4.3) with fusion-signature
                   kernel-cache lookup — structurally identical fusions
                   (stacked transformer layers) tune once
    MemoryPass     VMEM scratch planning (§5.1) with the memory-infeasible
                   feedback loop back into tuning (shrink + retune)
    CodegenPass    IrEmitterStitched Pallas emission (§5.2), deduplicated:
                   one emitted kernel per unique fusion signature
    FinalizePass   execution-plan construction + CompileStats

The memory feedback edge of Fig. 4 is preserved: MemoryPass re-invokes the
tuner when a fusion must shrink to fit the scratch budget, and members it
drops are demoted to standalone kernels (never silently lost).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .codegen import StitchedKernel, emit_fusion, emit_stitched_fusion
from .fusion import (
    FusedComputation,
    FusionConfig,
    FusionPlan,
    FusionScorer,
    deep_fuse,
)
from .ir import Instruction, Module
from .measure import measure_kernel
from .memory import MemoryInfeasible, plan_memory, plan_stitched_memory
from .perf_library import PerfLibrary
from .schedule import (
    CONSISTENT,
    PhaseSolution,
    Unsatisfiable,
    resolve_schedules,
    resolve_stitched,
    stitchable,
)
from .shard import propagate_layouts
from .signature import CacheEntry, KernelCache, fusion_signature
from .tuning import TunedPlan, score, tune


@dataclass
class PlannedFusion:
    """One fusion instance bound to its (possibly shared) cache entry."""

    fusion: FusedComputation
    entry: CacheEntry
    is_representative: bool          # this instance built the entry
    kernel: Optional[StitchedKernel] = None
    tuned_from_disk: bool = False
    # Signature provenance for the verifier's cache-collision audit
    # (EXEC005): the content hash of the fusion body as SchedulePass hashed
    # it, and whether memory feedback later shrank this instance — a shrunk
    # fusion keeps its pre-shrink signature by design (``kept_members``
    # records the shrink), so the audit skips re-hashing it.
    raw_signature: Optional[str] = None
    shrunk: bool = False
    # Measured-store key for this fusion (options salt + the signature the
    # planner SCORED — see FusedComputation.scored_signature).  Recorded by
    # SchedulePass so AutotunePass files measurements under the exact key
    # the next compile's scorer will look up.
    measure_sig: Optional[str] = None

    @property
    def cache_hit(self) -> bool:
        return not self.is_representative


@dataclass
class CompilationState:
    """The artifact every pass reads and extends."""

    module: Module
    options: "StitchOptions"              # noqa: F821 — compiler facade type
    library: PerfLibrary
    kernel_cache: KernelCache
    fusion_plan: Optional[FusionPlan] = None
    planned: List[PlannedFusion] = field(default_factory=list)
    demoted: List[Instruction] = field(default_factory=list)
    pass_times: Dict[str, float] = field(default_factory=dict)
    # Autotuning: the MeasuredCostStore for this compile (None = analytic
    # only).  The hit/miss counters live on the store and accumulate across
    # compiles when it is shared, so FinalizePass reports deltas against the
    # snapshot taken when the state was built.
    measured_store: Optional[object] = None
    measured_base_hits: int = 0
    measured_base_misses: int = 0
    measurements_taken: int = 0
    # Parameter names whose buffers the caller donated (frontend
    # ``donate_argnums``): threaded to the ExecutionPlan, which lifts the
    # donation protection on those slots.  Runtime-only — never part of any
    # cache fingerprint (like ``jit_replay``, it changes how a plan is
    # replayed, not what is tuned or emitted).
    donate_params: Optional[frozenset] = None
    # Shard-aware compilation (set when ``options.mesh_axes`` is): the Mesh
    # the plan replays on (runtime-only — never fingerprinted; its (name,
    # size) shape IS fingerprinted via options.mesh_axes), parameter/output
    # layouts from the shard_map trace, and ShardingPass counters.
    mesh: Optional[object] = None
    param_layouts: Optional[Dict[str, tuple]] = None
    out_layouts: Optional[List] = None
    shard_stats: Dict[str, int] = field(default_factory=dict)
    # Sub-module (loop body) compiles, filled by SubModulePass: unique
    # compiled bodies by structural module signature, plus call-site count.
    sub_compiled: Dict[str, object] = field(default_factory=dict)
    sub_call_sites: int = 0
    # filled by FinalizePass
    executable: Optional[object] = None
    stats: Optional[object] = None


class Pass:
    name = "pass"

    def run(self, state: CompilationState) -> None:
        raise NotImplementedError


class PassPipeline:
    def __init__(self, passes: List[Pass]):
        self.passes = list(passes)

    def run(self, state: CompilationState) -> CompilationState:
        from .verify import ERROR, VerificationError, resolve_verify_mode, verify_state

        mode = resolve_verify_mode(state.options)
        verify_time = 0.0
        boundaries = 0
        warnings = 0
        for p in self.passes:
            t0 = time.perf_counter()
            p.run(state)
            state.pass_times[p.name] = time.perf_counter() - t0
            # "off" does zero verification work (no pass_times["verify"]
            # entry either — the no-overhead contract is testable);
            # "checkpoint" verifies the finished artifact once; "strict"
            # checks every boundary so a violation names the pass that
            # introduced it.
            if mode == "off" or (mode == "checkpoint" and p is not self.passes[-1]):
                continue
            v0 = time.perf_counter()
            diags = verify_state(state, pass_name=p.name)
            verify_time += time.perf_counter() - v0
            boundaries += 1
            errors = [d for d in diags if d.severity == ERROR]
            warnings += len(diags) - len(errors)
            if errors:
                state.pass_times["verify"] = verify_time
                raise VerificationError(errors)
        if mode != "off":
            state.pass_times["verify"] = verify_time
            if state.stats is not None:
                state.stats.verify_mode = mode
                state.stats.verify_boundaries = boundaries
                state.stats.verify_warnings = warnings
                state.stats.verify_time_s = verify_time
        return state


# --------------------------------------------------------------------------
# Passes
# --------------------------------------------------------------------------


class SubModulePass(Pass):
    """Compile every loop body (``call`` instruction) as its own module
    through the full pipeline, BEFORE the parent's fusion pass runs.

    Bodies are deduplicated by structural ``module_signature``: the N
    scan layers of a stacked model lower to N ``call`` sites whose bodies
    hash equal, so one compiled sub-module serves them all.  The parent's
    ``kernel_cache`` and ``measured_store`` are shared into the sub-compile,
    so structurally identical fusions inside different (or repeated) bodies
    also dedup at the kernel level across layers and across compiles.
    Idempotent — a ``call`` that already carries a ``compiled_body`` is
    left alone; nested loops recurse naturally because the sub-compile runs
    this same pipeline.
    """

    name = "submodule"

    def run(self, state: CompilationState) -> None:
        from .compiler import compile_module
        from .signature import module_signature

        for instr in state.module.instructions:
            if instr.opcode != "call" or "compiled_body" in instr.attrs:
                continue
            state.sub_call_sites += 1
            sig = module_signature(instr.attrs["body"])
            cm = state.sub_compiled.get(sig)
            if cm is None:
                cm = compile_module(
                    instr.attrs["body"],
                    state.options,
                    kernel_cache=state.kernel_cache,
                    measured_store=state.measured_store,
                )
                state.sub_compiled[sig] = cm
            instr.attrs["compiled_body"] = cm
            instr.attrs["body_sig"] = sig


class ShardingPass(Pass):
    """Resolve shard layouts before fusion (the tentpole's pipeline hook).

    When the compile targets a mesh (``options.mesh_axes`` set), walk the
    module once with ``shard.propagate_layouts``: derive a layout for every
    instruction from the parameter layouts, stamp non-trivial results into
    ``attrs["shard"]`` (which salts ``fusion_signature`` downstream — the
    kernel cache can never alias per-shard and full-shape kernels), track
    pending partial sums, and validate collectives against the mesh.  A
    no-mesh compile is untouched — not a single attr changes, so every
    existing signature and cache key stays byte-identical.
    """

    name = "sharding"

    def run(self, state: CompilationState) -> None:
        mesh_axes = getattr(state.options, "mesh_axes", None)
        if not mesh_axes:
            return
        state.shard_stats = propagate_layouts(
            state.module, mesh_axes, state.param_layouts
        )


class FusionPass(Pass):
    """Deep fusion with the schedule+memory consistency checker (Fig. 4),
    cost-guided by the shared LatencyModel when ``options.planner`` is
    ``"cost"`` (candidate partitions + horizontal merging)."""

    name = "fusion"

    def run(self, state: CompilationState) -> None:
        opts = state.options
        srl = _stitch_replicate_limit(opts)

        scorer = None
        if opts.planner == "cost":
            # the planner scores with the SAME model the tuner's PerfLibrary
            # uses as its miss handler — one LatencyModel per compile
            scorer = FusionScorer(
                model=state.library.model,
                replicate_limit=opts.replicate_limit,
                max_blocks=opts.max_blocks,
                vmem_limit=opts.vmem_limit,
                allow_stitch=opts.enable_stitching,
                stitch_replicate_limit=srl,
                stitch_max_blocks=opts.stitch_max_blocks,
                measured=state.measured_store,
                options_salt=_measure_salt(opts),
                mesh_axes=getattr(opts, "mesh_axes", None) or (),
            )

        if scorer is not None:
            def consistency(roots, members) -> bool:
                # delegate to the scorer: same three-way verdict + memory
                # feasibility (incl. the stitched interface budget, so
                # over-budget stitches fall back to a split), memoized by
                # member-id frozenset — growth probes the same sets the
                # partition scoring later reuses.  Singletons must be
                # CONSISTENT outright: a lone op whose only schedule is the
                # stitched degenerate one cannot lower as a one-member
                # stitched kernel and would only be demoted later.
                if len(members) == 1:
                    return scorer.verdict(members).verdict == CONSISTENT
                return scorer.fused_cost(members) is not None
        else:
            def consistency(roots, members) -> bool:
                # planner="greedy" reproduces the paper's Algorithm 1
                # exactly: the boolean SchdConsistent veto, no stitching
                v = stitchable(
                    roots,
                    members,
                    replicate_limit=opts.replicate_limit,
                    max_blocks=opts.max_blocks,
                    allow_stitch=False,
                )
                if v.verdict != CONSISTENT:
                    return False
                try:
                    plan_memory(members, roots, v.solution, opts.vmem_limit)
                except MemoryInfeasible:
                    return False
                return True

        fcfg = FusionConfig(
            fuse_dot=opts.fuse_dot,
            ew_footprint_limit=opts.ew_footprint_limit,
            max_fusion_ops=opts.max_fusion_ops,
            consistency=consistency,
            planner=opts.planner,
            scorer=scorer,
            enable_stitching=opts.enable_stitching,
            # the consistency closure above IS the scorer's feasibility
            # check under the same limits — don't solve everything twice
            scorer_covers_consistency=scorer is not None,
        )
        state.fusion_plan = deep_fuse(state.module, fcfg)


def _stitch_replicate_limit(opts) -> int:
    """Resolved stitched-phase replicate limit (None = the VMEM budget);
    an explicit 0 means "no relaxed replication" and is honored."""
    if opts.stitch_replicate_limit is None:
        return opts.vmem_limit
    return opts.stitch_replicate_limit


def _options_fingerprint(opts) -> str:
    """Compile-options salt for cache keys: a kernel tuned/emitted under one
    (interpret, memory-budget, blocks, planner, stitching) regime must never
    serve a compile running under another, even through a shared or
    persistent cache.  The planner mode is part of the fingerprint because
    the planner decides *partitions*: a signature that names a greedy-built
    structure must not resurrect under a differently-partitioned compile.
    The stitching options are part of it because they decide *phases*: a
    stitched lowering must never serve a stitching-disabled compile (the
    phase structure itself additionally salts ``fusion_signature``).
    The autotune knobs are part of it because they decide which *costs* the
    planner saw: an entry partitioned under measured costs must not serve an
    analytic-only compile (or one reading a different tuning store)."""
    return (
        _measure_salt(opts)
        + f"at{int(getattr(opts, 'autotune', False))}"
        f":mr{getattr(opts, 'measure_repeats', 5)}"
        f":ts{getattr(opts, 'tuning_store_path', None) or ''}:"
    )


def _measure_salt(opts) -> str:
    """Salt for MeasuredCostStore keys: everything that changes what a
    kernel IS (interpret, memory budgets, blocks, planner, stitching) but
    NOT the autotune-control knobs — a measurement describes the lowering,
    not how eagerly we measure, so a store warmed under ``autotune=True``
    must still serve a later read-only ``tuning_store_path`` compile."""
    srl = _stitch_replicate_limit(opts)
    salt = (
        f"i{int(opts.interpret)}:v{opts.vmem_limit}:r{opts.replicate_limit}"
        f":b{opts.max_blocks}:p{opts.planner}"
        f":st{int(opts.enable_stitching)}:sb{opts.stitch_max_blocks}:sr{srl}:"
    )
    # Mesh shape enters the salt ONLY for sharded compiles: per-shard costs
    # measured on an 8-way mesh must not serve a 4-way (or unsharded) run,
    # while every pre-existing single-device key stays byte-identical.
    mesh_axes = getattr(opts, "mesh_axes", None)
    if mesh_axes:
        salt += "m" + ",".join(f"{a}{s}" for a, s in mesh_axes) + ":"
    return salt


class SchedulePass(Pass):
    """Tune each fusion's schedule; deduplicate by fusion signature.

    A cache hit binds the instance to the existing entry: no tuning, no
    memory planning, no emission for this instance.  A persistent-store hit
    (warm process) skips the tuning search but still resolves/validates the
    recorded root schedules against this fusion.
    """

    name = "schedule"

    def run(self, state: CompilationState) -> None:
        opts = state.options
        cache = state.kernel_cache
        salt = _options_fingerprint(opts)
        msalt = _measure_salt(opts)
        for fusion in state.fusion_plan.fusions:
            raw = fusion_signature(fusion)
            sig = salt + raw
            # Measured records are keyed by the signature the PLANNER scored
            # (pre-absorption when the two differ) — the key next compile's
            # scorer will ask the store for.
            msig = msalt + (fusion.scored_signature or raw)
            if opts.dedup_kernels:
                entry = cache.get(sig)
                if entry is not None:
                    state.planned.append(
                        PlannedFusion(
                            fusion, entry, False,
                            measure_sig=msig, raw_signature=raw,
                        )
                    )
                    continue
            tuned, from_disk = self._tune(state, fusion, sig)
            if tuned is None:
                entry = None
                if (
                    opts.enable_stitching
                    and opts.planner == "cost"
                    and len(fusion.members) > 1
                ):
                    entry = self._tune_stitched(state, fusion, sig)
                if entry is None:
                    state.demoted.extend(fusion.members)
                    continue
                self._apply_measured(state, entry, msig)
                if opts.dedup_kernels:
                    cache.put(entry)
                state.planned.append(
                    PlannedFusion(
                        fusion, entry, True,
                        measure_sig=msig, raw_signature=raw,
                    )
                )
                continue
            roots = fusion.roots
            entry = CacheEntry(
                signature=sig,
                solution=tuned.solution,
                memory=None,
                cost_s=tuned.cost_s,
                root_scheds=[tuned.solution.root_scheds[r.id] for r in roots],
                model_cost_s=tuned.cost_s,
            )
            self._apply_measured(state, entry, msig)
            if opts.dedup_kernels:
                cache.put(entry)
            state.planned.append(
                PlannedFusion(
                    fusion, entry, True,
                    tuned_from_disk=from_disk, measure_sig=msig,
                    raw_signature=raw,
                )
            )

    @staticmethod
    def _apply_measured(state, entry: CacheEntry, msig: str) -> None:
        """On a measured-store hit, the entry's actionable cost becomes the
        on-device time (the analytic number stays in ``model_cost_s`` for
        error reporting); on a miss, nothing changes and AutotunePass will
        measure the emitted kernel."""
        store = state.measured_store
        if store is None:
            return
        rec = store.get(msig)
        if rec is not None:
            entry.measured_cost_s = rec.cost_s
            entry.cost_s = rec.cost_s

    def _tune(self, state, fusion, sig):
        opts = state.options
        members, roots = fusion.members, fusion.roots
        if opts.dedup_kernels:
            hint = state.kernel_cache.tuning_hint(sig)
            if hint is not None and len(hint) == len(roots):
                try:
                    sol = resolve_schedules(
                        members,
                        roots,
                        {r.id: s for r, s in zip(roots, hint, strict=False)},
                        opts.replicate_limit,
                    )
                    return TunedPlan(sol, score(members, sol, state.library)), True
                except Unsatisfiable:
                    pass  # stale record — fall back to the full search
        tuned = tune(
            members,
            roots,
            state.library,
            max_blocks=opts.max_blocks,
            replicate_limit=opts.replicate_limit,
        )
        return tuned, False

    def _tune_stitched(self, state, fusion, sig) -> Optional[CacheEntry]:
        """No single schedule exists: resolve a multi-phase stitched plan and
        improve each phase's schedule with the performance library (the
        per-phase analogue of §4.3 tuning; phases whose only schedule needs
        the relaxed replicate limit keep the resolver's solution).

        This deliberately re-solves rather than reusing the fusion-pass
        scorer's solution: constant-like absorption extends the member list
        after planning, so the lowered phase structure must be derived from
        the FINAL members (``stitch_phases`` stays the planner's
        pre-absorption hint — a deterministic signature salt, not the
        lowering)."""
        opts = state.options
        members, roots = fusion.members, fusion.roots
        srl = _stitch_replicate_limit(opts)
        st = resolve_stitched(
            members,
            roots,
            replicate_limit=opts.replicate_limit,
            max_blocks=opts.max_blocks,
            stitch_replicate_limit=srl,
            stitch_max_blocks=opts.stitch_max_blocks,
        )
        if st is None:
            return None
        cap = min(opts.max_blocks, opts.stitch_max_blocks)
        for k, p in enumerate(st.phases):
            tuned = tune(
                p.members,
                p.roots,
                state.library,
                max_blocks=cap,
                replicate_limit=opts.replicate_limit,
            )
            if tuned is not None:
                st.phases[k] = PhaseSolution(p.members, p.roots, tuned.solution)
        cost = state.library.model.stitched_fusion_time(st)
        return CacheEntry(
            signature=sig,
            solution=None,
            memory=None,
            cost_s=cost,
            stitched=st,
            model_cost_s=cost,
        )


class MemoryPass(Pass):
    """VMEM scratch planning with the §5.1.2 feedback loop: on
    MemoryInfeasible, drop the deepest member, re-tune, retry.  Dropped
    members are demoted to standalone kernels."""

    name = "memory"

    def run(self, state: CompilationState) -> None:
        dead = set()  # entries whose representative proved unfusable
        kept: List[PlannedFusion] = []
        for p in state.planned:
            if not p.is_representative:
                if id(p.entry) in dead:
                    # the shared plan died — this instance runs standalone too
                    state.demoted.extend(p.fusion.members)
                    continue
                kept.append(p)  # shares the representative's plan
                continue
            if self._plan(state, p):
                kept.append(p)
            else:
                dead.add(id(p.entry))
                if state.options.dedup_kernels:
                    state.kernel_cache.remove(p.entry.signature)
        state.planned = kept

    def _plan(self, state, p: PlannedFusion) -> bool:
        opts = state.options
        fusion, entry = p.fusion, p.entry
        members, roots = fusion.members, fusion.roots
        if entry.stitched is not None:
            # stitched plans have no shrink loop: interface buffers are
            # required by construction, so an over-budget stitch (normally
            # vetoed during fusion) demotes to standalone kernels
            try:
                entry.memory = plan_stitched_memory(
                    entry.stitched, opts.vmem_limit
                )
            except MemoryInfeasible:
                state.demoted.extend(fusion.members)
                return False
            entry.kept_members = len(members)
            return True
        tuned: Optional[TunedPlan] = TunedPlan(entry.solution, entry.cost_s)
        dropped: List[Instruction] = []
        while tuned is not None:
            try:
                mem = plan_memory(members, roots, tuned.solution, opts.vmem_limit)
            except MemoryInfeasible:
                if len(members) <= 1:
                    tuned = None
                    break
                dropped.append(members[-1])
                members = members[:-1]
                fusion = FusedComputation(members, name=fusion.name)
                roots = fusion.roots
                tuned = tune(
                    members,
                    roots,
                    state.library,
                    max_blocks=opts.max_blocks,
                    replicate_limit=opts.replicate_limit,
                )
                continue
            # success
            state.demoted.extend(dropped)
            if dropped:
                p.shrunk = True
            p.fusion = fusion
            entry.solution = tuned.solution
            entry.cost_s = tuned.cost_s
            if dropped:
                # the structure changed: the pre-shrink measurement (and the
                # pre-shrink analytic estimate) no longer describe it
                entry.model_cost_s = tuned.cost_s
                entry.measured_cost_s = None
            entry.memory = mem
            entry.root_scheds = [
                tuned.solution.root_scheds[r.id] for r in roots
            ]
            entry.kept_members = len(members)
            if dropped and opts.dedup_kernels:
                # the persisted record (written pre-shrink by SchedulePass)
                # no longer describes the structure its signature hashes
                state.kernel_cache.discard_disk(entry.signature)
            return True
        # unfusable after all: every member (kept + dropped) runs standalone
        state.demoted.extend(fusion.members)
        state.demoted.extend(dropped)
        return False


class CodegenPass(Pass):
    """Emit one Pallas kernel per unique signature; bind instances.

    Representatives are planned before their hits (SchedulePass order), so
    an entry's kernel always exists by the time an instance binds to it.
    """

    name = "codegen"

    def run(self, state: CompilationState) -> None:
        for p in state.planned:
            entry = p.entry
            if p.is_representative:
                if entry.stitched is not None:
                    kernel = emit_stitched_fusion(
                        p.fusion, entry.stitched, entry.memory,
                        interpret=state.options.interpret,
                    )
                else:
                    kernel = emit_fusion(
                        p.fusion, entry.solution, entry.memory,
                        interpret=state.options.interpret,
                    )
                entry.kernel = kernel
                p.kernel = kernel
            else:
                # the representative may have shrunk under memory feedback;
                # apply the identical shrink to this instance before binding
                kept_n = entry.kept_members or len(p.fusion.members)
                if kept_n < len(p.fusion.members):
                    state.demoted.extend(p.fusion.members[kept_n:])
                    p.shrunk = True
                    p.fusion = FusedComputation(
                        p.fusion.members[:kept_n], name=p.fusion.name
                    )
                p.kernel = entry.kernel.bind(p.fusion)


class AutotunePass(Pass):
    """Measure each unique emitted kernel once and remember the result.

    Runs after CodegenPass (it needs the compiled callables) and only when
    ``options.autotune`` is set: every representative whose measured-store
    lookup missed in SchedulePass gets timed on device (warmup +
    median-of-``measure_repeats`` with ``block_until_ready``) and filed
    under its measure key, so the NEXT compile's scorer and SchedulePass see
    real costs.  Within this compile the plan is already committed — the
    measurement-guided loop closes across compiles, never by re-planning
    mid-pipeline.  Misses here are the store's cold-start cost; hits make
    the pass free.
    """

    name = "autotune"

    def run(self, state: CompilationState) -> None:
        store = state.measured_store
        if store is None or not getattr(state.options, "autotune", False):
            return
        repeats = getattr(state.options, "measure_repeats", 5)
        for p in state.planned:
            if not p.is_representative or p.kernel is None:
                continue
            entry = p.entry
            if entry.measured_cost_s is not None:
                continue  # store hit (or already measured this compile)
            t = measure_kernel(p.kernel, repeats=repeats)
            model_s = (
                entry.model_cost_s
                if entry.model_cost_s is not None
                else entry.cost_s
            )
            store.put(p.measure_sig, t, model_s=model_s, repeats=repeats)
            entry.measured_cost_s = t
            state.measurements_taken += 1


class FinalizePass(Pass):
    """Assemble the final FusionPlan, the planned executable, and stats."""

    name = "finalize"

    def run(self, state: CompilationState) -> None:
        # imported here: compiler is the facade above this module
        from .compiler import build_outputs

        build_outputs(state)


def default_pipeline() -> PassPipeline:
    return PassPipeline(
        [
            SubModulePass(),
            ShardingPass(),
            FusionPass(),
            SchedulePass(),
            MemoryPass(),
            CodegenPass(),
            AutotunePass(),
            FinalizePass(),
        ]
    )
