"""Measured-cost autotuning — on-device timing closes the loop over the
analytic ``LatencyModel``.

Every fusion decision the cost planner makes trusts ``core/latency.py``'s
roofline math.  The XLA fusion study (arXiv:2301.13062) documents exactly
where such analytic models mispredict — replication duplication, occupancy,
cross-block cache effects — and Tensor Comprehensions (arXiv:1802.04730)
shows the remedy: *autotune on device and remember the result*.  This module
is that remedy for the FusionStitching planner:

  * ``measure_callable`` / ``measure_kernel`` time a compiled lowering with
    warmup + median-of-k, fencing async dispatch with ``block_until_ready``.
    In ``interpret`` mode the same path runs on CPU, so CI exercises the
    whole loop end to end (the timings then describe the interpreter, not
    the TPU — the device fingerprint keeps the two worlds apart).
  * ``emit_group`` compiles an *arbitrary* candidate member set as one
    kernel through the existing tune -> memory-plan -> codegen path —
    single-schedule when one exists, multi-phase stitched otherwise — so
    the harness can time stitched-vs-split alternatives, tile/block choices
    (via ``max_blocks``), and phase partitions, not just committed plans.
  * ``MeasuredCostStore`` persists results as versioned JSON rows beside the
    ``KernelCache`` disk records, keyed by ``fusion_signature`` + a
    ``DeviceSpec``/backend fingerprint.  Stale-schema, corrupt, or
    wrong-device rows are evicted on read (counted, never raised), so a
    format bump or a device swap degrades to a cold retune.

The planner side lives in ``core/fusion.py`` (``FusionScorer`` prefers a
measured cost when a key hits, analytic as the cold-start prior) and
``core/pipeline.py`` (``AutotunePass`` measures each unique committed kernel
once and remembers it).  ``CompileStats`` reports
``measured_hits/measured_misses/measurements_taken/model_error_pct`` so the
analytic model's error is visible per compile — and per bench graph in
``benchmarks/baseline.json``.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

import jax

from .codegen import StitchedKernel, emit_fusion, emit_stitched_fusion
from .fusion import FusedComputation
from .ir import Instruction
from .latency import TPU_V5E, DeviceSpec
from .memory import MemoryInfeasible, plan_memory, plan_stitched_memory
from .perf_library import JsonStore, PerfLibrary
from .schedule import resolve_stitched
from .tuning import tune

# Version of the on-disk measured-cost row schema.  Bump whenever the
# persisted payload changes shape; rows written under any other version are
# evicted on read instead of crashing a warm process.
MEASURE_SCHEMA_VERSION = 1


def device_fingerprint(
    spec: DeviceSpec = TPU_V5E, interpret: bool = True
) -> str:
    """Fingerprint of the measurement substrate: the DeviceSpec constants
    plus the runtime backend actually executing kernels (platform + device
    kind + interpret flag).  Interpret-mode CPU timings must never serve a
    real-TPU compile and vice versa — they describe different machines."""
    dev = jax.devices()[0]
    feats = (
        spec.fingerprint(),
        jax.default_backend(),
        getattr(dev, "device_kind", "unknown"),
        bool(interpret),
    )
    return hashlib.sha256(repr(feats).encode()).hexdigest()[:16]


@dataclass(frozen=True)
class MeasuredCost:
    """One remembered measurement: wall-clock seconds for a fusion signature
    on a device, with the analytic prediction recorded at measure time so
    model error stays reportable without re-deriving it."""

    cost_s: float
    model_s: float
    repeats: int


class MeasuredCostStore:
    """Versioned persistent map: (device fingerprint, fusion signature) ->
    measured kernel seconds.

    Storage rides the same atomic ``JsonStore`` protocol as the PerfLibrary
    and the KernelCache tuning records (write-temp + fsync + ``os.replace``;
    an interrupted save can never corrupt the store).  ``get`` validates
    every row — schema version, device field, payload shape — and evicts
    rather than raises: a bumped schema, a corrupted file, or rows from
    another device all degrade to cold-start misses, so the planner falls
    back to the analytic model and plan *feasibility* is never affected.
    """

    def __init__(
        self, path: Optional[str] = None, device_fp: Optional[str] = None
    ):
        self._disk = JsonStore(path)
        self.device_fp = device_fp or device_fingerprint()
        self.hits = 0
        self.misses = 0
        self.stale_discards = 0
        self.measurements_taken = 0

    @property
    def path(self) -> Optional[str]:
        return self._disk.path

    def key(self, signature: str) -> str:
        return f"{self.device_fp}|{signature}"

    def get(self, signature: str) -> Optional[MeasuredCost]:
        rec = self._disk.get(self.key(signature))
        if rec is None:
            self.misses += 1
            return None
        try:
            if rec.get("version") != MEASURE_SCHEMA_VERSION:
                raise ValueError(f"schema version {rec.get('version')!r}")
            if rec.get("device") != self.device_fp:
                raise ValueError(f"device {rec.get('device')!r}")
            cost = MeasuredCost(
                cost_s=float(rec["cost_s"]),
                model_s=float(rec.get("model_s", 0.0)),
                repeats=int(rec.get("repeats", 1)),
            )
            if not (cost.cost_s > 0.0) or not np.isfinite(cost.cost_s):
                raise ValueError(f"cost_s {rec['cost_s']!r}")
        except (ValueError, TypeError, KeyError, AttributeError):
            self._disk.pop(self.key(signature))
            self.stale_discards += 1
            self.misses += 1
            return None
        self.hits += 1
        return cost

    def put(
        self,
        signature: str,
        cost_s: float,
        model_s: float = 0.0,
        repeats: int = 1,
    ) -> None:
        self.measurements_taken += 1
        self._disk.put(
            self.key(signature),
            {
                "version": MEASURE_SCHEMA_VERSION,
                "device": self.device_fp,
                "cost_s": float(cost_s),
                "model_s": float(model_s),
                "repeats": int(repeats),
            },
        )

    def save(self) -> None:
        self._disk.save()

    def __len__(self) -> int:
        return len(self._disk)

    def __contains__(self, signature: str) -> bool:
        return self.key(signature) in self._disk


# --------------------------------------------------------------------------
# The timing harness
# --------------------------------------------------------------------------


def measure_callable(fn, args: Sequence, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall-clock seconds of ``fn(*args)`` over ``repeats`` runs.

    ``warmup`` untimed calls first absorb trace/compile cost, then each
    timed call is fenced with ``jax.block_until_ready`` so async dispatch
    cannot leak one run's work into the next run's clock.
    """
    repeats = max(1, int(repeats))
    for _ in range(max(0, int(warmup))):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _random_args(inputs: List[Instruction], rng) -> List:
    """Random device arrays matching a kernel's input shapes/dtypes.
    Timing does not depend on values for these kernels (no data-dependent
    control flow in StitchIR), so uniform noise is enough; arrays are
    materialized on device *before* the clock starts."""
    args = []
    for i in inputs:
        dt = np.dtype(i.dtype)
        if dt == np.bool_:
            a = rng.rand(*i.shape) > 0.5
        elif np.issubdtype(dt, np.integer):
            hi = max(2, i.shape[0] if i.shape else 2)
            a = rng.randint(0, hi, size=i.shape).astype(dt)
        else:
            a = rng.uniform(-1, 1, size=i.shape).astype(dt)
        args.append(jax.numpy.asarray(a))
    return args


def measure_kernel(
    kernel: StitchedKernel, repeats: int = 5, warmup: int = 1, seed: int = 0
) -> float:
    """Time one compiled kernel on random inputs (median of ``repeats``)."""
    rng = np.random.RandomState(seed)
    args = _random_args(kernel.inputs, rng)
    return measure_callable(kernel, args, repeats=repeats, warmup=warmup)


# --------------------------------------------------------------------------
# Candidate lowerings: compile an arbitrary member set through the real path
# --------------------------------------------------------------------------


def emit_group(
    members: List[Instruction],
    library: Optional[PerfLibrary] = None,
    *,
    vmem_limit: int = 4 * 1024 * 1024,
    replicate_limit: int = 512 * 1024,
    max_blocks: int = 4096,
    stitch_replicate_limit: Optional[int] = None,
    stitch_max_blocks: int = 64,
    interpret: bool = True,
) -> Optional[StitchedKernel]:
    """Compile ``members`` as ONE kernel through the production path: §4.3
    schedule tuning + §5.1 memory planning + §5.2 emission, falling back to
    the multi-phase stitched lowering when no single schedule exists.

    This is the harness's candidate-lowering entry point: any partition the
    planner can score — the whole group (stitched), a split piece, a
    singleton — can be emitted and timed without going through a full module
    compile.  Returns None when the group is infeasible under the limits
    (exactly the sets the scorer returns None for).
    """
    lib = library or PerfLibrary()
    fusion = FusedComputation(list(members), name="measured")
    roots = fusion.roots
    tuned = tune(
        members, roots, lib,
        max_blocks=max_blocks, replicate_limit=replicate_limit,
    )
    if tuned is not None:
        try:
            mem = plan_memory(members, roots, tuned.solution, vmem_limit)
        except MemoryInfeasible:
            return None
        return emit_fusion(fusion, tuned.solution, mem, interpret=interpret)
    srl = vmem_limit if stitch_replicate_limit is None else stitch_replicate_limit
    st = resolve_stitched(
        members, roots,
        replicate_limit=replicate_limit, max_blocks=max_blocks,
        stitch_replicate_limit=srl, stitch_max_blocks=stitch_max_blocks,
    )
    if st is None:
        return None
    try:
        mem = plan_stitched_memory(st, vmem_limit)
    except MemoryInfeasible:
        return None
    return emit_stitched_fusion(fusion, st, mem, interpret=interpret)


def measure_group(
    members: List[Instruction],
    library: Optional[PerfLibrary] = None,
    repeats: int = 5,
    seed: int = 0,
    **emit_kwargs,
) -> Optional[float]:
    """Median measured seconds for ``members`` lowered as one kernel, or
    None when the group has no feasible lowering under the limits."""
    kernel = emit_group(members, library, **emit_kwargs)
    if kernel is None:
        return None
    return measure_kernel(kernel, repeats=repeats, seed=seed)
