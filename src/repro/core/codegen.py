"""IrEmitterStitched — block-composition code generation (paper §5.2).

Emits ONE ``pl.pallas_call`` per fused computation:

  * the launch grid is ``(blocks,)`` — the paper's CTA count, here the
    Pallas grid (TPU grid programs pipeline HBM->VMEM DMAs);
  * every fusion input/output gets a ``BlockSpec`` whose block shape is the
    propagated schedule's chunk and whose ``index_map`` is the schedule's
    block-index arithmetic;
  * ops whose MemoryPlan action is ALLOC/SHARE write their block tile into a
    VMEM scratch ref (``pltpu.VMEM`` via ``scratch_shapes``) and consumers
    read it back — block composition through scratchpad, exactly the paper's
    shared-memory stitching; slot sharing from the dominance-tree plan reuses
    one scratch ref for several ops;
  * INLINE ops are evaluated as straight vector expressions — thread
    composition (XLA's elemental emitter analogue, Algorithm 2's fallback
    branch).

The same ``apply_op`` interpreter evaluates ops here (on VMEM tiles) and in
the reference executor (on full arrays), so kernels match the oracle by
construction up to float reassociation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; interpret mode accepts them on CPU too
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except ImportError:  # pragma: no cover
    _VMEM = None

from .fusion import FusedComputation
from .ir import Instruction, apply_op
from .memory import ALLOC, INLINE, SHARE, MemoryPlan, StitchedMemoryPlan
from .schedule import (
    REPLICATED,
    Sched,
    ScheduleSolution,
    StitchedSolution,
    block_index,
    chunk_shape,
    propagate,
)


def _starts(shape, sched: Sched, b):
    idx = block_index(shape, sched, b)
    cs = chunk_shape(shape, sched)
    return tuple(i * c for i, c in zip(idx, cs, strict=False))


def _adapt(val, opnd: Instruction, stored: Sched, needed: Sched, b):
    """Convert an operand's stored form to the consumer's needed form."""
    if stored == needed:
        return val
    if stored.kind == "replicated" and needed.kind == "chunked":
        return jax.lax.dynamic_slice(
            val, _starts(opnd.shape, needed, b), chunk_shape(opnd.shape, needed)
        )
    if needed.kind == "replicated" and stored.kind == "replicated":
        return val
    raise AssertionError(
        f"cannot adapt {opnd.name}: stored {stored}, needed {needed}"
    )


def _emit_instr(instr: Instruction, sched: Sched, ovals: List, b):
    """Evaluate one instruction on block tiles (thread-composition body)."""
    op = instr.opcode
    a = instr.attrs
    out_chunk = chunk_shape(instr.shape, sched)

    if op in ("reshape", "bitcast"):
        return jnp.reshape(ovals[0], out_chunk)

    if op == "broadcast":
        dims = tuple(a["dims"])
        opnd = instr.operands[0]
        v = ovals[0]
        if sched.kind == "chunked" and tuple(v.shape) == tuple(opnd.shape):
            # replicated operand feeding a chunked broadcast: slice the
            # operand window this block's output chunk maps onto.
            ost = _starts(instr.shape, sched, b)
            starts = tuple(
                ost[dims[j]] if opnd.shape[j] != 1 else 0
                for j in range(len(dims))
            )
            sizes = tuple(
                out_chunk[dims[j]] if opnd.shape[j] != 1 else 1
                for j in range(len(dims))
            )
            v = jax.lax.dynamic_slice(v, starts, sizes)
        return jax.lax.broadcast_in_dim(v, out_chunk, dims)

    if op == "iota":
        d = a["dim"]
        base = jax.lax.broadcasted_iota(instr.dtype, out_chunk, d)
        if sched.kind == "chunked":
            start = _starts(instr.shape, sched, b)[d]
            base = base + jnp.asarray(start, dtype=instr.dtype)
        return base

    return apply_op(instr, *ovals)


@dataclass
class StitchedKernel:
    """A compiled stitched kernel: call with input arrays in ``inputs`` order.

    Single-phase (schedule-consistent) kernels carry a ``solution``;
    multi-phase stitched kernels carry a ``stitched`` solution instead and
    ``solution`` is None.
    """

    fusion: FusedComputation
    solution: Optional[ScheduleSolution]
    plan: object                         # MemoryPlan | StitchedMemoryPlan
    fn: Callable
    inputs: List[Instruction]
    outputs: List[Instruction]
    stitched: Optional[StitchedSolution] = None

    @property
    def blocks(self) -> int:
        if self.stitched is not None:
            return self.stitched.blocks
        return self.solution.blocks

    @property
    def num_phases(self) -> int:
        return self.stitched.num_phases if self.stitched is not None else 1

    def __call__(self, *args):
        return self.fn(*args)

    def bind(self, fusion: FusedComputation) -> "StitchedKernel":
        """Re-bind this kernel to a structurally-identical fusion instance.

        The compiled callable is purely positional, so any fusion with the
        same fusion-signature can share it; only the instruction lists used
        by the runtime to gather arguments and scatter results change.
        ``solution``/``plan`` keep referring to the representative instance.
        """
        return StitchedKernel(
            fusion, self.solution, self.plan, self.fn,
            fusion.inputs, fusion.roots, stitched=self.stitched,
        )


def emit_fusion(
    fusion: FusedComputation,
    solution: ScheduleSolution,
    plan: MemoryPlan,
    interpret: bool = True,
) -> StitchedKernel:
    members = fusion.members
    roots = fusion.roots
    inputs = fusion.inputs
    assign = solution.assignment
    blocks = solution.blocks
    member_ids = {m.id for m in members}
    for m in members:
        if m.is_collective:
            # unreachable through the planner (collectives are not fusable
            # and have no schedule) — fail loudly rather than emit a kernel
            # that silently drops the cross-device reduction
            raise ValueError(
                f"{m.name}: collective {m.opcode} cannot be emitted inside "
                "a kernel; it must stay a standalone schedule break"
            )

    def in_spec(instr: Instruction) -> pl.BlockSpec:
        sched = assign.get(instr.id, REPLICATED)
        cs = chunk_shape(instr.shape, sched)
        return pl.BlockSpec(
            cs, functools.partial(block_index, tuple(instr.shape), sched)
        )

    in_specs = [in_spec(i) for i in inputs]
    out_specs = [in_spec(r) for r in roots]
    out_shape = [jax.ShapeDtypeStruct(tuple(r.shape), r.dtype) for r in roots]
    scratch_shapes = []
    if _VMEM is not None:
        for sshape, sdtype in plan.slots:
            scratch_shapes.append(_VMEM(tuple(sshape), np.dtype(sdtype)))

    n_in, n_out = len(inputs), len(roots)
    root_pos = {r.id: j for j, r in enumerate(roots)}

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in: n_in + n_out]
        scratch = refs[n_in + n_out:]
        b = pl.program_id(0)

        stored: Dict[int, Sched] = {}
        vals: Dict[int, object] = {}
        for i, instr in enumerate(inputs):
            vals[instr.id] = in_refs[i][...]
            stored[instr.id] = assign.get(instr.id, REPLICATED)

        for m in members:
            sched = assign[m.id]
            if m.opcode == "constant":
                vals[m.id] = apply_op(m)
                stored[m.id] = REPLICATED
                continue
            needed = propagate(m, sched)
            ovals = [
                _adapt(vals[o.id], o, stored[o.id], ns, b)
                for o, ns in zip(m.operands, needed, strict=False)
            ]
            v = _emit_instr(m, sched, ovals, b)
            entry = plan.entries.get(m.id)
            if entry is not None and entry.action in (ALLOC, SHARE) and scratch:
                # block composition: stitch through the VMEM scratch slot
                ref = scratch[entry.slot]
                ref[...] = v
                v = ref[...]
            vals[m.id] = v
            stored[m.id] = sched
            if m.id in root_pos:
                out_refs[root_pos[m.id]][...] = v

    call = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )

    def fn(*args):
        outs = call(*args)
        return outs if isinstance(outs, (list, tuple)) else (outs,)

    return StitchedKernel(fusion, solution, plan, fn, inputs, roots)


# --------------------------------------------------------------------------
# Multi-phase stitched emission: phases as sequential loops in ONE kernel
# --------------------------------------------------------------------------


def _full_spec(instr: Instruction) -> pl.BlockSpec:
    """Whole-tensor BlockSpec: the block IS the array (grid is trivial)."""
    shape = tuple(instr.shape)
    return pl.BlockSpec(shape, lambda b, _n=len(shape): (0,) * _n)


def _store_chunk(ref, instr: Instruction, sched: Sched, v, b: int):
    """Write one block's value into a full-shape ref at static offsets."""
    if sched.kind == "replicated" or not instr.shape:
        ref[...] = v
        return
    starts = _starts(instr.shape, sched, b)
    cs = chunk_shape(instr.shape, sched)
    ref[tuple(slice(s, s + c) for s, c in zip(starts, cs, strict=False))] = v


def emit_stitched_fusion(
    fusion: FusedComputation,
    stitched: StitchedSolution,
    plan: StitchedMemoryPlan,
    interpret: bool = True,
) -> StitchedKernel:
    """Emit ONE Pallas kernel running every phase of a stitched group.

    The launch grid is trivial — each phase's grid is lowered as a
    *sequential loop* over that phase's own block schedule, unrolled at
    trace time (phase grids are capped by ``stitch_max_blocks``).  Inputs
    and outputs are whole-tensor blocks; every interface tensor is staged
    FULLY in a VMEM scratch ref by its producer phase and re-tiled (sliced
    per-block) by its consumer phases — shared-memory stitching across
    schedule breaks, per the FusionStitching follow-up work.
    """
    if _VMEM is None:  # pragma: no cover - jax always ships pallas.tpu here
        raise RuntimeError("stitched emission needs pallas TPU scratch spaces")
    for m in fusion.members:
        if m.is_collective:
            raise ValueError(
                f"{m.name}: collective {m.opcode} cannot be emitted inside "
                "a stitched kernel; it must stay a standalone schedule break"
            )
    inputs = fusion.inputs
    roots = fusion.roots

    in_specs = [_full_spec(i) for i in inputs]
    out_specs = [_full_spec(r) for r in roots]
    out_shape = [jax.ShapeDtypeStruct(tuple(r.shape), r.dtype) for r in roots]

    # scratch layout: interface staging buffers first, then each phase's
    # chunk-granular slots at a per-phase offset
    scratch_shapes = []
    iface_slot: Dict[int, int] = {}
    for iid, buf in plan.interfaces.items():
        iface_slot[iid] = len(scratch_shapes)
        scratch_shapes.append(_VMEM(tuple(buf.shape), np.dtype(buf.dtype)))
    phase_offsets: List[int] = []
    for pplan in plan.phase_plans:
        phase_offsets.append(len(scratch_shapes))
        for sshape, sdtype in pplan.slots:
            scratch_shapes.append(_VMEM(tuple(sshape), np.dtype(sdtype)))

    n_in, n_out = len(inputs), len(roots)
    root_pos = {r.id: j for j, r in enumerate(roots)}

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in: n_in + n_out]
        scratch = refs[n_in + n_out:]

        global_vals: Dict[int, object] = {}
        for i, instr in enumerate(inputs):
            global_vals[instr.id] = in_refs[i][...]

        for pk, phase in enumerate(stitched.phases):
            assign = phase.solution.assignment
            pplan = plan.phase_plans[pk]
            off = phase_offsets[pk]
            # staged interfaces this phase consumes, read back whole — only
            # once their producer phase has fully run (same-phase consumers
            # use the block-local value instead)
            for m in phase.members:
                for o in m.operands:
                    if (
                        o.id in iface_slot
                        and o.id not in global_vals
                        and plan.interfaces[o.id].produced_phase < pk
                    ):
                        global_vals[o.id] = scratch[iface_slot[o.id]][...]
            for b in range(phase.solution.blocks):
                vals: Dict[int, object] = {}
                stored: Dict[int, Sched] = {}
                for m in phase.members:
                    sched = assign[m.id]
                    if m.opcode == "constant":
                        v = apply_op(m)
                        sched = REPLICATED
                    else:
                        needed = propagate(m, sched)
                        ovals = []
                        for o, ns in zip(m.operands, needed, strict=False):
                            if o.id in vals:
                                ov = _adapt(vals[o.id], o, stored[o.id], ns, b)
                            else:
                                # kernel input or staged interface: stored whole
                                ov = _adapt(
                                    global_vals[o.id], o, REPLICATED, ns, b
                                )
                            ovals.append(ov)
                        v = _emit_instr(m, sched, ovals, b)
                        entry = pplan.entries.get(m.id)
                        if entry is not None and entry.action in (ALLOC, SHARE):
                            ref = scratch[off + entry.slot]
                            ref[...] = v
                            v = ref[...]
                    vals[m.id] = v
                    stored[m.id] = sched
                    if m.id in iface_slot:
                        _store_chunk(scratch[iface_slot[m.id]], m, sched, v, b)
                    if m.id in root_pos:
                        _store_chunk(out_refs[root_pos[m.id]], m, sched, v, b)

    call = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )

    def fn(*args):
        outs = call(*args)
        return outs if isinstance(outs, (list, tuple)) else (outs,)

    return StitchedKernel(
        fusion, None, plan, fn, inputs, roots, stitched=stitched
    )
