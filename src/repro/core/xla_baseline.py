"""XLA ``GpuInstructionFusion``-like baseline — the paper's comparison point.

This is a faithful re-statement of XLA's classic static ``ShouldFuse`` rules
(the rules the paper says are "compromised by exceptions, such as expensive
elementwise ops, column reductions, batched matmuls, or memory layout
transposes"):

  * loop fusion only: a producer is absorbed into its consumers when it is an
    elementwise / shape-modulation op;
  * producers may be *duplicated* into several consumer kernels, but
    **expensive** elementwise ops are never duplicated (single-user only);
  * ``reduce`` may only be a fusion *root* (input fusion), never an interior
    node of a loop fusion;
  * ``dot`` is never fused (library call);
  * no horizontal (multi-output, intra-layer) fusion.

Kernel count = number of non-absorbed instructions.  FusionStitching's
fusion-ratio benchmark (paper Fig. 7) divides its kernel count by this one.
"""
from __future__ import annotations

from typing import Dict, List, Set

from .ir import Instruction, Module


def _constant_like(instr: Instruction) -> bool:
    if instr.opcode in ("constant", "iota"):
        return True
    if instr.opcode in ("broadcast", "reshape", "bitcast", "transpose"):
        return all(_constant_like(o) for o in instr.operands)
    return False

_ABSORBING = frozenset(
    {"elementwise", "select", "reshape", "bitcast", "transpose", "broadcast",
     "reduce", "concat"}
)
_LOOP_FUSIBLE = frozenset(
    {"elementwise", "select", "reshape", "bitcast", "transpose", "broadcast",
     "iota"}
)


def _can_absorb(user: Instruction) -> bool:
    return user.opcode in _ABSORBING


def xla_baseline_kernels(module: Module) -> List[Instruction]:
    """Kernel roots under the XLA-like rules (excluding params/constants)."""
    absorbed: Set[int] = set()
    for instr in module.instructions:
        if instr.opcode in ("parameter", "constant"):
            continue
        if instr.opcode not in _LOOP_FUSIBLE:
            continue  # reduce/dot/gather/concat are never interior
        if not instr.users:
            continue  # module output must materialize
        if instr.is_expensive and len(instr.users) > 1:
            continue  # XLA: never duplicate expensive ops
        if all(_can_absorb(u) for u in instr.users):
            absorbed.add(instr.id)
    return [
        i
        for i in module.instructions
        if i.id not in absorbed
        and i.opcode not in ("parameter", "constant")
        and not _constant_like(i)
    ]


def xla_baseline_kernel_count(module: Module, exclude_library: bool = True) -> int:
    """``get`` projections are free (they name one output of a loop call);
    a ``call`` loop counts as its body's baseline kernels — XLA compiles a
    ``while``/``scan`` body once into its own kernels (launched per
    iteration, but Fig. 7 compares kernel *counts*, not launches)."""
    total = 0
    for r in xla_baseline_kernels(module):
        if r.opcode == "get":
            continue
        if r.is_collective:
            continue  # ICI traffic in ANY compiler — never a kernel launch
        if r.opcode == "call":
            total += xla_baseline_kernel_count(
                r.attrs["body"], exclude_library
            )
            continue
        if exclude_library and r.is_library_call:
            continue
        total += 1
    return total


def xla_baseline_groups(module: Module) -> Dict[int, List[Instruction]]:
    """Kernel root id -> member closure (absorbed producers, duplicated)."""
    roots = xla_baseline_kernels(module)
    root_ids = {r.id for r in roots}
    groups: Dict[int, List[Instruction]] = {}
    for root in roots:
        members: List[Instruction] = []
        seen: Set[int] = set()
        stack = [root]
        while stack:
            cur = stack.pop()
            if cur.id in seen:
                continue
            seen.add(cur.id)
            members.append(cur)
            for op in cur.operands:
                if op.id not in root_ids and op.opcode not in (
                    "parameter",
                    "constant",
                ):
                    # op was absorbed (into possibly several kernels)
                    stack.append(op)
        groups[root.id] = members
    return groups
