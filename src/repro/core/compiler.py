"""The FusionStitching compiler pipeline — paper Fig. 4.

HloModule (StitchIR) -> computation fusion -> schedule planning -> code
generation, with the memory-planning feedback loop into the
ScheduleConsistencyChecker (§5.1.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from . import span as span_lib
from .codegen import StitchedKernel, emit_fusion
from .executor import StitchedExecutable
from .fusion import FusedComputation, FusionConfig, FusionPlan, deep_fuse
from .ir import Module
from .memory import MemoryInfeasible, MemoryPlan, plan_memory
from .perf_library import CostModel, PerfLibrary
from .schedule import any_satisfiable
from .tuning import TunedPlan, tune
from .xla_baseline import xla_baseline_kernel_count


@dataclass
class StitchOptions:
    fuse_dot: bool = True                    # user decision (paper §2.1)
    vmem_limit: int = 4 * 1024 * 1024        # scratch budget per kernel
    replicate_limit: int = 512 * 1024
    max_blocks: int = 4096
    ew_footprint_limit: int = 64 * 1024 * 1024
    max_fusion_ops: int = 256
    perf_library_path: Optional[str] = None
    interpret: bool = True                   # CPU validation; False on TPU


@dataclass
class FusionReport:
    name: str
    num_ops: int
    blocks: int
    cost_s: float
    scratch_bytes: int
    shared_bytes: int
    num_shrinks: int
    roots: List[str]


@dataclass
class CompileStats:
    stitched_kernels: int
    standalone_kernels: int
    library_calls: int
    xla_baseline_kernels: int
    predicted_time_s: float
    library_time_s: float = 0.0
    reports: List[FusionReport] = field(default_factory=list)

    @property
    def fusion_ratio(self) -> float:
        """paper Fig. 7: our kernel count / XLA baseline kernel count."""
        ours = self.stitched_kernels + self.standalone_kernels
        return ours / self.xla_baseline_kernels if self.xla_baseline_kernels else 1.0

    @property
    def smem_average(self) -> float:
        allocs = [r.scratch_bytes for r in self.reports]
        return float(np.mean(allocs)) if allocs else 0.0

    @property
    def smem_max(self) -> int:
        return max((r.scratch_bytes for r in self.reports), default=0)

    @property
    def total_shrinks(self) -> int:
        return sum(r.num_shrinks for r in self.reports)

    @property
    def shared_ratio(self) -> float:
        tot = sum(r.scratch_bytes for r in self.reports)
        sh = sum(r.shared_bytes for r in self.reports)
        return sh / tot if tot else 0.0


class CompiledModule:
    def __init__(self, executable: StitchedExecutable, stats: CompileStats):
        self.executable = executable
        self.stats = stats

    def __call__(self, feeds):
        return self.executable(feeds)


def compile_module(
    module: Module, options: Optional[StitchOptions] = None
) -> CompiledModule:
    opts = options or StitchOptions()
    lib = PerfLibrary(opts.perf_library_path)

    # --- ScheduleConsistencyChecker with memory feedback (Fig. 4) --------
    def consistency(roots, members) -> bool:
        sol = any_satisfiable(
            members,
            roots,
            replicate_limit=opts.replicate_limit,
            max_blocks=opts.max_blocks,
        )
        if sol is None:
            return False
        try:
            plan_memory(members, roots, sol, opts.vmem_limit)
        except MemoryInfeasible:
            return False
        return True

    fcfg = FusionConfig(
        fuse_dot=opts.fuse_dot,
        ew_footprint_limit=opts.ew_footprint_limit,
        max_fusion_ops=opts.max_fusion_ops,
        consistency=consistency,
    )
    plan = deep_fuse(module, fcfg)

    kernels: Dict[str, StitchedKernel] = {}
    reports: List[FusionReport] = []
    predicted = 0.0
    final_fusions: List[FusedComputation] = []
    extra_standalone = []

    for fusion in plan.fusions:
        members, roots = fusion.members, fusion.roots
        tuned = tune(
            members,
            roots,
            lib,
            max_blocks=opts.max_blocks,
            replicate_limit=opts.replicate_limit,
        )
        mem: Optional[MemoryPlan] = None
        # memory feedback loop: drop deepest members until the plan fits
        while tuned is not None:
            try:
                mem = plan_memory(members, roots, tuned.solution, opts.vmem_limit)
                break
            except MemoryInfeasible:
                if len(members) <= 1:
                    tuned = None
                    break
                members = members[:-1]
                fusion = FusedComputation(members, name=fusion.name)
                roots = fusion.roots
                tuned = tune(
                    members,
                    roots,
                    lib,
                    max_blocks=opts.max_blocks,
                    replicate_limit=opts.replicate_limit,
                )
        if tuned is None or mem is None:
            # unfusable after all: emit every member standalone
            extra_standalone.extend(fusion.members)
            continue
        kernel = emit_fusion(fusion, tuned.solution, mem, interpret=opts.interpret)
        kernels[fusion.name] = kernel
        final_fusions.append(fusion)
        predicted += tuned.cost_s
        reports.append(
            FusionReport(
                fusion.name,
                len(members),
                tuned.solution.blocks,
                tuned.cost_s,
                mem.total_bytes,
                mem.shared_bytes,
                mem.num_shrinks,
                [r.name for r in roots],
            )
        )

    plan = FusionPlan(final_fusions, plan.standalone + extra_standalone, module)
    library_time = 0.0
    for s in plan.standalone:
        # standalone kernels are costed as single-op launches; library-call
        # time (cuBLAS/MXU dots) is tracked separately — it is common to the
        # baseline and the stitched build (paper Fig. 6/8 methodology).
        t = lib.model.kernel_time(1, lib.model.op_time(s, _whole(s), 1))
        if s.is_library_call:
            library_time += t
        else:
            predicted += t

    executable = StitchedExecutable(module, plan, kernels)
    st = executable.launch_stats()
    stats = CompileStats(
        stitched_kernels=st.stitched_kernels,
        standalone_kernels=st.standalone_kernels,
        library_calls=st.library_calls,
        xla_baseline_kernels=xla_baseline_kernel_count(module),
        predicted_time_s=predicted,
        library_time_s=library_time,
        reports=reports,
    )
    if opts.perf_library_path:
        lib.save()
    return CompiledModule(executable, stats)


def _whole(instr):
    from .schedule import REPLICATED

    return REPLICATED
