"""The FusionStitching compiler facade — paper Fig. 4.

The actual pipeline (deep fusion -> schedule tuning -> memory planning ->
code generation, with the memory feedback loop of §5.1.2 and
fusion-signature kernel deduplication) lives in ``pipeline.py`` as explicit
passes over a ``CompilationState``.  ``compile_module`` stays the one-call
entry point: it builds the state, runs the default pass pipeline, and
returns a ``CompiledModule`` wrapping the planned executable and stats.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .codegen import StitchedKernel
from .executor import StitchedExecutable
from .fusion import FusionPlan
from .perf_library import PerfLibrary
from .pipeline import CompilationState, default_pipeline
from .signature import KernelCache
from .xla_baseline import xla_baseline_kernel_count


@dataclass
class StitchOptions:
    fuse_dot: bool = True                    # user decision (paper §2.1)
    vmem_limit: int = 4 * 1024 * 1024        # scratch budget per kernel
    replicate_limit: int = 512 * 1024
    max_blocks: int = 4096
    ew_footprint_limit: int = 64 * 1024 * 1024
    max_fusion_ops: int = 256
    perf_library_path: Optional[str] = None
    kernel_cache_path: Optional[str] = None  # persistent tuning records
    dedup_kernels: bool = True               # fusion-signature kernel reuse
    interpret: bool = True                   # CPU validation; False on TPU
    # "cost": candidate-plan exploration under the shared LatencyModel with
    # the greedy result as the floor; "greedy": the paper's Algorithm 1.
    planner: str = "cost"
    # Multi-phase stitching (arXiv:1911.11576 / 2009.10924): groups with no
    # single consistent schedule lower as ONE kernel of sequential phases
    # stitched through full VMEM staging buffers, and the planner may pack
    # independent same-layer sink towers into one kernel.  Effective only
    # with planner="cost" — planner="greedy" stays the paper's hard veto.
    enable_stitching: bool = True
    # Replicate limit inside stitched phases (None = vmem_limit): a phase's
    # working set lives in VMEM staging, so replication is bounded by the
    # stitched memory plan rather than the per-block limit above.
    stitch_replicate_limit: Optional[int] = None
    # Cap on any ONE phase's grid: phases lower as sequential (trace-time
    # unrolled) loops inside the kernel, so this bounds emitted code size.
    stitch_max_blocks: int = 64
    # Runtime replay mode: True routes CompiledModule calls through the
    # single-dispatch traced ExecutionPlan (jax.jit of the pre-bound step
    # loop, released slots donated); False keeps the eager per-step loop.
    # Runtime-only — deliberately NOT part of the kernel-cache options
    # fingerprint (it changes how a plan is replayed, never what is
    # tuned/emitted).
    jit_replay: bool = True
    # Measured-cost autotuning (core/measure.py).  autotune=True times each
    # unique emitted kernel (warmup + median-of-measure_repeats) and files
    # the result in a MeasuredCostStore; the planner prefers stored
    # measurements over the analytic LatencyModel whenever a key hits.
    # tuning_store_path persists the store as JSON beside the kernel-cache
    # records; setting only the path reads an existing store without taking
    # new measurements.  All three salt the kernel-cache options fingerprint.
    autotune: bool = False
    measure_repeats: int = 5
    tuning_store_path: Optional[str] = None
    # Shard-aware compilation: the (axis name, size) shape of the mesh the
    # plan targets, e.g. (("data", 2), ("model", 4)).  Hashable on purpose —
    # it salts the options fingerprint and the measured-store keys, while
    # the live Mesh object (runtime-only) is passed to ``compile_module``
    # separately, like ``donate_params``.  None = single-device compile;
    # every pre-existing cache key stays byte-identical.
    mesh_axes: Optional[Tuple[Tuple[str, int], ...]] = None
    # Pass-boundary verification (core/verify.py): "off" = no checks at
    # all, "checkpoint" (default) = verify the finished artifact once after
    # FinalizePass, "strict" = verify after every pass so a violation names
    # the pass that introduced it.  The REPRO_VERIFY environment variable
    # overrides this at compile time (CI forces strict without touching
    # call sites).  Runtime/compile-policy only — like ``jit_replay``,
    # deliberately NOT part of the kernel-cache options fingerprint (it
    # changes what gets checked, never what is tuned or emitted).
    verify: str = "checkpoint"

    VALID_PLANNERS = ("cost", "greedy")
    VALID_VERIFY = ("off", "checkpoint", "strict")

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        """Reject option values the pipeline would otherwise misread (an
        unknown planner string used to silently behave as "greedy")."""
        if self.planner not in self.VALID_PLANNERS:
            raise ValueError(
                f"unknown planner {self.planner!r}; valid choices: "
                f"{', '.join(self.VALID_PLANNERS)}"
            )
        if self.verify not in self.VALID_VERIFY:
            raise ValueError(
                f"unknown verify level {self.verify!r}; valid choices: "
                f"{', '.join(self.VALID_VERIFY)}"
            )
        for name in ("vmem_limit", "replicate_limit", "max_blocks",
                     "ew_footprint_limit", "max_fusion_ops",
                     "stitch_max_blocks"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(f"{name} must be >= 0, got {v}")
        if self.stitch_replicate_limit is not None and self.stitch_replicate_limit < 0:
            raise ValueError(
                f"stitch_replicate_limit must be >= 0 (or None), got "
                f"{self.stitch_replicate_limit}"
            )
        if self.measure_repeats < 1:
            raise ValueError(
                f"measure_repeats must be >= 1, got {self.measure_repeats}"
            )
        if self.mesh_axes is not None:
            for entry in self.mesh_axes:
                name, size = entry
                if not isinstance(name, str) or int(size) < 1:
                    raise ValueError(
                        f"mesh_axes entries must be (name, size>=1) pairs, "
                        f"got {entry!r}"
                    )


@dataclass
class FusionReport:
    name: str
    num_ops: int
    blocks: int
    cost_s: float
    scratch_bytes: int
    shared_bytes: int
    num_shrinks: int
    roots: List[str]
    cached: bool = False                     # kernel reused via signature
    signature: str = ""
    num_phases: int = 1                      # >1 = multi-phase stitched kernel
    interface_bytes: int = 0                 # staged phase-boundary buffers
    # cost provenance (frontend ``Lowered.cost_estimate``): the analytic
    # LatencyModel seconds, and the on-device measurement when the tuning
    # store had (or autotune took) one — ``cost_s`` above is whichever of
    # the two the planner acted on.
    model_cost_s: Optional[float] = None
    measured_cost_s: Optional[float] = None


@dataclass
class CompileStats:
    stitched_kernels: int
    standalone_kernels: int
    library_calls: int
    xla_baseline_kernels: int
    predicted_time_s: float
    library_time_s: float = 0.0
    reports: List[FusionReport] = field(default_factory=list)
    # sub-module (loop body) accounting: ``call`` loop sites in the module,
    # unique bodies compiled (after module-signature dedup), call sites
    # served by an already-compiled body, and the total kernels inside all
    # unique bodies (recursive) — fusion_ratio counts them as ours.
    loop_calls: int = 0
    sub_compiles: int = 0
    sub_call_sites: int = 0
    sub_kernels: int = 0
    # kernel-dedup + pipeline accounting
    kernel_cache_hits: int = 0               # fusion instances served by cache
    kernel_cache_misses: int = 0             # unique fusions tuned this compile
    tuning_disk_hits: int = 0                # tuning searches skipped (warm disk)
    unique_kernels: int = 0                  # distinct kernels backing the fusions
    kernels_emitted: int = 0                 # Pallas kernels emitted THIS compile
    compile_time_s: float = 0.0
    pass_times: Dict[str, float] = field(default_factory=dict)
    # fusion-planner accounting (core/fusion.py PlannerStats)
    planner_mode: str = "greedy"
    plans_explored: int = 0                  # candidate partitions scored
    plans_rejected: int = 0                  # candidates with no feasible plan
    planner_splits: int = 0                  # seeds committed non-greedily
    planner_merges: int = 0                  # horizontal merges applied
    planner_packs: int = 0                   # sink groups committed as one kernel
    planner_stitches: int = 0                # groups committed as multi-phase
    # stitched-lowering accounting (the README "stitching counters")
    stitch_lowered_kernels: int = 0          # instances using the stitched emitter
    stitch_phases_total: int = 0             # sum of phases over stitched instances
    stitch_interface_bytes: int = 0          # staged interface bytes, all instances
    planner_predicted_s: float = 0.0         # modeled latency, committed plan
    # "greedy" here = the planner's same-regime floor (see PlannerStats);
    # on stitched graphs it differs from a paper-exact planner="greedy" run
    greedy_predicted_s: float = 0.0          # modeled latency, floor plan
    greedy_kernels: int = 0                  # launches the floor plan needs
    planner_kernels: int = 0                 # fusion-pass view, pre-demotion
    unfused_kernels: int = 0                 # launches with no fusion at all
    # runtime-replay accounting (ExecutionPlan): the eager loop dispatches
    # one XLA call per pre-bound step; the traced replay dispatches one per
    # jitted segment (segments break only where XLA could alter a library
    # dot's accumulation order — 1 segment for most graphs).
    replay_mode: str = "jit"                 # "jit" | "eager"
    eager_dispatches_per_call: int = 0       # steps the eager loop runs
    traced_dispatches_per_call: int = 1      # jitted replay segments
    donated_buffers: int = 0                 # dead segment inputs donated
    # measured-cost autotuning accounting (core/measure.py): store lookups
    # THIS compile (scorer candidates + schedule-pass entries), kernels
    # timed on device this compile, and the analytic model's mean relative
    # error over every entry with both costs known.  None = no entry had a
    # measurement (autotune off, or fully cold with measurement disabled).
    measured_hits: int = 0
    measured_misses: int = 0
    measurements_taken: int = 0
    model_error_pct: Optional[float] = None
    # Shard-aware compilation accounting (zero on single-device compiles):
    # collective steps in the plan (ICI traffic — counted apart from kernels
    # and library calls), their modeled wire time, how many of them sit
    # BETWEEN two stitched kernels (compute fused on both sides of the
    # break — the tentpole's acceptance metric), and how many instructions
    # carry a non-trivial shard layout.
    collective_calls: int = 0
    collective_time_s: float = 0.0
    collective_breaks_spanned: int = 0
    sharded_instrs: int = 0
    # Pass-boundary verifier accounting (core/verify.py): the resolved
    # level this compile ran under (REPRO_VERIFY may override the option),
    # boundaries checked, warning-severity diagnostics (errors raise), and
    # the total verification time — also surfaced as pass_times["verify"].
    verify_mode: str = "off"
    verify_boundaries: int = 0
    verify_warnings: int = 0
    verify_time_s: float = 0.0

    @property
    def replay_dispatch_reduction(self) -> int:
        """Per-call dispatches the traced replay saves over the eager loop."""
        return self.eager_dispatches_per_call - self.traced_dispatches_per_call

    @property
    def fusion_ratio(self) -> float:
        """paper Fig. 7: our kernel count / XLA baseline kernel count.
        Sub-module (loop body) kernels count as ours — the baseline count
        recurses into loop bodies the same way."""
        ours = self.stitched_kernels + self.standalone_kernels + self.sub_kernels
        return ours / self.xla_baseline_kernels if self.xla_baseline_kernels else 1.0

    @property
    def launches_saved_vs_unfused(self) -> int:
        """Kernel launches the committed plan saves over one-launch-per-op."""
        return self.unfused_kernels - (
            self.stitched_kernels + self.standalone_kernels
        )

    @property
    def launches_saved_vs_greedy(self) -> int:
        return self.greedy_kernels - (
            self.stitched_kernels + self.standalone_kernels
        )

    @property
    def cache_hit_rate(self) -> float:
        total = self.kernel_cache_hits + self.kernel_cache_misses
        return self.kernel_cache_hits / total if total else 0.0

    @property
    def smem_average(self) -> float:
        allocs = [r.scratch_bytes for r in self.reports]
        return float(np.mean(allocs)) if allocs else 0.0

    @property
    def smem_max(self) -> int:
        return max((r.scratch_bytes for r in self.reports), default=0)

    @property
    def total_shrinks(self) -> int:
        return sum(r.num_shrinks for r in self.reports)

    @property
    def shared_ratio(self) -> float:
        tot = sum(r.scratch_bytes for r in self.reports)
        sh = sum(r.shared_bytes for r in self.reports)
        return sh / tot if tot else 0.0


class CompiledModule:
    def __init__(self, executable: StitchedExecutable, stats: CompileStats):
        self.executable = executable
        self.stats = stats

    def __call__(self, feeds):
        return self.executable(feeds)


def build_outputs(state: CompilationState) -> None:
    """FinalizePass body: final FusionPlan, planned executable, stats."""
    lib = state.library

    kernels: Dict[str, StitchedKernel] = {}
    reports: List[FusionReport] = []
    predicted = 0.0
    final_fusions = []
    stitched_instances = 0
    stitch_phases_total = 0
    stitch_iface_bytes = 0
    for p in state.planned:
        kernels[p.fusion.name] = p.kernel
        final_fusions.append(p.fusion)
        predicted += p.entry.cost_s
        mem = p.entry.memory
        st = p.entry.stitched
        if st is not None:
            stitched_instances += 1
            stitch_phases_total += st.num_phases
            stitch_iface_bytes += st.interface_bytes
        reports.append(
            FusionReport(
                p.fusion.name,
                len(p.fusion.members),
                p.entry.blocks,
                p.entry.cost_s,
                mem.total_bytes,
                mem.shared_bytes,
                mem.num_shrinks,
                [r.name for r in p.fusion.roots],
                cached=p.cache_hit,
                signature=p.entry.signature,
                num_phases=st.num_phases if st is not None else 1,
                interface_bytes=st.interface_bytes if st is not None else 0,
                model_cost_s=p.entry.model_cost_s,
                measured_cost_s=p.entry.measured_cost_s,
            )
        )

    plan = FusionPlan(
        final_fusions,
        state.fusion_plan.standalone + state.demoted,
        state.module,
        planner=state.fusion_plan.planner,
    )
    library_time = 0.0
    collective_time = 0.0
    collective_calls = 0
    mesh_sizes = dict(getattr(state.options, "mesh_axes", None) or ())
    for s in plan.standalone:
        if s.opcode == "get":
            continue   # projection of a loop output — no launch, no cost
        if s.is_collective:
            # ICI traffic, not a kernel launch: charged by the ring model,
            # reported apart from both kernel and library time.
            g = 1
            for a in s.attrs.get("axes", ()):
                g *= mesh_sizes.get(a, 1)
            collective_time += lib.model.collective_op_time(s, g)
            collective_calls += 1
            continue
        if s.opcode == "call":
            # a loop costs its body's predicted time per iteration
            sub = s.attrs["compiled_body"].stats
            trip = int(s.attrs["trip_count"])
            predicted += trip * sub.predicted_time_s
            library_time += trip * sub.library_time_s
            continue
        # standalone kernels are costed as single-op launches; library-call
        # time (cuBLAS/MXU dots) is tracked separately — it is common to the
        # baseline and the stitched build (paper Fig. 6/8 methodology).
        t = lib.model.kernel_time(1, lib.model.op_time(s, _whole(s), 1))
        if s.is_library_call:
            library_time += t
        else:
            predicted += t

    # Collective breaks SPANNED by stitched compute: some fused kernel runs
    # upstream of the collective and another downstream — the plan stitched
    # compute into phases around the break (transitively: the value feeding
    # an all-reduce is typically a library dot, with the fused compute one
    # hop further).
    fused_ids = set()
    for f in final_fusions:
        fused_ids.update(m.id for m in f.members)

    def _reaches(start_ops, follow) -> bool:
        seen, stack = set(), list(start_ops)
        while stack:
            i = stack.pop()
            if i.id in seen:
                continue
            seen.add(i.id)
            if i.id in fused_ids:
                return True
            stack.extend(follow(i))
        return False

    breaks_spanned = sum(
        1
        for s in plan.standalone
        if s.is_collective
        and _reaches(s.operands, lambda i: i.operands)
        and _reaches(s.users, lambda i: i.users)
    )

    executable = StitchedExecutable(
        state.module, plan, kernels,
        jit_replay=state.options.jit_replay,
        donate_params=state.donate_params,
        mesh=state.mesh,
        param_layouts=state.param_layouts,
        out_layouts=state.out_layouts,
    )
    st = executable.launch_stats()
    hits = sum(1 for p in state.planned if p.cache_hit)
    from .fusion import constant_like

    unfused = sum(
        1
        for i in state.module.instructions
        if i.opcode not in ("parameter", "constant", "call", "get")
        and not constant_like(i)
        and not i.is_library_call
    )
    # a loop site's no-fusion-at-all launch count is its body's, recursively
    unfused += sum(
        i.attrs["compiled_body"].stats.unfused_kernels
        for i in state.module.instructions
        if i.opcode == "call"
    )
    sub_kernels = sum(
        cm.stats.stitched_kernels
        + cm.stats.standalone_kernels
        + cm.stats.sub_kernels
        for cm in state.sub_compiled.values()
    )
    pstats = state.fusion_plan.planner
    mstore = state.measured_store
    m_hits = mstore.hits - state.measured_base_hits if mstore else 0
    m_misses = mstore.misses - state.measured_base_misses if mstore else 0
    errors = [
        abs(e.model_cost_s - e.measured_cost_s) / e.measured_cost_s * 100.0
        for e in {id(p.entry): p.entry for p in state.planned}.values()
        if e.model_cost_s is not None
        and e.measured_cost_s is not None
        and e.measured_cost_s > 0.0
    ]
    state.executable = executable
    state.stats = CompileStats(
        stitched_kernels=st.stitched_kernels,
        standalone_kernels=st.standalone_kernels,
        library_calls=st.library_calls,
        loop_calls=st.loop_calls,
        sub_compiles=len(state.sub_compiled),
        sub_call_sites=state.sub_call_sites,
        sub_kernels=sub_kernels,
        xla_baseline_kernels=xla_baseline_kernel_count(state.module),
        predicted_time_s=predicted,
        library_time_s=library_time,
        reports=reports,
        kernel_cache_hits=hits,
        kernel_cache_misses=len(state.planned) - hits,
        tuning_disk_hits=sum(1 for p in state.planned if p.tuned_from_disk),
        unique_kernels=len({id(p.entry) for p in state.planned}),
        kernels_emitted=sum(1 for p in state.planned if p.is_representative),
        planner_mode=pstats.mode if pstats else "greedy",
        plans_explored=pstats.plans_explored if pstats else 0,
        plans_rejected=pstats.plans_rejected if pstats else 0,
        planner_splits=pstats.splits_taken if pstats else 0,
        planner_merges=pstats.merges_taken if pstats else 0,
        planner_packs=pstats.packs_taken if pstats else 0,
        planner_stitches=pstats.stitches_taken if pstats else 0,
        stitch_lowered_kernels=stitched_instances,
        stitch_phases_total=stitch_phases_total,
        stitch_interface_bytes=stitch_iface_bytes,
        planner_predicted_s=pstats.predicted_s if pstats else 0.0,
        greedy_predicted_s=pstats.greedy_predicted_s if pstats else 0.0,
        greedy_kernels=pstats.greedy_kernels if pstats else 0,
        planner_kernels=pstats.planned_kernels if pstats else 0,
        unfused_kernels=unfused,
        replay_mode=(
            "sharded"
            if state.mesh is not None
            else ("jit" if state.options.jit_replay else "eager")
        ),
        eager_dispatches_per_call=st.eager_dispatches_per_call,
        traced_dispatches_per_call=st.traced_dispatches_per_call,
        donated_buffers=st.donated_buffers,
        measured_hits=m_hits,
        measured_misses=m_misses,
        measurements_taken=state.measurements_taken,
        model_error_pct=float(np.mean(errors)) if errors else None,
        collective_calls=collective_calls,
        collective_time_s=collective_time,
        collective_breaks_spanned=breaks_spanned,
        sharded_instrs=state.shard_stats.get("sharded_instrs", 0),
    )


def compile_module(
    module,
    options: Optional[StitchOptions] = None,
    kernel_cache: Optional[KernelCache] = None,
    measured_store=None,
    donate_params=None,
    mesh=None,
    param_layouts=None,
    out_layouts=None,
) -> CompiledModule:
    """Compile a StitchIR module through the default pass pipeline.

    ``kernel_cache`` may be shared across calls so repeated compiles of
    structurally-identical graphs (per-layer blocks, per-request recompiles)
    reuse tuned schedules and emitted kernels.  ``measured_store`` (a
    ``core.measure.MeasuredCostStore``) may likewise be shared so autotune
    measurements taken by one compile guide the next; when None, one is
    created if ``options.autotune`` or ``options.tuning_store_path`` asks
    for it.  ``donate_params`` names parameters whose buffers the caller
    donates (the frontend's ``donate_argnums``) — runtime-only, never part
    of any cache fingerprint.

    ``mesh``/``param_layouts``/``out_layouts`` make this a sharded compile:
    the module must hold the PER-SHARD computation (a shard_map body, as
    ``frontend.jaxpr_lower.lower_sharded_jaxpr`` produces), ``mesh`` is the
    live Mesh the one ExecutionPlan replays on, and the layouts map
    parameter names / outputs to ``core.shard`` layout tuples.  The mesh's
    (name, size) shape must match ``options.mesh_axes`` — the hashable half
    that salts every cache key.
    """
    opts = options or StitchOptions()
    t0 = time.perf_counter()
    library = PerfLibrary(opts.perf_library_path)
    store = measured_store
    if store is None and (opts.autotune or opts.tuning_store_path):
        from .measure import MeasuredCostStore, device_fingerprint

        store = MeasuredCostStore(
            opts.tuning_store_path,
            device_fp=device_fingerprint(library.model.spec, opts.interpret),
        )
    state = CompilationState(
        module=module,
        options=opts,
        library=library,
        kernel_cache=(
            kernel_cache
            if kernel_cache is not None
            else KernelCache(opts.kernel_cache_path)
        ),
        measured_store=store,
        measured_base_hits=store.hits if store else 0,
        measured_base_misses=store.misses if store else 0,
        donate_params=frozenset(donate_params) if donate_params else None,
        mesh=mesh,
        param_layouts=param_layouts,
        out_layouts=out_layouts,
    )
    default_pipeline().run(state)
    state.stats.compile_time_s = time.perf_counter() - t0
    state.stats.pass_times = dict(state.pass_times)
    if opts.perf_library_path:
        state.library.save()
    if opts.kernel_cache_path:
        state.kernel_cache.save()
    if store is not None and opts.tuning_store_path:
        store.save()
    return CompiledModule(state.executable, state.stats)


def _whole(instr):
    from .schedule import REPLICATED

    return REPLICATED
