"""Shared-memory (VMEM scratch) planning — paper §5.1.

Three phases, faithfully ported from GPU shared memory to TPU VMEM scratch:

  1. **Size-requirement analysis** (§5.1.1): non-root Reduce / fusable-Dot
     results MUST be buffered (their consumers use separate loop emitters);
     expensive elementwise ops with multiple in-fusion users SHOULD be
     buffered (compute reuse — true even for cheap ops); expensive
     elementwise ops transitively feeding a BatchDot through shape ops MUST
     be buffered (high data reuse inside the dot).

  2. **Size shrinking** (§5.1.2): when demand exceeds the per-kernel budget,
     drop optional buffers (recompute instead — thread composition) in the
     paper's priority order: cheap multi-user ew -> expensive multi-user ew
     -> expensive ew feeding a dot; ties broken by closeness to the root
     (smallest span first).  If *required* buffers alone exceed the budget,
     ``MemoryInfeasible`` propagates back to the fusion pass
     (ScheduleConsistencyChecker feedback).

  3. **Space sharing** (§5.1.3): build a dominance tree from the root
     (Cooper-Harvey-Kennedy on the reverse dataflow graph) and let an op
     reuse a buffer whose owner it dominates — by then the owner's value is
     provably dead.  We additionally verify deadness with explicit liveness
     on the emission order (belt and braces) and require identical
     chunk-shape/dtype so the Pallas scratch ref can be reused as-is.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .ir import Instruction
from .schedule import ScheduleSolution, StitchedSolution, chunk_shape

ALLOC = "ALLOC"
SHARE = "SHARE"
INLINE = "INLINE"


class MemoryInfeasible(Exception):
    """Required buffers exceed the VMEM budget — feedback to fusion."""


@dataclass
class BufferEntry:
    action: str                 # ALLOC | SHARE | INLINE
    slot: int = -1              # scratch slot id (ALLOC/SHARE)
    nbytes: int = 0
    shape: Tuple[int, ...] = ()
    dtype: object = None
    required: bool = False


@dataclass
class MemoryPlan:
    entries: Dict[int, BufferEntry]         # instr id -> entry
    slots: List[Tuple[Tuple[int, ...], object]]   # slot id -> (shape, dtype)
    total_bytes: int
    shared_bytes: int
    shrunk: List[str] = field(default_factory=list)

    @property
    def num_shrinks(self) -> int:
        return len(self.shrunk)

    @property
    def shared_ratio(self) -> float:
        return self.shared_bytes / self.total_bytes if self.total_bytes else 0.0

    def action(self, instr: Instruction) -> str:
        e = self.entries.get(instr.id)
        return e.action if e else INLINE


# --------------------------------------------------------------------------
# Dominance tree (Cooper-Harvey-Kennedy) on the reverse dataflow graph
# --------------------------------------------------------------------------


def dominance_tree(
    members: List[Instruction], roots: List[Instruction]
) -> Dict[int, Optional[int]]:
    """idom map over member ids; a virtual root (None) covers multi-root.

    Edges run root -> operands (reverse dataflow).  ``members`` is in
    module-topological order, so reversed order is a valid RPO from roots.
    """
    member_ids = {m.id for m in members}
    root_ids = {r.id for r in roots}
    order = [m for m in reversed(members)]          # users before producers
    index = {m.id: i for i, m in enumerate(order)}
    idom: Dict[int, Optional[int]] = {}
    VROOT = -1
    for r in roots:
        idom[r.id] = VROOT

    def intersect(a: int, b: int) -> int:
        while a != b:
            if a == VROOT or b == VROOT:
                return VROOT
            while index[a] > index[b]:
                a = idom[a]
                if a == VROOT:
                    return VROOT
            if a == b:
                break
            while index[b] > index[a]:
                b = idom[b]
                if b == VROOT:
                    return VROOT
        return a

    changed = True
    while changed:
        changed = False
        for m in order:
            preds = [u.id for u in m.users if u.id in member_ids]
            if m.id in root_ids:
                continue
            defined = [p for p in preds if p in idom]
            if not defined:
                continue
            new = defined[0]
            for p in defined[1:]:
                new = intersect(new, p)
            if idom.get(m.id) != new:
                idom[m.id] = new
                changed = True
    return idom


def dominates(a: int, b: int, idom: Dict[int, Optional[int]]) -> bool:
    """True if instruction ``a`` dominates instruction ``b``."""
    cur = b
    while cur is not None and cur != -1:
        if cur == a:
            return True
        cur = idom.get(cur, -1)
    return False


# --------------------------------------------------------------------------
# Planning
# --------------------------------------------------------------------------


def _feeds_dot_through_shape_ops(instr: Instruction, member_ids: Set[int]) -> bool:
    """Transitive use by an in-fusion BatchDot via shape-modulation ops
    (the paper's Divide.1 -> Bitcast.1 -> Dot.1 case)."""
    stack = list(instr.users)
    seen = set()
    while stack:
        u = stack.pop()
        if u.id in seen or u.id not in member_ids:
            continue
        seen.add(u.id)
        if u.opcode == "dot":
            return True
        if u.opcode in ("reshape", "bitcast", "transpose", "broadcast"):
            stack.extend(u.users)
    return False


def plan_memory(
    members: List[Instruction],
    roots: List[Instruction],
    solution: ScheduleSolution,
    vmem_limit: int = 4 * 1024 * 1024,
) -> MemoryPlan:
    member_ids = {m.id for m in members}
    root_ids = {r.id for r in roots}

    # ---- phase 1: size requirements (candidates) -------------------------
    # category: 0=required, 1=cheap multi-user, 2=expensive multi-user,
    #           3=expensive feeding dot  (shrink order: 1 -> 2 -> 3, never 0)
    candidates: Dict[int, int] = {}
    for m in members:
        in_users = [u for u in m.users if u.id in member_ids]
        if m.id in root_ids and not in_users:
            continue  # pure output: written straight to the output ref
        if m.opcode in ("reduce", "dot"):
            candidates[m.id] = 0
        elif m.opcode == "elementwise":
            feeds_dot = _feeds_dot_through_shape_ops(m, member_ids)
            if m.is_expensive and feeds_dot:
                candidates[m.id] = 3
            elif m.is_expensive and len(in_users) > 1:
                candidates[m.id] = 2
            elif len(in_users) > 1:
                candidates[m.id] = 1

    sizes: Dict[int, Tuple[Tuple[int, ...], int]] = {}
    for m in members:
        if m.id in candidates:
            cs = chunk_shape(m.shape, solution.sched(m))
            nbytes = int(np.prod(cs, dtype=np.int64)) * np.dtype(m.dtype).itemsize
            sizes[m.id] = (tuple(cs), nbytes)

    # ---- phase 2: size shrinking -----------------------------------------
    span_rank = {m.id: i for i, m in enumerate(members)}  # later = closer root
    shrunk: List[str] = []

    def demand() -> int:
        return sum(sizes[i][1] for i in candidates)

    while demand() > vmem_limit:
        droppable = [i for i, cat in candidates.items() if cat > 0]
        if not droppable:
            raise MemoryInfeasible(
                f"required buffers need {demand()}B > {vmem_limit}B budget"
            )
        # paper order: category 1, then 2, then 3; within a category the
        # op closest to the root goes first.
        droppable.sort(key=lambda i: (candidates[i], -span_rank[i]))
        victim = droppable[0]
        name = next(m.name for m in members if m.id == victim)
        shrunk.append(name)
        del candidates[victim]

    # ---- phase 3: space sharing via dominance ----------------------------
    idom = dominance_tree(members, roots)
    # liveness on emission (topo) order: value of i is dead after its last
    # in-fusion user's position.
    last_use: Dict[int, int] = {}
    for pos, m in enumerate(members):
        for o in m.operands:
            if o.id in member_ids:
                last_use[o.id] = pos

    entries: Dict[int, BufferEntry] = {}
    slots: List[Tuple[Tuple[int, ...], object]] = []
    slot_owner: List[Optional[int]] = []     # current live owner per slot
    total = 0
    shared = 0
    for pos, m in enumerate(members):
        if m.id not in candidates:
            continue
        cs, nbytes = sizes[m.id]
        # find a reusable slot: same shape/dtype, previous owner's value
        # dead (liveness), and we dominate the previous owner (paper's rule)
        reuse = None
        for s, (sshape, sdtype) in enumerate(slots):
            prev = slot_owner[s]
            if sshape != cs or np.dtype(sdtype) != np.dtype(m.dtype):
                continue
            if prev is None:
                continue
            if last_use.get(prev, -1) < pos and dominates(m.id, prev, idom):
                reuse = s
                break
        if reuse is not None:
            entries[m.id] = BufferEntry(
                SHARE, reuse, nbytes, cs, m.dtype, candidates[m.id] == 0
            )
            slot_owner[reuse] = m.id
            shared += nbytes
        else:
            slots.append((cs, m.dtype))
            slot_owner.append(m.id)
            entries[m.id] = BufferEntry(
                ALLOC, len(slots) - 1, nbytes, cs, m.dtype, candidates[m.id] == 0
            )
            total += nbytes
    for m in members:
        if m.id not in entries:
            entries[m.id] = BufferEntry(INLINE)

    return MemoryPlan(entries, slots, total, shared, shrunk)


# --------------------------------------------------------------------------
# Stitched (multi-phase) planning: full interface buffers + per-phase scratch
# --------------------------------------------------------------------------


@dataclass
class InterfaceBuffer:
    """One staged phase-boundary tensor, materialized WHOLE in VMEM."""

    slot: int
    shape: Tuple[int, ...]
    dtype: object
    nbytes: int
    produced_phase: int
    last_consumer_phase: int


@dataclass
class StitchedMemoryPlan:
    """VMEM plan for a multi-phase stitched kernel.

    Interface tensors are allocated at FULL (untiled) size — the producer
    phase writes each block's chunk into the staging buffer and the consumer
    phase re-tiles it under its own schedule.  Each phase additionally gets
    its own chunk-granular ``MemoryPlan`` for phase-interior buffering.

    Feasibility matches what ``emit_stitched_fusion`` actually allocates:
    every interface buffer AND every phase's scratch slots are passed to one
    ``pallas_call`` and coexist for the whole kernel, so the budget is
    consumed sequentially — each phase plans (and shrinks) against whatever
    the interfaces and earlier phases left over.  ``MemoryInfeasible``
    propagates back to the fusion pass so infeasible stitches fall back to
    a split.
    """

    interfaces: Dict[int, InterfaceBuffer]     # instr id -> staged buffer
    phase_plans: List[MemoryPlan]
    interface_bytes: int
    io_bytes: int = 0        # whole-tensor input/output blocks (trivial grid)

    @property
    def num_phases(self) -> int:
        return len(self.phase_plans)

    # ---- MemoryPlan-compatible reporting surface -------------------------
    @property
    def total_bytes(self) -> int:
        """Whole-kernel VMEM residency: interfaces + every phase's slots
        (they all coexist in the one pallas_call's scratch set)."""
        return self.interface_bytes + sum(p.total_bytes for p in self.phase_plans)

    @property
    def shared_bytes(self) -> int:
        return sum(p.shared_bytes for p in self.phase_plans)

    @property
    def num_shrinks(self) -> int:
        return sum(p.num_shrinks for p in self.phase_plans)

    @property
    def shared_ratio(self) -> float:
        return self.shared_bytes / self.total_bytes if self.total_bytes else 0.0


def plan_stitched_memory(
    stitched: StitchedSolution,
    vmem_limit: int = 4 * 1024 * 1024,
) -> StitchedMemoryPlan:
    """Plan VMEM for a stitched kernel: one full-size staging buffer per
    interface tensor plus one chunk-granular plan per phase, checked against
    ``vmem_limit`` as ONE allocation together with the whole-tensor kernel
    input/output blocks — exactly the VMEM working set the stitched emitter
    hands to ``pallas_call`` (trivial grid, full BlockSpecs)."""
    phase_of: Dict[int, int] = {}
    for k, p in enumerate(stitched.phases):
        for m in p.members:
            phase_of[m.id] = k

    interfaces: Dict[int, InterfaceBuffer] = {}
    for slot, i in enumerate(stitched.interfaces):
        last = max(
            (phase_of[u.id] for u in i.users if u.id in phase_of),
            default=phase_of[i.id],
        )
        interfaces[i.id] = InterfaceBuffer(
            slot=slot,
            shape=tuple(i.shape),
            dtype=i.dtype,
            nbytes=int(i.bytesize),
            produced_phase=phase_of[i.id],
            last_consumer_phase=last,
        )

    # the stitched emitter's trivial grid gives every kernel input and every
    # kernel output a WHOLE-tensor BlockSpec, so those blocks are VMEM-
    # resident for the entire kernel too (unlike the chunk-sized blocks of a
    # schedule-consistent kernel) — they must come out of the same budget
    group_ids = set(phase_of)
    io_bytes = 0
    seen_io = set()
    for p in stitched.phases:
        for m in p.members:
            for o in m.operands:
                if o.id not in group_ids and o.id not in seen_io:
                    seen_io.add(o.id)
                    io_bytes += int(o.bytesize)
            if m.id not in seen_io and (
                not m.users or any(u.id not in group_ids for u in m.users)
            ):
                seen_io.add(m.id)
                io_bytes += int(m.bytesize)

    iface_bytes = sum(b.nbytes for b in interfaces.values())
    if iface_bytes + io_bytes > vmem_limit:
        raise MemoryInfeasible(
            f"staged interfaces ({iface_bytes}B) + whole-tensor kernel I/O "
            f"({io_bytes}B) > {vmem_limit}B budget"
        )
    phase_plans: List[MemoryPlan] = []
    remaining = vmem_limit - iface_bytes - io_bytes
    for p in stitched.phases:
        # every phase's slots coexist with the interfaces and with every
        # other phase's slots for the whole kernel, so each phase plans
        # (and shrinks) against what earlier phases left over; a phase
        # whose REQUIRED buffers exceed that raises MemoryInfeasible
        plan = plan_memory(p.members, p.roots, p.solution, remaining)
        phase_plans.append(plan)
        remaining -= plan.total_bytes

    return StitchedMemoryPlan(interfaces, phase_plans, iface_bytes, io_bytes)
