"""Canonical fusion signatures + the kernel cache.

Stacked transformer graphs contain N structurally-identical fusions (one per
layer): same opcodes, shapes, dtypes, attrs and internal wiring, differing
only in *which* parameters/intermediates bind to the fusion inputs.  The
follow-up FusionStitching work (arXiv:2009.10924) and the XLA fusion study
(arXiv:2301.13062) both identify duplicate-fusion deduplication as the main
compile-latency lever at production scale.

``fusion_signature`` canonicalizes a ``FusedComputation`` *parameterized over
its input bindings*: members are numbered in topological order, inputs in
first-use order, and every operand reference becomes ("m", k) or ("in", k).
Two fusions get equal signatures iff they would tune to the same schedule,
get the same memory plan, and emit byte-identical kernels — so the tuned
solution and the emitted Pallas callable can be shared.

``KernelCache`` maps signatures to compiled entries.  It is in-memory per
compile (and shareable across compiles), with optional on-disk persistence
of the *tuned schedule choice* — the same JSON KV protocol as PerfLibrary —
so a warm process skips schedule tuning entirely and only re-emits kernels.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .fusion import FusedComputation
from .memory import MemoryPlan
from .perf_library import JsonStore
from .schedule import Sched, ScheduleSolution


def _canon_value(v):
    """Canonical, hashable form of one attr value (ndarrays by content)."""
    if isinstance(v, np.ndarray):
        return (
            "ndarray",
            tuple(v.shape),
            str(v.dtype),
            hashlib.sha256(np.ascontiguousarray(v).tobytes()).hexdigest(),
        )
    if isinstance(v, (tuple, list)):
        return tuple(_canon_value(x) for x in v)
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return repr(v)


def _canon_attrs(attrs: Dict) -> Tuple:
    return tuple(sorted((k, _canon_value(v)) for k, v in attrs.items()))


def fusion_signature(fusion: FusedComputation) -> str:
    """Content hash of a fusion's structure, independent of input bindings.

    Covers: per-input (shape, dtype); per-member (opcode, shape, dtype,
    canonical attrs, operand references as member/input ordinals, root-ness);
    and the planner's committed phase structure (``stitch_phases``) — a
    multi-phase stitched lowering and a single-schedule lowering of the same
    member graph must never alias in the kernel cache.
    Instruction ids and names never enter the hash.
    """
    inputs = fusion.inputs
    members = fusion.members
    in_pos = {i.id: k for k, i in enumerate(inputs)}
    mem_pos = {m.id: k for k, m in enumerate(members)}
    root_ids = {r.id for r in fusion.roots}

    # Input features carry the shard layout when one is stamped: per-shard
    # member shapes are already local, but a fusion fed by a model-sharded
    # parameter and one fed by a replicated parameter of the same local shape
    # must never alias in the cache.  The entry is appended only when
    # non-trivial so unsharded signatures stay byte-identical across versions.
    feats: List = [
        ("phases", tuple(fusion.stitch_phases) if fusion.stitch_phases else None),
        tuple(
            (tuple(i.shape), str(np.dtype(i.dtype)))
            + ((("shard", _canon_value(i.attrs["shard"])),) if i.attrs.get("shard") else ())
            for i in inputs
        ),
    ]
    for m in members:
        refs = tuple(
            ("m", mem_pos[o.id]) if o.id in mem_pos else ("in", in_pos[o.id])
            for o in m.operands
        )
        feats.append(
            (
                m.opcode,
                tuple(m.shape),
                str(np.dtype(m.dtype)),
                _canon_attrs(m.attrs),
                refs,
                m.id in root_ids,
            )
        )
    return hashlib.sha256(repr(feats).encode()).hexdigest()


def module_signature(module) -> str:
    """Content hash of a whole module's structure — opcode/shape/dtype/attrs
    and operand wiring in instruction order, plus parameter arity and root
    positions.  Instruction ids and *names* never enter the hash, so two
    loop bodies lowered from structurally identical jaxprs (stacked scan
    layers) hash equal and share one compiled sub-module
    (``pipeline.SubModulePass``).  Nested ``call`` bodies hash recursively;
    their ``body``/``compiled_body`` attrs (unstable object reprs) are
    replaced by the recursive signature."""
    pos: Dict[int, int] = {}
    feats: List = []
    n_params = 0
    for k, instr in enumerate(module.instructions):
        pos[instr.id] = k
        attrs = instr.attrs
        if instr.opcode == "call":
            attrs = {
                key: v for key, v in attrs.items()
                if key not in ("body", "compiled_body", "body_sig")
            }
            attrs["body_sig"] = module_signature(instr.attrs["body"])
        if instr.opcode == "parameter":
            n_params += 1
        feats.append(
            (
                instr.opcode,
                tuple(instr.shape),
                str(np.dtype(instr.dtype)),
                _canon_attrs(attrs),
                tuple(pos[o.id] for o in instr.operands),
            )
        )
    feats.append(("params", n_params))
    feats.append(("roots", tuple(pos[r.id] for r in module.roots)))
    return hashlib.sha256(repr(feats).encode()).hexdigest()


@dataclass
class CacheEntry:
    """One unique fusion structure: its tuned schedule, memory plan, and the
    emitted kernel (ids inside solution/memory refer to the representative
    instance the entry was built from; the kernel callable is positional and
    binds to any instance with the same signature).

    Multi-phase stitched fusions carry a ``stitched`` solution (and a
    ``StitchedMemoryPlan`` in ``memory``) instead of a single ``solution``;
    their tuning records are never persisted to disk — the root-schedule
    hint protocol only describes single-schedule kernels."""

    signature: str
    solution: Optional[ScheduleSolution]
    memory: Optional[MemoryPlan]
    cost_s: float
    kernel: Optional[object] = None      # StitchedKernel of the representative
    root_scheds: List[Sched] = field(default_factory=list)  # in root order
    kept_members: Optional[int] = None   # after memory-feedback shrink
    stitched: Optional[object] = None    # schedule.StitchedSolution
    # Autotuning bookkeeping: cost_s above is whatever the planner will act
    # on (measured when the store hit, analytic otherwise); these two keep
    # the provenance apart so CompileStats can report model error.
    model_cost_s: Optional[float] = None     # analytic LatencyModel seconds
    measured_cost_s: Optional[float] = None  # on-device seconds, if known

    @property
    def blocks(self) -> int:
        if self.stitched is not None:
            return self.stitched.blocks
        return self.solution.blocks


# Version of the on-disk tuning-record schema.  Bump whenever the persisted
# payload changes shape (fields, Sched encoding, cost semantics): records
# written under any other version are silently discarded on read instead of
# crashing a warm process on an unpacking error.
SCHEMA_VERSION = 2


def _sched_to_json(s: Sched) -> List:
    return [s.kind, s.split_dim, s.sword, s.sched_type]


def _sched_from_json(row) -> Sched:
    kind, split_dim, sword, sched_type = row
    return Sched(kind, int(split_dim), int(sword), sched_type)


class KernelCache:
    """Signature -> CacheEntry map with optional persistent tuning hints.

    The persistent layer stores only the tuned schedule decision (root
    schedules + predicted cost), not the kernel: Pallas callables are cheap
    to re-emit once tuning — the expensive search — is skipped.  Records
    carry a ``version`` field; stale or corrupt rows are dropped on read
    (``stale_discards`` counts them) rather than raised.
    """

    def __init__(self, path: Optional[str] = None):
        self._entries: Dict[str, CacheEntry] = {}
        self._disk = JsonStore(path)
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.stale_discards = 0

    # ---- in-memory entries ----------------------------------------------
    def get(self, signature: str) -> Optional[CacheEntry]:
        e = self._entries.get(signature)
        if e is not None:
            self.hits += 1
        else:
            self.misses += 1
        return e

    def put(self, entry: CacheEntry, persist: bool = True) -> None:
        self._entries[entry.signature] = entry
        if entry.stitched is not None:
            persist = False      # hint protocol is single-schedule only
        if persist and self._disk.path is not None:
            self._disk.put(
                entry.signature,
                {
                    "version": SCHEMA_VERSION,
                    "roots": [_sched_to_json(s) for s in entry.root_scheds],
                    "blocks": entry.solution.blocks,
                    "cost_s": entry.cost_s,
                },
            )

    def remove(self, signature: str) -> None:
        """Drop a dead entry everywhere (in-memory and persistent)."""
        self._entries.pop(signature, None)
        self._disk.pop(signature)

    def discard_disk(self, signature: str) -> None:
        """Invalidate only the persistent tuning record (e.g. after the
        memory-feedback loop shrank the fusion: the recorded schedules no
        longer describe the structure the signature hashes)."""
        self._disk.pop(signature)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, signature: str) -> bool:
        return signature in self._entries

    # ---- persistent tuning hints ----------------------------------------
    def tuning_hint(self, signature: str) -> Optional[List[Sched]]:
        """Root schedules recorded by a previous process, or None.

        A record from another schema version — or one that does not parse —
        is evicted and reported as a miss, so format changes degrade to a
        cold retune instead of a crash.
        """
        rec = self._disk.get(signature)
        if rec is None:
            return None
        try:
            if rec.get("version") != SCHEMA_VERSION:
                raise ValueError(f"schema version {rec.get('version')!r}")
            scheds = [_sched_from_json(r) for r in rec["roots"]]
        except (ValueError, TypeError, KeyError, AttributeError, IndexError):
            self._disk.pop(signature)
            self.stale_discards += 1
            return None
        self.disk_hits += 1
        return scheds

    def save(self) -> None:
        self._disk.save()
