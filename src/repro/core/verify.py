"""Pass-boundary StitchIR verifier and ExecutionPlan linter.

Nine PRs of compiler invariants — fusion groups must not cross an LC layer
(paper §3.2), stitched phases must each be schedule-consistent, collectives
must never sit inside a kernel body, shard layouts must close their partial
sums before a root, donated buffer slots must be dead — and until now the
only machine-checked one was ``Module.verify()``'s shape-only
def-before-use pass.  This module is the static-analysis backstop: after a
pass runs, ``verify_state`` re-derives every invariant the pipeline is
supposed to maintain and reports violations as structured ``Diagnostic``
records naming the rule, the offending instruction/slot, and the pass
boundary that introduced the breakage — so a broken plan fails loudly at
its source instead of as a wrong number three subsystems later.

Three analysis families:

* **IR well-formedness** (``IR0xx``, ``verify_module``): def-before-use,
  topological storage order, operand/user back-edge symmetry, unique ids,
  shape AND dtype re-inference, and the attr-declared shapes of the
  ``call``/``get``/``constant`` opcodes that ``infer_shape`` skips.
  ``Module.verify()`` delegates here.
* **Plan lint** (``PLAN0xx``): fusion groups are acyclic single-DAGs that
  never span an LC layer (``core/span.py`` roofs), never contain a
  collective / library call / non-scalar constant, every instruction is
  covered exactly once, each planned entry's schedule solution is sound
  (the ``resolve_schedules`` readability contract, per phase for stitched
  plans) and its memory plan fits the VMEM budget, and — on sharded
  compiles — the stamped shard/partial attrs agree with a fresh
  ``derive_layouts`` run with no partial sum reaching a root unclosed.
* **ExecutionPlan lint** (``EXEC0xx``, ``verify_execution_plan``): a
  dataflow walk over the flat slot table proving every slot is written
  before read and never read after its eager-release point, that releases
  are sane (no root released, no double release), that jit-segment
  ``donate_argnums`` only name slots dead after the segment (parameter and
  template slots never donated), plus a KernelCache signature-collision
  audit re-hashing committed entries against their lowered bodies.

``PassPipeline.run`` invokes ``verify_state`` according to
``StitchOptions.verify``: ``"off"`` (no work at all), ``"checkpoint"``
(after FinalizePass only — the default), ``"strict"`` (after every pass).
The ``REPRO_VERIFY`` environment variable overrides the option so CI can
force strict without touching call sites.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import span as span_lib
from .fusion import constant_like
from .ir import (
    COLLECTIVE_OPCODES,
    Instruction,
    Module,
    infer_dtype,
    infer_shape,
)
from .schedule import Unsatisfiable, blocks_of, propagate

ERROR = "error"
WARNING = "warning"

VERIFY_MODES = ("off", "checkpoint", "strict")
VERIFY_ENV_VAR = "REPRO_VERIFY"

#: rule id -> one-line description (the README table renders from this)
RULES: Dict[str, str] = {
    "IR001": "operand is not an instruction of this module (dangling def)",
    "IR002": "operand stored after its user (topological order broken)",
    "IR003": "operand/user back-edges are asymmetric",
    "IR004": "duplicate instruction id in one module",
    "IR005": "recorded shape disagrees with shape re-inference",
    "IR006": "recorded dtype disagrees with dtype re-inference",
    "IR007": "attr-declared shape/dtype contract broken (call/get/constant)",
    "IR008": "duplicate parameter name",
    "PLAN001": "fusion group is cyclic through outside instructions",
    "PLAN002": "fusion component spans an LC layer roof",
    "PLAN003": "forbidden member in a kernel body (collective/library/loop)",
    "PLAN004": "non-scalar constant inside a kernel body",
    "PLAN005": "schedule solution unsound for its fusion",
    "PLAN006": "memory plan exceeds the VMEM budget",
    "PLAN007": "stamped shard layout disagrees with re-derivation",
    "PLAN008": "partial sum reaches a module root unclosed",
    "PLAN009": "instruction not covered exactly once by the plan",
    "EXEC001": "slot read before written / written twice",
    "EXEC002": "slot read after its eager-release point",
    "EXEC003": "bad release (root slot, double release, never written)",
    "EXEC004": "donated slot is protected or still live",
    "EXEC005": "cache entry signature does not match its lowered body",
}


@dataclass(frozen=True)
class Diagnostic:
    """One structured verifier finding.

    ``subject`` names the offending instruction / fusion / slot;
    ``pass_name`` is the pass boundary the violation was detected at (empty
    when the verifier ran standalone, e.g. via ``Module.verify``).
    """

    severity: str                 # ERROR | WARNING
    rule: str                     # key into RULES
    message: str
    subject: str = ""
    pass_name: str = ""

    def __str__(self) -> str:
        where = f" [{self.subject}]" if self.subject else ""
        origin = f" (after pass {self.pass_name!r})" if self.pass_name else ""
        return f"{self.severity} {self.rule}{where}: {self.message}{origin}"


class VerificationError(ValueError):
    """Raised when verification finds error-severity diagnostics.

    Subclasses ``ValueError`` so every pre-existing caller of
    ``Module.verify()`` (which raised bare ValueErrors) keeps working.
    """

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics = list(diagnostics)
        shown = "\n".join(f"  {d}" for d in self.diagnostics[:12])
        more = len(self.diagnostics) - 12
        if more > 0:
            shown += f"\n  ... and {more} more"
        super().__init__(
            f"{len(self.diagnostics)} verifier diagnostic(s):\n{shown}"
        )


def resolve_verify_mode(options) -> str:
    """The effective verify level: ``REPRO_VERIFY`` env override first,
    then ``options.verify``.  The env var exists so CI can force strict
    across an entire test lane without touching any call site."""
    env = os.environ.get(VERIFY_ENV_VAR)
    if env:
        if env not in VERIFY_MODES:
            raise ValueError(
                f"{VERIFY_ENV_VAR}={env!r}: valid values are "
                f"{', '.join(VERIFY_MODES)}"
            )
        return env
    mode = getattr(options, "verify", "checkpoint")
    if mode not in VERIFY_MODES:
        raise ValueError(
            f"options.verify={mode!r}: valid values are "
            f"{', '.join(VERIFY_MODES)}"
        )
    return mode


# --------------------------------------------------------------------------
# Family 1: IR well-formedness
# --------------------------------------------------------------------------


def verify_module(
    module: Module, pass_name: str = "", _prefix: str = ""
) -> List[Diagnostic]:
    """IR well-formedness diagnostics for one module (and, recursively, the
    body modules of its ``call`` loops)."""
    diags: List[Diagnostic] = []

    def err(rule: str, subject: str, message: str) -> None:
        diags.append(
            Diagnostic(ERROR, rule, message, _prefix + subject, pass_name)
        )

    index: Dict[int, int] = {}
    for pos, instr in enumerate(module.instructions):
        if instr.id in index:
            err(
                "IR004",
                instr.name,
                f"id {instr.id} already used by "
                f"{module.instructions[index[instr.id]].name}",
            )
        else:
            index[instr.id] = pos

    param_names: Set[str] = set()
    for pos, instr in enumerate(module.instructions):
        if instr.opcode == "parameter":
            if instr.name in param_names:
                err("IR008", instr.name, "duplicate parameter name")
            param_names.add(instr.name)

        # -- def-before-use + topological storage order --------------------
        for op in instr.operands:
            at = index.get(op.id)
            if at is None:
                err(
                    "IR001",
                    instr.name,
                    f"operand {op.name} is not an instruction of module "
                    f"{module.name!r}",
                )
            elif at >= pos:
                err(
                    "IR002",
                    instr.name,
                    f"operand {op.name} stored at position {at}, after its "
                    f"user at {pos}",
                )

        # -- operand/user back-edge symmetry --------------------------------
        for op in set(instr.operands):
            uses = sum(1 for o in instr.operands if o.id == op.id)
            backs = sum(1 for u in op.users if u.id == instr.id)
            if uses != backs:
                err(
                    "IR003",
                    instr.name,
                    f"lists operand {op.name} {uses}x but appears in its "
                    f"users {backs}x",
                )
        for u in instr.users:
            if u.id not in index:
                err(
                    "IR003",
                    instr.name,
                    f"user {u.name} is not an instruction of module "
                    f"{module.name!r} (stale back-edge)",
                )

        diags.extend(
            Diagnostic(ERROR, rule, msg, _prefix + instr.name, pass_name)
            for rule, msg in _check_instr_types(instr)
        )

    # recurse into loop bodies: their invariants hold or break independently
    for instr in module.instructions:
        if instr.opcode == "call":
            body = instr.attrs.get("body")
            if isinstance(body, Module):
                diags.extend(
                    verify_module(
                        body, pass_name, _prefix=f"{_prefix}{instr.name}/"
                    )
                )
    return diags


def _check_instr_types(instr: Instruction) -> List[Tuple[str, str]]:
    """Shape/dtype re-inference plus the attr-declared contracts of the
    opcodes ``infer_shape`` skips.  Returns (rule, message) pairs."""
    out: List[Tuple[str, str]] = []
    try:
        shape = infer_shape(
            instr.opcode, [o.shape for o in instr.operands], instr.attrs
        )
    except (ValueError, AssertionError, KeyError, IndexError) as e:
        out.append(("IR005", f"shape inference failed: {e}"))
        shape = None
    if shape is not None and tuple(shape) != tuple(instr.shape):
        out.append(
            ("IR005", f"recorded shape {instr.shape} != inferred {tuple(shape)}")
        )
    try:
        dtype = infer_dtype(
            instr.opcode, [o.dtype for o in instr.operands], instr.attrs
        )
    except (ValueError, KeyError, IndexError) as e:
        out.append(("IR006", f"dtype inference failed: {e}"))
        dtype = None
    if dtype is not None and np.dtype(dtype) != np.dtype(instr.dtype):
        out.append(
            (
                "IR006",
                f"recorded dtype {np.dtype(instr.dtype).name} != inferred "
                f"{np.dtype(dtype).name}",
            )
        )

    a = instr.attrs
    if instr.opcode == "constant":
        value = a.get("value")
        if value is None:
            out.append(("IR007", "constant without a value attr"))
        elif tuple(np.shape(value)) != tuple(instr.shape):
            out.append(
                (
                    "IR007",
                    f"value shape {np.shape(value)} != recorded {instr.shape}",
                )
            )
    elif instr.opcode == "call":
        out.extend(_check_call(instr))
    elif instr.opcode == "get":
        src = instr.operands[0] if instr.operands else None
        if src is None or src.opcode != "call":
            out.append(("IR007", "get must project a call instruction"))
        else:
            idx = int(a.get("index", -1))
            shapes = src.attrs.get("out_shapes", ())
            dtypes = src.attrs.get("out_dtypes", ())
            if not 0 <= idx < len(shapes):
                out.append(
                    ("IR007", f"index {idx} out of range for {len(shapes)} outputs")
                )
            else:
                if tuple(shapes[idx]) != tuple(instr.shape):
                    out.append(
                        (
                            "IR007",
                            f"recorded shape {instr.shape} != declared "
                            f"out_shapes[{idx}] {tuple(shapes[idx])}",
                        )
                    )
                if np.dtype(dtypes[idx]) != np.dtype(instr.dtype):
                    out.append(
                        (
                            "IR007",
                            f"recorded dtype {np.dtype(instr.dtype).name} != "
                            f"declared out_dtypes[{idx}]",
                        )
                    )
    return out


def _check_call(instr: Instruction) -> List[Tuple[str, str]]:
    """The ``call`` loop contract: declared outputs index real body roots,
    carries close their shape loop, xs stack over the trip count."""
    out: List[Tuple[str, str]] = []
    a = instr.attrs
    body = a.get("body")
    if not isinstance(body, Module):
        return [("IR007", "call without a body module")]
    try:
        nc, k = int(a["num_consts"]), int(a["num_carry"])
        trip = int(a["trip_count"])
        order = tuple(a["out_order"])
        shapes = tuple(a["out_shapes"])
        dtypes = tuple(a["out_dtypes"])
    except (KeyError, TypeError, ValueError) as e:
        return [("IR007", f"call attrs incomplete: {e}")]

    if not (len(order) == len(shapes) == len(dtypes)):
        out.append(
            (
                "IR007",
                f"out_order/out_shapes/out_dtypes lengths disagree: "
                f"{len(order)}/{len(shapes)}/{len(dtypes)}",
            )
        )
        return out
    roots = body.roots
    for j in order:
        if not 0 <= j < len(roots):
            out.append(
                ("IR007", f"out_order entry {j} out of range for {len(roots)} body roots")
            )
            return out
    if k > len(order):
        out.append(("IR007", f"num_carry {k} > {len(order)} declared outputs"))
        return out
    if nc + k > len(instr.operands):
        out.append(
            (
                "IR007",
                f"num_consts+num_carry {nc + k} > {len(instr.operands)} operands",
            )
        )
        return out
    if tuple(instr.shape) != tuple(shapes[0]) or np.dtype(
        instr.dtype
    ) != np.dtype(dtypes[0]):
        out.append(
            ("IR007", "call instr shape/dtype must alias out_shapes[0]/out_dtypes[0]")
        )
    # carries: the init operand, the body root, and the declared output must
    # agree — the loop feeds output j back as carry j every iteration
    for i in range(k):
        init = instr.operands[nc + i]
        if tuple(init.shape) != tuple(shapes[i]):
            out.append(
                (
                    "IR007",
                    f"carry {i}: init {init.name} shape {init.shape} != "
                    f"declared {tuple(shapes[i])}",
                )
            )
        if tuple(roots[order[i]].shape) != tuple(shapes[i]):
            out.append(
                (
                    "IR007",
                    f"carry {i}: body root shape {roots[order[i]].shape} != "
                    f"declared {tuple(shapes[i])}",
                )
            )
    # ys: stacked per-iteration body roots — (trip,) + root shape
    for j in range(k, len(order)):
        want = (trip,) + tuple(roots[order[j]].shape)
        if tuple(shapes[j]) != want:
            out.append(
                (
                    "IR007",
                    f"ys output {j}: declared {tuple(shapes[j])} != "
                    f"(trip,)+root shape {want}",
                )
            )
    # xs: sliced along the leading dim, one slice per iteration
    for j, xs in enumerate(instr.operands[nc + k:]):
        if not xs.shape or int(xs.shape[0]) != trip:
            out.append(
                (
                    "IR007",
                    f"xs operand {xs.name} leading dim "
                    f"{xs.shape[:1] or '()'} != trip_count {trip}",
                )
            )
    return out


# --------------------------------------------------------------------------
# Family 2: plan lint
# --------------------------------------------------------------------------


def verify_fusion_groups(
    fusions, standalone, module: Module, pass_name: str = ""
) -> List[Diagnostic]:
    """Structural lint of a fusion partition: acyclic groups, LC-layer
    roofs, member legality, exactly-once coverage."""
    from .fusion import _group_cycle

    diags: List[Diagnostic] = []
    span = span_lib.compute_spans(module)
    lcs = span_lib.lc_spans(module, span)
    max_span = max(span.values()) if span else 0

    for f in fusions:
        members = list(f.members)
        if _group_cycle(set(members)):
            diags.append(
                Diagnostic(
                    ERROR, "PLAN001",
                    "member union reaches itself through outside instructions",
                    f.name, pass_name,
                )
            )
        for m in members:
            if m.is_collective:
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN003",
                        f"collective {m.name} inside a kernel body",
                        f.name, pass_name,
                    )
                )
            elif m.is_library_call or m.opcode in ("call", "get", "parameter"):
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN003",
                        f"{m.opcode} {m.name} inside a kernel body",
                        f.name, pass_name,
                    )
                )
            elif m.opcode == "constant" and m.num_elements != 1:
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN004",
                        f"array constant {m.name} ({m.num_elements} elements) "
                        "inside a kernel body — Pallas only inlines scalars",
                        f.name, pass_name,
                    )
                )
        # LC roofs apply per weakly-connected component of member-to-member
        # operand edges: a horizontal merge may legally pack INDEPENDENT
        # towers from opposite sides of an LC layer into one kernel, but no
        # single dependent chain may cross a roof.  Constant-like members
        # are exempt — absorption is unbounded by design (paper §3.2).
        for comp in _member_components(members):
            spans_c = [span[m.id] for m in comp if m.id in span]
            if not spans_c:
                continue
            roof = span_lib.roof_for(min(spans_c), lcs, max_span)
            if max(spans_c) > roof:
                names = ", ".join(m.name for m in comp[:4])
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN002",
                        f"component [{names}...] spans layers "
                        f"{min(spans_c)}..{max(spans_c)} past LC roof {roof}",
                        f.name, pass_name,
                    )
                )

    # exactly-once coverage of the non-trivial instruction universe
    counts: Dict[int, int] = {}
    by_id: Dict[int, Instruction] = {}
    for f in fusions:
        for m in f.members:
            counts[m.id] = counts.get(m.id, 0) + 1
            by_id[m.id] = m
    for s in standalone:
        counts[s.id] = counts.get(s.id, 0) + 1
        by_id[s.id] = s
    for instr in module.instructions:
        if instr.opcode in ("parameter", "constant") or constant_like(instr):
            continue
        n = counts.get(instr.id, 0)
        if n != 1:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN009",
                    f"covered {n}x by the plan (want exactly once)",
                    instr.name, pass_name,
                )
            )
    return diags


def _member_components(members) -> List[List[Instruction]]:
    """Weakly-connected components of the member set under member-to-member
    operand edges, with constant-like members dropped (they bridge towers
    without schedule or layer constraints)."""
    core = [m for m in members if not constant_like(m)]
    ids = {m.id for m in core}
    parent: Dict[int, int] = {m.id: m.id for m in core}

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for m in core:
        for o in m.operands:
            if o.id in ids:
                parent[find(m.id)] = find(o.id)
    groups: Dict[int, List[Instruction]] = {}
    for m in core:
        groups.setdefault(find(m.id), []).append(m)
    return list(groups.values())


def _verify_solution(
    members, solution, blocks: int, subject: str, pass_name: str
) -> List[Diagnostic]:
    """The ``resolve_schedules`` soundness contract, re-checked: every
    member is assigned, chunked members agree with the launch grid, and
    every operand is readable (equal schedule or replicated) under its
    user's propagated requirement."""
    diags: List[Diagnostic] = []
    assignment = solution.assignment
    for m in members:
        sched = assignment.get(m.id)
        if sched is None:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN005",
                    f"member {m.name} has no schedule assignment",
                    subject, pass_name,
                )
            )
            continue
        if sched.kind == "chunked" and blocks_of(m.shape, sched) != blocks:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN005",
                    f"member {m.name}: {sched!r} yields "
                    f"{blocks_of(m.shape, sched)} blocks, launch grid is "
                    f"{blocks}",
                    subject, pass_name,
                )
            )
            continue
        try:
            needs = propagate(m, sched)
        except Unsatisfiable as e:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN005",
                    f"member {m.name}: no propagation under {sched!r}: {e}",
                    subject, pass_name,
                )
            )
            continue
        for o, osched in zip(m.operands, needs, strict=False):
            got = assignment.get(o.id)
            if got is None:
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN005",
                        f"member {m.name}: operand {o.name} unassigned",
                        subject, pass_name,
                    )
                )
            elif got != osched and got.kind != "replicated":
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN005",
                        f"member {m.name}: operand {o.name} has {got!r}, "
                        f"needs {osched!r}",
                        subject, pass_name,
                    )
                )
    return diags


def verify_planned_entries(state, pass_name: str = "") -> List[Diagnostic]:
    """Per-entry lint: schedule-solution soundness (per phase for stitched
    plans), VMEM budget, and the kernel-cache signature audit."""
    from .pipeline import _options_fingerprint
    from .signature import fusion_signature

    diags: List[Diagnostic] = []
    opts = state.options
    salt = _options_fingerprint(opts)
    for p in state.planned:
        fusion, entry = p.fusion, p.entry

        # -- signature-collision audit (EXEC005) ---------------------------
        # Re-hash the lowered body against the signature recorded when the
        # entry was committed.  Shrunk instances keep their PRE-shrink
        # signature on purpose (the entry records kept_members instead), so
        # only the salt check applies to them.
        if p.raw_signature is not None:
            if not p.shrunk and fusion_signature(fusion) != p.raw_signature:
                diags.append(
                    Diagnostic(
                        ERROR, "EXEC005",
                        "fusion body no longer hashes to its committed "
                        "signature",
                        fusion.name, pass_name,
                    )
                )
            if entry.signature != salt + p.raw_signature:
                diags.append(
                    Diagnostic(
                        ERROR, "EXEC005",
                        "cache entry signature does not match this compile's "
                        "options salt + body hash",
                        fusion.name, pass_name,
                    )
                )

        # Solution/memory checks describe the REPRESENTATIVE's instruction
        # ids; hit instances share the entry and are covered through it.
        if not p.is_representative:
            continue
        st = entry.stitched
        if st is not None:
            phase_ids = {m.id for ph in st.phases for m in ph.members}
            member_ids = {m.id for m in fusion.members}
            if phase_ids != member_ids:
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN005",
                        "stitched phases do not partition the member set "
                        f"({len(phase_ids)} phase members vs "
                        f"{len(member_ids)} fusion members)",
                        fusion.name, pass_name,
                    )
                )
            for k, ph in enumerate(st.phases):
                diags.extend(
                    _verify_solution(
                        ph.members, ph.solution, ph.blocks,
                        f"{fusion.name}/phase{k}", pass_name,
                    )
                )
            # interfaces = values produced in one phase, consumed later
            phase_of = {
                m.id: k for k, ph in enumerate(st.phases) for m in ph.members
            }
            want = {
                m.id
                for ph in st.phases
                for m in ph.members
                if any(
                    phase_of.get(u.id, -1) > phase_of[m.id] for u in m.users
                )
            }
            got = {i.id for i in st.interfaces}
            if want != got:
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN005",
                        f"staged interfaces disagree with the phase dataflow "
                        f"({len(got)} staged, {len(want)} required)",
                        fusion.name, pass_name,
                    )
                )
        elif entry.solution is not None:
            diags.extend(
                _verify_solution(
                    fusion.members, entry.solution, entry.solution.blocks,
                    fusion.name, pass_name,
                )
            )
        else:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN005",
                    "planned entry carries neither a schedule solution nor "
                    "a stitched plan",
                    fusion.name, pass_name,
                )
            )

        mem = entry.memory
        if mem is not None:
            used = mem.total_bytes + getattr(mem, "io_bytes", 0)
            if used > opts.vmem_limit:
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN006",
                        f"VMEM plan needs {used}B > budget {opts.vmem_limit}B",
                        fusion.name, pass_name,
                    )
                )
    return diags


def verify_shard_attrs(
    module: Module,
    mesh_axes,
    param_layouts=None,
    pass_name: str = "",
) -> List[Diagnostic]:
    """Shard-layout lint: re-derive every layout/partial from scratch and
    compare against the stamped attrs; flag partial sums reaching a root."""
    from .shard import derive_layouts, is_trivial_layout

    try:
        layouts, partial, _ = derive_layouts(module, mesh_axes, param_layouts)
    except ValueError as e:
        return [Diagnostic(ERROR, "PLAN007", str(e), module.name, pass_name)]

    diags: List[Diagnostic] = []
    for instr in module.instructions:
        expected = layouts.get(instr.id)
        stamped = instr.attrs.get("shard")
        if expected is not None and not is_trivial_layout(expected):
            if stamped != expected:
                diags.append(
                    Diagnostic(
                        ERROR, "PLAN007",
                        f"stamped shard {stamped!r} != derived {expected!r}",
                        instr.name, pass_name,
                    )
                )
        elif stamped is not None:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN007",
                    f"stale shard stamp {stamped!r} (derived layout is "
                    "trivial or unknown)",
                    instr.name, pass_name,
                )
            )
        want_partial = tuple(sorted(partial.get(instr.id, ())))
        got_partial = tuple(instr.attrs.get("partial", ()))
        if want_partial != got_partial:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN007",
                    f"stamped partial {got_partial!r} != derived "
                    f"{want_partial!r}",
                    instr.name, pass_name,
                )
            )
    for r in module.roots:
        open_axes = tuple(sorted(partial.get(r.id, ())))
        if open_axes:
            diags.append(
                Diagnostic(
                    ERROR, "PLAN008",
                    f"root carries an open partial sum over axes "
                    f"{open_axes} — missing all_reduce/reduce_scatter",
                    r.name, pass_name,
                )
            )
    return diags


# --------------------------------------------------------------------------
# Family 3: ExecutionPlan lint
# --------------------------------------------------------------------------


def verify_execution_plan(ep, pass_name: str = "") -> List[Diagnostic]:
    """Dataflow over the flat slot table + jit-segment donation audit."""
    from .executor import _JitSegment, _step_outs

    diags: List[Diagnostic] = []

    def err(rule: str, subject: str, message: str) -> None:
        diags.append(Diagnostic(ERROR, rule, message, subject, pass_name))

    param_slots = {slot for _, slot, _, _ in ep._param_binds}
    template_slots = {
        i for i, v in enumerate(ep._template) if v is not None
    }
    root_slots = {s for _, s in ep._root_binds}

    def _step_name(step) -> str:
        instr = getattr(step, "instr", None)
        if instr is not None:
            return instr.name
        return getattr(step.kernel, "name", "kernel")

    written: Set[int] = set(param_slots) | template_slots
    released: Set[int] = set()
    for step in ep.steps:
        name = _step_name(step)
        for s in step.arg_slots:
            if s not in written:
                err("EXEC001", name, f"reads slot {s} before it is written")
            elif s in released:
                err("EXEC002", name, f"reads slot {s} after its release point")
        for s in _step_outs(step):
            if s in written:
                err("EXEC001", name, f"writes slot {s} twice")
            if s in released:
                err("EXEC003", name, f"writes slot {s} after its release")
            written.add(s)
        for s in step.release:
            if s in root_slots:
                err("EXEC003", name, f"releases root slot {s}")
            if s in released:
                err("EXEC003", name, f"releases slot {s} twice")
            if s not in written:
                err("EXEC003", name, f"releases slot {s} that was never written")
            released.add(s)
    for rname, s in ep._root_binds:
        if s not in written:
            err("EXEC001", rname, f"root slot {s} is never produced")

    # -- jit-segment donation audit -----------------------------------------
    protected = template_slots | (param_slots - set(ep.donated_param_slots))
    segments = ep._segments
    # slots each segment suffix still reads, computed right-to-left
    future_reads: List[Set[int]] = [set() for _ in segments]
    acc: Set[int] = set()
    for k in range(len(segments) - 1, -1, -1):
        future_reads[k] = set(acc)
        seg = segments[k]
        if isinstance(seg, _JitSegment):
            acc.update(seg.in_slots)
        else:  # _LoopStep dispatches as its own unit
            acc.update(seg.arg_slots)
    for k, seg in enumerate(segments):
        if not isinstance(seg, _JitSegment):
            continue
        subject = f"segment {k}"
        for i in seg.donate:
            if not 0 <= i < len(seg.in_slots):
                err("EXEC004", subject, f"donate index {i} out of range")
                continue
            s = seg.in_slots[i]
            if s in protected:
                err(
                    "EXEC004", subject,
                    f"donates protected slot {s} (parameter/template buffer)",
                )
            if s not in seg.released:
                err(
                    "EXEC004", subject,
                    f"donates slot {s} that stays live inside the segment",
                )
            if s in future_reads[k]:
                err(
                    "EXEC004", subject,
                    f"donates slot {s} that a later segment still reads",
                )
    return diags


# --------------------------------------------------------------------------
# Boundary dispatch
# --------------------------------------------------------------------------


def verify_state(state, pass_name: str = "") -> List[Diagnostic]:
    """Run every analysis family the state's contents support.

    Called by ``PassPipeline.run`` at each verified boundary; families
    activate as their subject matter appears (the fusion-plan lint only
    after FusionPass has produced a plan, the ExecutionPlan lint only after
    FinalizePass has built one), so the same entry point serves every
    boundary of a strict run.
    """
    diags: List[Diagnostic] = list(verify_module(state.module, pass_name))

    if state.shard_stats and getattr(state.options, "mesh_axes", None):
        diags.extend(
            verify_shard_attrs(
                state.module,
                state.options.mesh_axes,
                state.param_layouts,
                pass_name,
            )
        )

    view = _plan_view(state)
    if view is not None:
        fusions, standalone = view
        diags.extend(
            verify_fusion_groups(fusions, standalone, state.module, pass_name)
        )

    if state.planned:
        diags.extend(verify_planned_entries(state, pass_name))

    executable = state.executable
    ep = getattr(executable, "execution_plan", None)
    if ep is not None:
        diags.extend(verify_execution_plan(ep, pass_name))
    return diags


def _plan_view(state) -> Optional[Tuple[list, list]]:
    """The (fusions, standalone) partition as it stands at this boundary:
    the raw FusionPass plan, then the planned/demoted view once SchedulePass
    has run, then the final executable's plan."""
    executable = state.executable
    if executable is not None:
        plan = executable.plan
        return list(plan.fusions), list(plan.standalone)
    if state.fusion_plan is None:
        return None
    if state.planned or state.demoted:
        return (
            [p.fusion for p in state.planned],
            list(state.fusion_plan.standalone) + list(state.demoted),
        )
    return list(state.fusion_plan.fusions), list(state.fusion_plan.standalone)
