"""Schedule tuning — paper §4.3.

Single root: iterate the root's candidate schedules, keep the cheapest
satisfiable one (per the performance library).

Multiple roots: the paper's two-stage search — (1) per root, compute the set
of valid ``blocks`` values; intersect across roots; (2) iterate only over
schedule combinations whose blocks lie in the agreed set, accumulating per-op
times with best-so-far early exit.

Two paper optimizations are implemented: computationally trivial ops
(reshape/bitcast/broadcast, small transposes) are ignored during scoring —
they inline via thread composition with negligible cost but would otherwise
veto good schedules — and scoring aborts as soon as the running sum exceeds
the incumbent.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional

from .ir import Instruction
from .latency import is_trivial as _is_trivial  # shared convention (latency.py)
from .perf_library import PerfLibrary
from .schedule import (
    REPLICATED,
    Sched,
    ScheduleSolution,
    Unsatisfiable,
    blocks_of,
    candidate_schedules,
    resolve_schedules,
)


@dataclass
class TunedPlan:
    solution: ScheduleSolution
    cost_s: float


def score(
    members: List[Instruction],
    solution: ScheduleSolution,
    lib: PerfLibrary,
    best_so_far: float = float("inf"),
) -> float:
    """Accumulated per-op time under the solution, with early exit."""
    total = 0.0
    for m in members:
        if _is_trivial(m):
            continue
        total += lib.lookup(m, solution.sched(m), solution.blocks)
        if total >= best_so_far:
            return float("inf")
    return lib.model.kernel_time(solution.blocks, total)


def tune(
    members: List[Instruction],
    roots: List[Instruction],
    lib: PerfLibrary,
    max_blocks: int = 1 << 16,
    replicate_limit: int = 512 * 1024,
    max_combos: int = 64,
) -> Optional[TunedPlan]:
    """Find the cheapest satisfiable schedule for a fused computation."""
    if len(roots) == 1:
        return _tune_single(members, roots, lib, max_blocks, replicate_limit)
    return _tune_multi(
        members, roots, lib, max_blocks, replicate_limit, max_combos
    )


def _tune_single(members, roots, lib, max_blocks, replicate_limit):
    root = roots[0]
    best: Optional[TunedPlan] = None
    for sched in candidate_schedules(root.shape, max_blocks):
        try:
            sol = resolve_schedules(
                members, roots, {root.id: sched}, replicate_limit
            )
        except Unsatisfiable:
            continue
        c = score(members, sol, lib, best.cost_s if best else float("inf"))
        if best is None or c < best.cost_s:
            best = TunedPlan(sol, c)
    return best


def _tune_multi(members, roots, lib, max_blocks, replicate_limit, max_combos):
    # ---- stage 1: intersect valid blocks sets across roots (paper §4.3) --
    per_root: List[Dict[int, List[Sched]]] = []
    for r in roots:
        by_blocks: Dict[int, List[Sched]] = {}
        for sched in candidate_schedules(r.shape, max_blocks):
            by_blocks.setdefault(blocks_of(r.shape, sched), []).append(sched)
        per_root.append(by_blocks)
    agreed = set(per_root[0])
    for bb in per_root[1:]:
        agreed &= set(bb)
    if not agreed:
        return None

    # ---- stage 2: iterate schedules in the agreed blocks set -------------
    best: Optional[TunedPlan] = None
    for b in sorted(agreed, reverse=True):  # prefer more parallelism first
        combos = itertools.islice(
            itertools.product(*[bb[b] for bb in per_root]), max_combos
        )
        for combo in combos:
            rs = {r.id: s for r, s in zip(roots, combo, strict=False)}
            try:
                sol = resolve_schedules(members, roots, rs, replicate_limit)
            except Unsatisfiable:
                continue
            c = score(members, sol, lib, best.cost_s if best else float("inf"))
            if best is None or c < best.cost_s:
                best = TunedPlan(sol, c)
    return best
