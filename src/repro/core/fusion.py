"""Deep fusion — paper §3.2 (ElementwiseFusion + Algorithm 1) — grown into a
**cost-guided fusion planner**.

The driver walks layers bottom-up (span 0 upward).  At each *root layer* it
first performs intra-layer ElementwiseFusion (horizontal fusion of
independent same-shape elementwise ops — the weight-accumulation pattern in
training graphs), then runs Algorithm 1 from every fusion seed in the layer,
fusing producer instructions layer-by-layer up to the *roof* (the next
library-call layer).

``SchdConsistent`` is injected by the compiler pipeline: it asks the schedule
planner whether an optimized schedule still exists for the enlarged fusion,
and the memory planner's infeasibility feedback arrives through the same
callable (paper §5.1.2 — "a feedback signal is generated back to
ScheduleConsistencyChecker").

**Planner (follow-up work, arXiv:2009.10924 / 2301.13062):** the original
paper *accepts or rejects* each greedy enlargement with a boolean check; the
successor systems show the real wins come from evaluating alternative fusion
plans under an analytic latency model and keeping the cheapest.  With
``FusionConfig.planner == "cost"``, each greedy-maximal seed result becomes
one *candidate partition* among several (split-at-reduce,
split-before-broadcast, no-fuse), every candidate is scored with the shared
``LatencyModel`` (``core/latency.py``) through a ``FusionScorer``, and the
cheapest feasible partition is committed.  A final **horizontal-merge** pass
packs independent fusions with matching root shapes into one kernel when the
model says the saved launches beat the packing cost.  The greedy result is
always in the candidate set, so the planner is never worse than greedy
*under the model* (the floor property; tested in ``tests/test_planner.py``).
``planner == "greedy"`` reproduces the paper's original behavior exactly.

**Stitching (arXiv:1911.11576 / 2009.10924):** the
injected SchdConsistent callable now accepts groups whose only lowering is
a multi-phase *stitched* kernel (``schedule.stitchable``'s three-way
verdict), the scorer charges those through
``LatencyModel.stitched_fusion_time``, committed stitched groups carry
their phase structure in ``FusedComputation.stitch_phases`` (which salts
the fusion signature), and independent same-layer sink towers are grown
separately then scored as ONE *packed* kernel against the per-tower floor
(``_sink_pack_groups`` / ``_choose_pack``) — the ReduceTowers/BcastHeavy
pathology reaches a single kernel at planning time instead of relying on
the horizontal-merge post-pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Set, Tuple

from .ir import Instruction, Module
from .latency import LatencyModel
from .memory import MemoryInfeasible, plan_memory, plan_stitched_memory
from .schedule import CONSISTENT, STITCHABLE, StitchVerdict, stitchable
from . import span as span_lib

# Opcodes that may live inside a fused computation.  Collectives
# (ir.COLLECTIVE_OPCODES) are deliberately absent: an all_reduce
# synchronizes the mesh, so it is a hard schedule break — compute on each
# side fuses into its own kernel and the collective stays a standalone
# step, the same way PR 3's phase machinery breaks at VMEM interfaces.
FUSABLE_OPCODES = frozenset(
    {
        "elementwise", "select", "reshape", "bitcast", "transpose",
        "broadcast", "reduce", "concat", "gather", "iota", "constant",
    }
)

# A broadcast that expands its operand at least this much marks a
# replication boundary the planner may split at.
_BCAST_EXPAND_FACTOR = 8


def fusable_member(instr: Instruction, fuse_dot: bool) -> bool:
    if instr.opcode == "dot":
        return fuse_dot and instr.attrs.get("fusable", False)
    if instr.opcode == "constant":
        # Pallas kernel bodies can only inline SCALAR constants (an array
        # would be a captured closure constant, which pallas_call rejects);
        # array constants stay kernel inputs, folded once at plan-build
        # time into the executor's buffer template.
        return instr.num_elements == 1
    return instr.opcode in FUSABLE_OPCODES


def constant_like(instr: Instruction) -> bool:
    """Constant-derived data-movement chains (constant/iota + shape ops over
    them).  These never launch a kernel — XLA folds them — and the paper
    inlines trivial ops via thread composition; they are absorbed into any
    consumer fusion regardless of layer roofs and never counted standalone.

    Memoized on the instruction (operands are immutable after construction):
    the naive recursion is exponential on shared-operand DAG chains.
    """
    cached = getattr(instr, "_constant_like", None)
    if cached is not None:
        return cached
    if instr.opcode in ("constant", "iota"):
        result = True
    elif instr.opcode in ("broadcast", "reshape", "bitcast", "transpose"):
        result = all(constant_like(o) for o in instr.operands)
    else:
        result = False
    instr._constant_like = result
    return result


@dataclass
class FusedComputation:
    """A group of instructions emitted as ONE stitched kernel."""

    members: List[Instruction]           # topological order
    name: str = "fusion"
    modeled_cost_s: Optional[float] = None   # planner's LatencyModel estimate
    # Phase structure (member count per phase) when the planner committed
    # this group as a multi-phase stitched lowering; None = single-schedule.
    # Salts the fusion signature so stitched and split lowerings never alias
    # in the kernel cache.
    stitch_phases: Optional[Tuple[int, ...]] = None
    # Signature of the member set the planner actually SCORED, when the
    # constant-absorption post-pass grew the group afterwards.  Measured-cost
    # records must be keyed by this (the scorer's lookup key on the next
    # compile), not by the post-absorption structure; None = they coincide.
    scored_signature: Optional[str] = None

    def __post_init__(self):
        ids = {m.id for m in self.members}
        self._ids = ids

    def __contains__(self, instr: Instruction) -> bool:
        return instr.id in self._ids

    @property
    def roots(self) -> List[Instruction]:
        """Outputs: members used outside the fusion (or module sinks)."""
        out = []
        for m in self.members:
            if not m.users or any(u.id not in self._ids for u in m.users):
                out.append(m)
        return out

    @property
    def inputs(self) -> List[Instruction]:
        seen, out = set(), []
        for m in self.members:
            for op in m.operands:
                if op.id not in self._ids and op.id not in seen:
                    seen.add(op.id)
                    out.append(op)
        return out

    def footprint_bytes(self) -> int:
        return sum(i.bytesize for i in self.inputs) + sum(
            r.bytesize for r in self.roots
        )

    def __repr__(self):
        return (
            f"FusedComputation({self.name}: {len(self.members)} ops, "
            f"roots={[r.name for r in self.roots]})"
        )


@dataclass
class PlannerStats:
    """What the cost-guided planner did, for CompileStats / benchmarks."""

    mode: str = "greedy"
    plans_explored: int = 0        # candidate partitions scored (incl. greedy)
    plans_rejected: int = 0        # candidates with no feasible schedule/memory
    splits_taken: int = 0          # seeds committed as a non-greedy partition
    merges_taken: int = 0          # horizontal merges applied
    packs_taken: int = 0           # sink groups committed as ONE packed kernel
    stitches_taken: int = 0        # groups committed with multi-phase lowering
    # The "greedy floor": per-seed whole-group commits under the SAME
    # consistency regime as the planner (including stitching when enabled).
    # This is the plan the floor property guarantees we never exceed.  It is
    # NOT the paper-exact greedy on stitched graphs — there a seed grows
    # across breaks that planner="greedy" would refuse, so compile with
    # planner="greedy" (as bench_fusion_planner does) for that comparison.
    greedy_kernels: int = 0        # kernels the floor plan would launch
    planned_kernels: int = 0       # kernels the committed plan launches
    predicted_s: float = 0.0       # modeled latency of the committed plan
    greedy_predicted_s: float = 0.0  # modeled latency of the floor plan

    @property
    def launches_saved_vs_greedy(self) -> int:
        return self.greedy_kernels - self.planned_kernels


@dataclass
class FusionPlan:
    fusions: List[FusedComputation]
    standalone: List[Instruction]        # unfused kernel launches (incl. LC dots)
    module: Module
    planner: Optional[PlannerStats] = None

    @property
    def num_kernels(self) -> int:
        """Kernel launches excluding library calls and collectives (the
        paper's Fig-7 metric; collectives are ICI traffic, not launches)."""
        return len(self.fusions) + sum(
            1
            for s in self.standalone
            if not s.is_library_call and not s.is_collective
        )

    @property
    def num_library_calls(self) -> int:
        return sum(1 for s in self.standalone if s.is_library_call)

    @property
    def num_collectives(self) -> int:
        return sum(1 for s in self.standalone if s.is_collective)


def _always_consistent(roots: List[Instruction], members: List[Instruction]) -> bool:
    return True


@dataclass
class FusionConfig:
    fuse_dot: bool = True                 # user decision, paper §2.1
    ew_footprint_limit: int = 64 * 1024 * 1024   # ElementwiseFusion threshold
    max_fusion_ops: int = 256
    # SchdConsistent(roots, tentative_members) -> bool.  Injected by the
    # compiler; defaults to permissive for structural tests.
    consistency: Callable[[List[Instruction], List[Instruction]], bool] = (
        _always_consistent
    )
    # "cost": candidate-partition exploration under the LatencyModel (with
    # the greedy result as the floor).  "greedy": the paper's Algorithm 1
    # accept/reject, exactly as before.
    planner: str = "cost"
    # Multi-phase stitching (arXiv:1911.11576 / 2009.10924): lets the cost
    # planner pack independent same-layer sinks into one kernel and commit
    # groups with no single consistent schedule as phase-stitched lowerings.
    enable_stitching: bool = True
    # Scorer shared with the rest of the compile (built from the pipeline's
    # PerfLibrary model + StitchOptions limits); a default one is
    # constructed when the planner runs without a pipeline.
    scorer: Optional["FusionScorer"] = None
    # True when ``consistency`` is exactly the scorer's own feasibility
    # check (any_satisfiable + plan_memory under the same limits) — the
    # pipeline sets this so planner commits skip the duplicate solve.
    # Custom checkers injected by direct deep_fuse callers keep the veto.
    scorer_covers_consistency: bool = False


class FusionScorer:
    """Scores candidate partitions for the cost-guided planner.

    Feasibility uses the same machinery the pipeline's consistency checker
    uses (the three-way ``stitchable`` verdict + the matching memory plan);
    the time estimate is the shared ``LatencyModel`` — ``fusion_time`` for
    schedule-consistent groups, ``stitched_fusion_time`` (which charges the
    interface staging traffic and phase-loop overhead) for groups that only
    lower as multi-phase stitched kernels.  Scores are memoized by member-id
    frozenset — candidate partitions overlap heavily (the greedy group
    reappears inside every merge attempt).

    When a ``measured`` store is attached (autotuning), a feasible group's
    cost is replaced by the remembered on-device time whenever the group's
    salted signature hits the store; the analytic number stays the cold-start
    prior.  Feasibility itself NEVER consults measurements — an infeasible
    group stays None no matter what the store claims — so a warm store can
    flip plan *choices* but never plan *validity*.
    """

    def __init__(
        self,
        model: Optional[LatencyModel] = None,
        replicate_limit: int = 512 * 1024,
        max_blocks: int = 4096,
        vmem_limit: int = 4 * 1024 * 1024,
        allow_stitch: bool = True,
        stitch_replicate_limit: Optional[int] = None,
        stitch_max_blocks: int = 64,
        measured=None,
        options_salt: str = "",
        mesh_axes: Tuple[Tuple[str, int], ...] = (),
    ):
        self.model = model or LatencyModel()
        self.mesh_axes = dict(mesh_axes)
        # MeasuredCostStore (duck-typed: .get(sig) -> obj with .cost_s, or
        # None) — fusion.py cannot import core.measure (signature.py sits
        # between them in the import graph).
        self.measured = measured
        self.options_salt = options_salt
        self.replicate_limit = replicate_limit
        self.max_blocks = max_blocks
        self.vmem_limit = vmem_limit
        self.allow_stitch = allow_stitch
        self.stitch_replicate_limit = (
            vmem_limit if stitch_replicate_limit is None else stitch_replicate_limit
        )
        self.stitch_max_blocks = stitch_max_blocks
        self._memo: Dict[frozenset, Optional[float]] = {}
        self._verdicts: Dict[frozenset, StitchVerdict] = {}

    def standalone_cost(self, instr: Instruction) -> float:
        if instr.is_collective:
            g = 1
            for a in instr.attrs.get("axes", ()):
                g *= self.mesh_axes.get(a, 1)
            return self.model.collective_op_time(instr, g)
        return self.model.standalone_time(instr)

    def verdict(self, members: List[Instruction]) -> StitchVerdict:
        """Memoized three-way schedule verdict for a member set."""
        key = frozenset(m.id for m in members)
        if key not in self._verdicts:
            roots = FusedComputation(list(members), name="candidate").roots
            self._verdicts[key] = stitchable(
                roots,
                members,
                replicate_limit=self.replicate_limit,
                max_blocks=self.max_blocks,
                stitch_replicate_limit=self.stitch_replicate_limit,
                stitch_max_blocks=self.stitch_max_blocks,
                allow_stitch=self.allow_stitch,
            )
        return self._verdicts[key]

    def stitch_phases_for(
        self, members: List[Instruction]
    ) -> Optional[Tuple[int, ...]]:
        """Phase structure the committed group will lower with, or None for
        single-schedule groups.  Only consults the memo — never solves."""
        v = self._verdicts.get(frozenset(m.id for m in members))
        if v is not None and v.verdict == STITCHABLE and v.stitched is not None:
            return v.stitched.phase_sizes
        return None

    def fused_cost(self, members: List[Instruction]) -> Optional[float]:
        """Modeled seconds for ``members`` as ONE kernel; None = infeasible."""
        key = frozenset(m.id for m in members)
        if key not in self._memo:
            self._memo[key] = self._fused_cost(members)
        return self._memo[key]

    def _fused_cost(self, members: List[Instruction]) -> Optional[float]:
        fusion = FusedComputation(list(members), name="candidate")
        if len(members) == 1:
            return self._maybe_measured(fusion, self.standalone_cost(members[0]))
        roots = fusion.roots
        v = self.verdict(members)
        if v.verdict == CONSISTENT:
            try:
                plan_memory(members, roots, v.solution, self.vmem_limit)
            except MemoryInfeasible:
                return None
            return self._maybe_measured(
                fusion, self.model.fusion_time(members, roots, v.solution)
            )
        if v.verdict == STITCHABLE:
            try:
                plan_stitched_memory(v.stitched, self.vmem_limit)
            except MemoryInfeasible:
                return None
            # Sign the candidate with the phase structure it would lower
            # with, so its store key matches the committed stitched kernel's.
            fusion.stitch_phases = v.stitched.phase_sizes
            return self._maybe_measured(
                fusion, self.model.stitched_fusion_time(v.stitched)
            )
        return None

    def _maybe_measured(
        self, fusion: FusedComputation, analytic: float
    ) -> float:
        """Measured seconds when the store knows this lowering, else the
        analytic prior.  Called only on FEASIBLE groups."""
        if self.measured is None:
            return analytic
        from .signature import fusion_signature  # local: signature imports us

        rec = self.measured.get(self.options_salt + fusion_signature(fusion))
        return rec.cost_s if rec is not None else analytic

    def partition_cost(
        self, groups: List[List[Instruction]]
    ) -> Optional[List[float]]:
        """Per-group modeled cost, or None if any group is infeasible."""
        out = []
        for g in groups:
            c = self.fused_cost(g)
            if c is None:
                return None
            out.append(c)
        return out


def _topo_sorted(members: Set[Instruction], module: Module) -> List[Instruction]:
    ids = {m.id for m in members}
    return [i for i in module.instructions if i.id in ids]


def _elementwise_groups(
    layer: List[Instruction], assigned: Set[int], cfg: FusionConfig
) -> List[List[Instruction]]:
    """Group independent same-layer elementwise ops by output shape, chunked
    by the footprint threshold (paper §3.2 ElementwiseFusion)."""
    by_shape: Dict[tuple, List[Instruction]] = {}
    for instr in layer:
        if instr.id in assigned or not instr.is_elementwise:
            continue
        by_shape.setdefault((instr.shape, str(instr.dtype)), []).append(instr)
    groups = []
    for _, instrs in sorted(by_shape.items(), key=lambda kv: str(kv[0])):
        cur, cur_bytes = [], 0
        for i in instrs:
            fp = i.footprint_bytes()
            if cur and cur_bytes + fp > cfg.ew_footprint_limit:
                groups.append(cur)
                cur, cur_bytes = [], 0
            cur.append(i)
            cur_bytes += fp
        if cur:
            groups.append(cur)
    # Only multi-op groups constitute a horizontal fusion seed.
    return [g for g in groups if len(g) >= 2]


def _would_cycle(hlo: Instruction, fused: Set[Instruction]) -> bool:
    """True if fusing ``hlo`` creates a group-level dependence cycle: a path
    from ``hlo`` through outside-the-fusion consumers back to an input of the
    fusion.  (The paper collapses fusions into single HLO instructions after
    each pass, which makes such cycles visible structurally; with virtual
    groups we check reachability explicitly.)"""
    stack = [u for u in hlo.users if u not in fused]
    seen: Set[int] = set()
    while stack:
        n = stack.pop()
        if n.id in seen:
            continue
        seen.add(n.id)
        if any(u in fused for u in n.users):
            return True
        stack.extend(u for u in n.users if u not in fused)
    return False


def subgraph_fuse(
    seed: List[Instruction],
    module: Module,
    span: Dict[int, int],
    layer_map: Dict[int, List[Instruction]],
    roof: int,
    assigned: Set[int],
    cfg: FusionConfig,
) -> List[Instruction]:
    """Algorithm 1: fuse producers layer-by-layer from the seed up to roof."""
    fused: Set[Instruction] = set(seed)
    giveup: Set[Instruction] = set()
    roots = list(seed)
    curr_span = max(span[s.id] for s in seed)
    # The roof layer's NON-library ops are fusable (only the library call
    # itself is a boundary); constant-like producers get a final absorption
    # pass below, unbounded by roofs.
    for lvl in range(curr_span + 1, roof + 1):
        for hlo in layer_map.get(lvl, ()):
            if hlo.id in assigned or hlo in fused:
                continue
            if not fusable_member(hlo, cfg.fuse_dot):
                continue
            if len(fused) >= cfg.max_fusion_ops:
                return _topo_sorted(fused, module)
            # --- SchdConsistent (paper §3.2) -----------------------------
            if any(u in giveup for u in hlo.users):
                giveup.add(hlo)            # poisoned: avoid dependence loops
                continue
            if not any(u in fused for u in hlo.users):
                continue                   # producer/consumer fusion only
            if _would_cycle(hlo, fused):
                giveup.add(hlo)
                continue
            tentative = _topo_sorted(fused | {hlo}, module)
            if cfg.consistency(roots, tentative):
                fused.add(hlo)
            else:
                giveup.add(hlo)
    return _topo_sorted(fused, module)


# --------------------------------------------------------------------------
# Candidate-partition exploration (the cost-guided planner)
# --------------------------------------------------------------------------


def _candidate_partitions(
    members: List[Instruction],
) -> List[Tuple[str, List[List[Instruction]]]]:
    """Alternative partitions of one greedy-maximal member set.

    Every partition cuts ``members`` (module-topological order) into
    contiguous runs, which can never introduce a group-level cycle: a run
    only depends on earlier runs and on values outside the set.
    """
    cands: List[Tuple[str, List[List[Instruction]]]] = [("greedy", [members])]
    if len(members) == 1:
        return cands

    # split AFTER each reduce: the reduce ends its group, so its consumers
    # (typically a broadcast back to the wide shape) start a fresh kernel —
    # the anti-over-fusion cut from the follow-up papers.
    groups: List[List[Instruction]] = []
    cur: List[Instruction] = []
    for m in members:
        cur.append(m)
        if m.opcode == "reduce":
            groups.append(cur)
            cur = []
    if cur:
        groups.append(cur)
    if len(groups) > 1:
        cands.append(("split_reduce", groups))

    # split BEFORE each widening broadcast: the replication boundary.
    groups2: List[List[Instruction]] = []
    cur = []
    for m in members:
        if (
            cur
            and m.opcode == "broadcast"
            and m.operands
            and m.num_elements
            >= _BCAST_EXPAND_FACTOR * max(1, m.operands[0].num_elements)
        ):
            groups2.append(cur)
            cur = []
        cur.append(m)
    if cur:
        groups2.append(cur)
    if len(groups2) > 1 and [len(g) for g in groups2] != [len(g) for g in groups]:
        cands.append(("split_broadcast", groups2))

    cands.append(("nofuse", [[m] for m in members]))
    return cands


def _consistent_partition(
    groups: List[List[Instruction]], cfg: FusionConfig
) -> bool:
    """Every group must satisfy the injected SchdConsistent checker — the
    planner explores partitions, but the extension point still vetoes.
    Skipped when the checker is the scorer's own feasibility test, which
    the scoring pass already ran (and memoized)."""
    if cfg.scorer_covers_consistency:
        return True
    for g in groups:
        roots = FusedComputation(list(g), name="candidate").roots
        if not cfg.consistency(roots, g):
            return False
    return True


def _choose_partition(
    members: List[Instruction],
    scorer: Optional[FusionScorer],
    cfg: FusionConfig,
    stats: PlannerStats,
) -> Tuple[List[List[Instruction]], List[Optional[float]]]:
    """Pick the cheapest feasible partition; greedy is the floor.

    Returns (groups, per-group modeled costs).  When the greedy group cannot
    be scored (no satisfiable schedule under the scorer's limits — only
    reachable with a permissive external consistency checker), the greedy
    result is committed unscored, exactly as the greedy planner would.
    Single-member seeds are scored too, so the horizontal-merge pass can
    still pack them (single-op launch-bound towers are exactly the
    missed-merge pathology).
    """
    if scorer is None:
        return [members], [None]
    if len(members) <= 1:
        cost = scorer.fused_cost(members)
        stats.greedy_predicted_s += cost or 0.0
        return [members], [cost]
    cands = _candidate_partitions(members)
    stats.plans_explored += 1
    greedy_costs = scorer.partition_cost(cands[0][1])
    if greedy_costs is None:
        stats.plans_rejected += 1
        return [members], [None]
    best_name, best_groups, best_costs = "greedy", cands[0][1], greedy_costs
    best_total = sum(best_costs)
    for name, groups in cands[1:]:
        stats.plans_explored += 1
        costs = scorer.partition_cost(groups)
        if costs is None or not _consistent_partition(groups, cfg):
            stats.plans_rejected += 1
            continue
        total = sum(costs)
        if total < best_total:
            best_name, best_groups, best_costs = name, groups, costs
            best_total = total
    if best_name != "greedy":
        stats.splits_taken += 1
    stats.greedy_predicted_s += sum(greedy_costs)
    return best_groups, list(best_costs)


def _commit_fusion(
    g: List[Instruction],
    name: str,
    cost: Optional[float],
    scorer: Optional[FusionScorer],
) -> FusedComputation:
    """Build a committed FusedComputation, marking the phase structure when
    the scorer's verdict said the group lowers as a multi-phase stitch."""
    fc = FusedComputation(g, name=name, modeled_cost_s=cost)
    if scorer is not None and len(g) > 1:
        fc.stitch_phases = scorer.stitch_phases_for(g)
    return fc


def _sink_pack_groups(
    layer: List[Instruction],
    assigned: Set[int],
    claimed: Set[int],
    cfg: FusionConfig,
) -> List[List[Instruction]]:
    """Independent same-layer non-elementwise sinks with matching output
    (shape, dtype), e.g. N reduce towers or N reshape-terminated towers.
    ElementwiseFusion never groups these (its seeds are elementwise), so
    greedy commits one kernel per sink; the planner grows each sink's tower
    separately and then scores the union as ONE packed kernel against the
    per-tower floor (the stitch-across-break / pack candidate)."""
    by_key: Dict[tuple, List[Instruction]] = {}
    for instr in layer:
        if instr.id in assigned or instr.id in claimed:
            continue
        if instr.is_elementwise or instr.opcode in ("parameter", "constant", "iota"):
            continue
        if constant_like(instr) or not fusable_member(instr, cfg.fuse_dot):
            continue
        by_key.setdefault((tuple(instr.shape), str(instr.dtype)), []).append(instr)
    return [
        g
        for _, g in sorted(by_key.items(), key=lambda kv: str(kv[0]))
        if len(g) >= 2
    ]


def _choose_pack(
    towers: List[List[Instruction]],
    module: Module,
    scorer: FusionScorer,
    cfg: FusionConfig,
    stats: PlannerStats,
) -> Tuple[List[List[Instruction]], List[Optional[float]]]:
    """Commit a sink-pack group: either the union of all towers as ONE
    kernel, or each tower's own best partition (the greedy floor)."""
    groups: List[List[Instruction]] = []
    costs: List[Optional[float]] = []
    splits_before = stats.splits_taken
    for t in towers:
        g, c = _choose_partition(t, scorer, cfg, stats)
        groups.extend(g)
        costs.extend(c)
    if len(towers) < 2 or any(c is None for c in costs):
        return groups, costs
    union = set()
    for t in towers:
        union.update(t)
    if _group_cycle(union):
        return groups, costs
    packed = _topo_sorted(union, module)
    if len(packed) > cfg.max_fusion_ops:
        return groups, costs
    if (
        FusedComputation(packed, name="candidate").footprint_bytes()
        > cfg.ew_footprint_limit
    ):
        return groups, costs
    stats.plans_explored += 1
    cost = scorer.fused_cost(packed)
    if cost is None or not _consistent_partition([packed], cfg):
        stats.plans_rejected += 1
        return groups, costs
    if cost < sum(costs):
        stats.packs_taken += 1
        # the per-tower partitions (and any splits they took) are discarded
        stats.splits_taken = splits_before
        return [packed], [cost]
    return groups, costs


def _group_cycle(fused: Set[Instruction]) -> bool:
    """Would the member union reach itself through outside instructions?"""
    stack = [u for m in fused for u in m.users if u not in fused]
    seen: Set[int] = set()
    while stack:
        n = stack.pop()
        if n.id in seen:
            continue
        seen.add(n.id)
        for u in n.users:
            if u in fused:
                return True
            stack.append(u)
    return False


def _merge_key(f: FusedComputation) -> tuple:
    return tuple(sorted((tuple(r.shape), str(r.dtype)) for r in f.roots))


def _horizontal_merge(
    fusions: List[FusedComputation],
    module: Module,
    scorer: FusionScorer,
    cfg: FusionConfig,
    stats: PlannerStats,
) -> List[FusedComputation]:
    """Pack independent fusions with matching root shapes into one kernel
    when the model says the saved launches beat the packing cost.

    Greedy never does this beyond same-layer ElementwiseFusion — missed
    horizontal merges are one of the two greedy pathologies the XLA fusion
    study (arXiv:2301.13062) documents.  Merges are gated on: known costs
    for both sides, the combined op count and footprint staying under the
    ElementwiseFusion limits, no group-level cycle through outside
    instructions (which also keeps dependent fusions on opposite sides of a
    library-call layer apart), a feasible merged schedule + memory plan, a
    strict modeled-latency improvement, and the injected SchdConsistent
    checker accepting the merged group.
    """
    changed = True
    while changed:
        changed = False
        by_key: Dict[tuple, List[int]] = {}
        for idx, f in enumerate(fusions):
            by_key.setdefault(_merge_key(f), []).append(idx)
        for idxs in by_key.values():
            if len(idxs) < 2:
                continue
            for ai in range(len(idxs)):
                a = fusions[idxs[ai]]
                if a is None or a.modeled_cost_s is None:
                    continue
                for bi in range(ai + 1, len(idxs)):
                    b = fusions[idxs[bi]]
                    if b is None or b.modeled_cost_s is None:
                        continue
                    if len(a.members) + len(b.members) > cfg.max_fusion_ops:
                        continue
                    if (
                        a.footprint_bytes() + b.footprint_bytes()
                        > cfg.ew_footprint_limit
                    ):
                        continue
                    union = set(a.members) | set(b.members)
                    if _group_cycle(union):
                        continue
                    merged_members = _topo_sorted(union, module)
                    stats.plans_explored += 1
                    cost = scorer.fused_cost(merged_members)
                    if cost is None:
                        stats.plans_rejected += 1
                        continue
                    if cost >= a.modeled_cost_s + b.modeled_cost_s:
                        continue
                    if not _consistent_partition([merged_members], cfg):
                        stats.plans_rejected += 1
                        continue
                    merged = _commit_fusion(
                        merged_members, a.name, cost, scorer
                    )
                    fusions[idxs[ai]] = merged
                    fusions[idxs[bi]] = None
                    a = merged
                    stats.merges_taken += 1
                    changed = True
        fusions = [f for f in fusions if f is not None]
    return fusions


# --------------------------------------------------------------------------
# The driver
# --------------------------------------------------------------------------


def deep_fuse(module: Module, cfg: Optional[FusionConfig] = None) -> FusionPlan:
    """The full fusion driver: Algorithm 1 growth (paper §3.2) plus, in
    ``planner="cost"`` mode, candidate-partition exploration and horizontal
    merging under the shared LatencyModel."""
    cfg = cfg or FusionConfig()
    scorer: Optional[FusionScorer] = None
    if cfg.planner == "cost":
        scorer = cfg.scorer or FusionScorer()
    stats = PlannerStats(mode=cfg.planner)

    span = span_lib.compute_spans(module)
    layer_map = span_lib.layers(module, span)
    max_span = max(span.values()) if span else 0
    lcs = span_lib.lc_spans(module, span)

    assigned: Set[int] = set()
    fusions: List[FusedComputation] = []
    forced_standalone: List[Instruction] = []
    greedy_fusion_count = 0      # kernels the pure-greedy plan would emit

    for root_span in range(0, max_span + 1):
        layer = layer_map.get(root_span, [])
        roof = span_lib.roof_for(root_span, lcs, max_span)

        # -- step 1: intra-layer ElementwiseFusion ------------------------
        seeds: List[List[Instruction]] = _elementwise_groups(layer, assigned, cfg)
        claimed = {i.id for g in seeds for i in g}
        # -- step 1.5: horizontal sink packs (cost planner + stitching) ---
        packs: List[List[Instruction]] = []
        if scorer is not None and cfg.enable_stitching:
            packs = _sink_pack_groups(layer, assigned, claimed, cfg)
            for g in packs:
                claimed.update(i.id for i in g)
        # -- step 2: every remaining fusable instruction seeds Algorithm 1
        for instr in layer:
            if instr.id in assigned or instr.id in claimed:
                continue
            if instr.opcode in ("parameter", "constant", "iota"):
                continue
            if constant_like(instr):
                continue  # folded at compile time; absorbed where consumed
            if not fusable_member(instr, cfg.fuse_dot):
                continue
            seeds.append([instr])

        for seed in seeds:
            if not cfg.consistency(seed, seed):
                # even the seed alone has no valid schedule — leave standalone
                for s in seed:
                    assigned.add(s.id)
                    forced_standalone.append(s)
                continue
            members = subgraph_fuse(
                seed, module, span, layer_map, roof, assigned, cfg
            )
            for m in members:
                assigned.add(m.id)
            greedy_fusion_count += 1
            groups, costs = _choose_partition(members, scorer, cfg, stats)
            for g, c in zip(groups, costs, strict=False):
                fusions.append(
                    _commit_fusion(g, f"f{len(fusions)}", c, scorer)
                )

        # -- step 3: sink-pack groups — grow each tower exactly as greedy
        # would (one seed per sink), then score the union as ONE kernel
        for group in packs:
            towers: List[List[Instruction]] = []
            for sink in group:
                if not cfg.consistency([sink], [sink]):
                    assigned.add(sink.id)
                    forced_standalone.append(sink)
                    continue
                t = subgraph_fuse(
                    [sink], module, span, layer_map, roof, assigned, cfg
                )
                for m in t:
                    assigned.add(m.id)
                towers.append(t)
                greedy_fusion_count += 1
            if not towers:
                continue
            groups, costs = _choose_pack(towers, module, scorer, cfg, stats)
            for g, c in zip(groups, costs, strict=False):
                fusions.append(
                    _commit_fusion(g, f"f{len(fusions)}", c, scorer)
                )

    # --- horizontal-merge post-pass (cost mode only) ---------------------
    if scorer is not None:
        fusions = _horizontal_merge(fusions, module, scorer, cfg, stats)

    # --- final pass: absorb constant-like producer chains (free ops) -----
    absorbed_fusions: List[FusedComputation] = []
    for f in fusions:
        members = set(f.members)
        stack = [o for m in f.members for o in m.operands]
        while stack:
            o = stack.pop()
            if o in members or o.id in assigned or o.opcode == "parameter":
                continue
            if o.opcode == "constant" and o.num_elements > 1:
                # Pallas kernel bodies can only inline SCALAR constants
                # (arrays would be captured closure constants, which
                # pallas_call rejects); array constants stay kernel inputs,
                # folded once at plan-build time into the buffer template.
                continue
            if constant_like(o):
                members.add(o)
                assigned.add(o.id)
                stack.extend(o.operands)
        scored_sig = None
        if (
            len(members) > len(f.members)
            and scorer is not None
            and scorer.measured is not None
        ):
            # Absorption changed the structure AFTER scoring: remember the
            # signature the scorer looked up, so the autotuner can file the
            # measurement under the key the next compile's scorer will ask
            # for.
            from .signature import fusion_signature  # local: import cycle

            scored_sig = fusion_signature(f)
        absorbed_fusions.append(
            FusedComputation(
                _topo_sorted(members, module),
                name=f.name,
                modeled_cost_s=f.modeled_cost_s,
                stitch_phases=f.stitch_phases,
                scored_signature=scored_sig,
            )
        )
    fusions = absorbed_fusions

    standalone = forced_standalone + [
        i
        for i in module.instructions
        if i.id not in assigned
        and i.opcode not in ("parameter", "constant")
        and not constant_like(i)
    ]
    # Drop trivial single-op "fusions" of free ops back to standalone
    real_fusions, extra = [], []
    for f in fusions:
        if len(f.members) == 1 and f.members[0].opcode in ("iota",):
            extra.append(f.members[0])
        else:
            real_fusions.append(f)
    plan = FusionPlan(real_fusions, standalone + extra, module, planner=stats)

    # --- planner accounting ----------------------------------------------
    # Collectives are charged (collective_op_time) but never counted as
    # kernels — they appear in neither mode's launch tally.
    shared_standalone = [
        s
        for s in plan.standalone
        if not s.is_library_call and not s.is_collective
    ]
    # Split/no-fuse singletons stay singleton *fusions* (never standalone),
    # so the standalone list is identical in both modes and greedy's kernel
    # count is one fusion per committed seed plus that shared remainder.
    stats.planned_kernels = plan.num_kernels
    stats.greedy_kernels = greedy_fusion_count + len(shared_standalone)
    stats.stitches_taken = sum(
        1 for f in plan.fusions if f.stitch_phases is not None
    )
    if scorer is not None:
        shared_cost = sum(
            scorer.standalone_cost(s) for s in shared_standalone
        ) + sum(
            scorer.standalone_cost(s)
            for s in plan.standalone
            if s.is_collective
        )
        stats.predicted_s = shared_cost + sum(
            f.modeled_cost_s
            for f in plan.fusions
            if f.modeled_cost_s is not None
        )
        stats.greedy_predicted_s += shared_cost
    return plan
