"""Deep fusion — paper §3.2 (ElementwiseFusion + Algorithm 1).

The driver walks layers bottom-up (span 0 upward).  At each *root layer* it
first performs intra-layer ElementwiseFusion (horizontal fusion of
independent same-shape elementwise ops — the weight-accumulation pattern in
training graphs), then runs Algorithm 1 from every fusion seed in the layer,
fusing producer instructions layer-by-layer up to the *roof* (the next
library-call layer).

``SchdConsistent`` is injected by the compiler pipeline: it asks the schedule
planner whether an optimized schedule still exists for the enlarged fusion,
and the memory planner's infeasibility feedback arrives through the same
callable (paper §5.1.2 — "a feedback signal is generated back to
ScheduleConsistencyChecker").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set

from .ir import Instruction, Module
from . import span as span_lib

# Opcodes that may live inside a fused computation.
FUSABLE_OPCODES = frozenset(
    {
        "elementwise", "select", "reshape", "bitcast", "transpose",
        "broadcast", "reduce", "concat", "gather", "iota", "constant",
    }
)


def fusable_member(instr: Instruction, fuse_dot: bool) -> bool:
    if instr.opcode == "dot":
        return fuse_dot and instr.attrs.get("fusable", False)
    return instr.opcode in FUSABLE_OPCODES


def constant_like(instr: Instruction) -> bool:
    """Constant-derived data-movement chains (constant/iota + shape ops over
    them).  These never launch a kernel — XLA folds them — and the paper
    inlines trivial ops via thread composition; they are absorbed into any
    consumer fusion regardless of layer roofs and never counted standalone.

    Memoized on the instruction (operands are immutable after construction):
    the naive recursion is exponential on shared-operand DAG chains.
    """
    cached = getattr(instr, "_constant_like", None)
    if cached is not None:
        return cached
    if instr.opcode in ("constant", "iota"):
        result = True
    elif instr.opcode in ("broadcast", "reshape", "bitcast", "transpose"):
        result = all(constant_like(o) for o in instr.operands)
    else:
        result = False
    instr._constant_like = result
    return result


@dataclass
class FusedComputation:
    """A group of instructions emitted as ONE stitched kernel."""

    members: List[Instruction]           # topological order
    name: str = "fusion"

    def __post_init__(self):
        ids = {m.id for m in self.members}
        self._ids = ids

    def __contains__(self, instr: Instruction) -> bool:
        return instr.id in self._ids

    @property
    def roots(self) -> List[Instruction]:
        """Outputs: members used outside the fusion (or module sinks)."""
        out = []
        for m in self.members:
            if not m.users or any(u.id not in self._ids for u in m.users):
                out.append(m)
        return out

    @property
    def inputs(self) -> List[Instruction]:
        seen, out = set(), []
        for m in self.members:
            for op in m.operands:
                if op.id not in self._ids and op.id not in seen:
                    seen.add(op.id)
                    out.append(op)
        return out

    def footprint_bytes(self) -> int:
        return sum(i.bytesize for i in self.inputs) + sum(
            r.bytesize for r in self.roots
        )

    def __repr__(self):
        return (
            f"FusedComputation({self.name}: {len(self.members)} ops, "
            f"roots={[r.name for r in self.roots]})"
        )


@dataclass
class FusionPlan:
    fusions: List[FusedComputation]
    standalone: List[Instruction]        # unfused kernel launches (incl. LC dots)
    module: Module

    @property
    def num_kernels(self) -> int:
        """Kernel launches excluding library calls (paper's Fig-7 metric)."""
        return len(self.fusions) + sum(
            1 for s in self.standalone if not s.is_library_call
        )

    @property
    def num_library_calls(self) -> int:
        return sum(1 for s in self.standalone if s.is_library_call)


@dataclass
class FusionConfig:
    fuse_dot: bool = True                 # user decision, paper §2.1
    ew_footprint_limit: int = 64 * 1024 * 1024   # ElementwiseFusion threshold
    max_fusion_ops: int = 256
    # SchdConsistent(roots, tentative_members) -> bool.  Injected by the
    # compiler; defaults to permissive for structural tests.
    consistency: Callable[[List[Instruction], List[Instruction]], bool] = (
        lambda roots, members: True
    )


def _topo_sorted(members: Set[Instruction], module: Module) -> List[Instruction]:
    ids = {m.id for m in members}
    return [i for i in module.instructions if i.id in ids]


def _elementwise_groups(
    layer: List[Instruction], assigned: Set[int], cfg: FusionConfig
) -> List[List[Instruction]]:
    """Group independent same-layer elementwise ops by output shape, chunked
    by the footprint threshold (paper §3.2 ElementwiseFusion)."""
    by_shape: Dict[tuple, List[Instruction]] = {}
    for instr in layer:
        if instr.id in assigned or not instr.is_elementwise:
            continue
        by_shape.setdefault((instr.shape, str(instr.dtype)), []).append(instr)
    groups = []
    for _, instrs in sorted(by_shape.items(), key=lambda kv: str(kv[0])):
        cur, cur_bytes = [], 0
        for i in instrs:
            fp = i.footprint_bytes()
            if cur and cur_bytes + fp > cfg.ew_footprint_limit:
                groups.append(cur)
                cur, cur_bytes = [], 0
            cur.append(i)
            cur_bytes += fp
        if cur:
            groups.append(cur)
    # Only multi-op groups constitute a horizontal fusion seed.
    return [g for g in groups if len(g) >= 2]


def _would_cycle(hlo: Instruction, fused: Set[Instruction]) -> bool:
    """True if fusing ``hlo`` creates a group-level dependence cycle: a path
    from ``hlo`` through outside-the-fusion consumers back to an input of the
    fusion.  (The paper collapses fusions into single HLO instructions after
    each pass, which makes such cycles visible structurally; with virtual
    groups we check reachability explicitly.)"""
    stack = [u for u in hlo.users if u not in fused]
    seen: Set[int] = set()
    while stack:
        n = stack.pop()
        if n.id in seen:
            continue
        seen.add(n.id)
        if any(u in fused for u in n.users):
            return True
        stack.extend(u for u in n.users if u not in fused)
    return False


def subgraph_fuse(
    seed: List[Instruction],
    module: Module,
    span: Dict[int, int],
    layer_map: Dict[int, List[Instruction]],
    roof: int,
    assigned: Set[int],
    cfg: FusionConfig,
) -> List[Instruction]:
    """Algorithm 1: fuse producers layer-by-layer from the seed up to roof."""
    fused: Set[Instruction] = set(seed)
    giveup: Set[Instruction] = set()
    roots = list(seed)
    curr_span = max(span[s.id] for s in seed)
    # The roof layer's NON-library ops are fusable (only the library call
    # itself is a boundary); constant-like producers get a final absorption
    # pass below, unbounded by roofs.
    for l in range(curr_span + 1, roof + 1):
        for hlo in layer_map.get(l, ()):
            if hlo.id in assigned or hlo in fused:
                continue
            if not fusable_member(hlo, cfg.fuse_dot):
                continue
            if len(fused) >= cfg.max_fusion_ops:
                return _topo_sorted(fused, module)
            # --- SchdConsistent (paper §3.2) -----------------------------
            if any(u in giveup for u in hlo.users):
                giveup.add(hlo)            # poisoned: avoid dependence loops
                continue
            if not any(u in fused for u in hlo.users):
                continue                   # producer/consumer fusion only
            if _would_cycle(hlo, fused):
                giveup.add(hlo)
                continue
            tentative = _topo_sorted(fused | {hlo}, module)
            if cfg.consistency(roots, tentative):
                fused.add(hlo)
            else:
                giveup.add(hlo)
    return _topo_sorted(fused, module)


def deep_fuse(module: Module, cfg: Optional[FusionConfig] = None) -> FusionPlan:
    """The full deep-fusion driver (paper §3.2)."""
    cfg = cfg or FusionConfig()
    span = span_lib.compute_spans(module)
    layer_map = span_lib.layers(module, span)
    max_span = max(span.values()) if span else 0
    lcs = span_lib.lc_spans(module, span)

    assigned: Set[int] = set()
    fusions: List[FusedComputation] = []
    forced_standalone: List[Instruction] = []

    for root_span in range(0, max_span + 1):
        layer = layer_map.get(root_span, [])
        roof = span_lib.roof_for(root_span, lcs, max_span)

        # -- step 1: intra-layer ElementwiseFusion ------------------------
        seeds: List[List[Instruction]] = _elementwise_groups(layer, assigned, cfg)
        claimed = {i.id for g in seeds for i in g}
        # -- step 2: every remaining fusable instruction seeds Algorithm 1
        for instr in layer:
            if instr.id in assigned or instr.id in claimed:
                continue
            if instr.opcode in ("parameter", "constant", "iota"):
                continue
            if constant_like(instr):
                continue  # folded at compile time; absorbed where consumed
            if not fusable_member(instr, cfg.fuse_dot):
                continue
            seeds.append([instr])

        for seed in seeds:
            if not cfg.consistency(seed, seed):
                # even the seed alone has no valid schedule — leave standalone
                for s in seed:
                    assigned.add(s.id)
                    forced_standalone.append(s)
                continue
            members = subgraph_fuse(
                seed, module, span, layer_map, roof, assigned, cfg
            )
            for m in members:
                assigned.add(m.id)
            fusions.append(FusedComputation(members, name=f"f{len(fusions)}"))

    # --- final pass: absorb constant-like producer chains (free ops) -----
    absorbed_fusions: List[FusedComputation] = []
    for f in fusions:
        members = set(f.members)
        stack = [o for m in f.members for o in m.operands]
        while stack:
            o = stack.pop()
            if o in members or o.id in assigned or o.opcode == "parameter":
                continue
            if constant_like(o):
                members.add(o)
                assigned.add(o.id)
                stack.extend(o.operands)
        absorbed_fusions.append(
            FusedComputation(_topo_sorted(members, module), name=f.name)
        )
    fusions = absorbed_fusions

    standalone = forced_standalone + [
        i
        for i in module.instructions
        if i.id not in assigned
        and i.opcode not in ("parameter", "constant")
        and not constant_like(i)
    ]
    # Drop trivial single-op "fusions" of free ops back to standalone
    real_fusions, extra = [], []
    for f in fusions:
        if len(f.members) == 1 and f.members[0].opcode in ("iota",):
            extra.append(f.members[0])
        else:
            real_fusions.append(f)
    return FusionPlan(real_fusions, standalone + extra, module)
