"""FusionStitching core: StitchIR, deep fusion, schedule planning, VMEM
memory planning, and IrEmitterStitched Pallas code generation."""
from .compiler import CompiledModule, CompileStats, StitchOptions, compile_module
from .executor import StitchedExecutable, reference_execute
from .fusion import FusedComputation, FusionConfig, FusionPlan, deep_fuse
from .ir import (
    GraphBuilder,
    Instruction,
    Module,
    Tensor,
    apply_op,
    trace,
)
from .memory import MemoryInfeasible, MemoryPlan, plan_memory
from .perf_library import CostModel, PerfLibrary, TPU_V5E, TpuSpec
from .schedule import (
    REPLICATED,
    Sched,
    ScheduleSolution,
    Unsatisfiable,
    blocks_of,
    candidate_schedules,
    chunk_shape,
    propagate,
    resolve_schedules,
)
from .span import compute_spans, critical_path_length, layers
from .tuning import TunedPlan, tune
from .xla_baseline import xla_baseline_groups, xla_baseline_kernel_count

__all__ = [
    "CompiledModule", "CompileStats", "StitchOptions", "compile_module",
    "StitchedExecutable", "reference_execute", "FusedComputation",
    "FusionConfig", "FusionPlan", "deep_fuse", "GraphBuilder", "Instruction",
    "Module", "Tensor", "apply_op", "trace", "MemoryInfeasible", "MemoryPlan",
    "plan_memory", "CostModel", "PerfLibrary", "TPU_V5E", "TpuSpec",
    "REPLICATED", "Sched", "ScheduleSolution", "Unsatisfiable", "blocks_of",
    "candidate_schedules", "chunk_shape", "propagate", "resolve_schedules",
    "compute_spans", "critical_path_length", "layers", "TunedPlan", "tune",
    "xla_baseline_groups", "xla_baseline_kernel_count",
]
