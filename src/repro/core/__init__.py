"""FusionStitching core: StitchIR, deep fusion, schedule planning, VMEM
memory planning, and IrEmitterStitched Pallas code generation — organized
as an explicit pass pipeline (``pipeline``) with fusion-signature kernel
deduplication (``signature``) and a planned buffer-table runtime
(``executor``)."""
from .compiler import CompiledModule, CompileStats, StitchOptions, compile_module
from .executor import ExecutionPlan, StitchedExecutable, reference_execute
from .measure import (
    MeasuredCost,
    MeasuredCostStore,
    device_fingerprint,
    emit_group,
    measure_callable,
    measure_group,
    measure_kernel,
)
from .pipeline import (
    AutotunePass,
    CodegenPass,
    CompilationState,
    FinalizePass,
    FusionPass,
    MemoryPass,
    PassPipeline,
    SchedulePass,
    default_pipeline,
)
from .signature import CacheEntry, KernelCache, fusion_signature
from .fusion import (
    FusedComputation,
    FusionConfig,
    FusionPlan,
    FusionScorer,
    PlannerStats,
    deep_fuse,
)
from .latency import DeviceSpec, LatencyModel, instr_flops
from .ir import (
    GraphBuilder,
    Instruction,
    Module,
    Tensor,
    apply_op,
    trace,
)
from .memory import (
    MemoryInfeasible,
    MemoryPlan,
    StitchedMemoryPlan,
    plan_memory,
    plan_stitched_memory,
)
from .perf_library import CostModel, PerfLibrary, TPU_V5E, TpuSpec
from .schedule import (
    CONSISTENT,
    INFEASIBLE,
    REPLICATED,
    STITCHABLE,
    Sched,
    ScheduleSolution,
    StitchedSolution,
    StitchVerdict,
    Unsatisfiable,
    blocks_of,
    candidate_schedules,
    chunk_shape,
    propagate,
    resolve_schedules,
    resolve_stitched,
    stitchable,
)
from .span import compute_spans, critical_path_length, layers
from .tuning import TunedPlan, tune
from .verify import (
    RULES,
    Diagnostic,
    VerificationError,
    resolve_verify_mode,
    verify_execution_plan,
    verify_module,
    verify_state,
)
from .xla_baseline import xla_baseline_groups, xla_baseline_kernel_count

__all__ = [
    "CompiledModule", "CompileStats", "StitchOptions", "compile_module",
    "StitchedExecutable", "ExecutionPlan", "reference_execute",
    "CompilationState", "PassPipeline", "default_pipeline", "FusionPass",
    "SchedulePass", "MemoryPass", "CodegenPass", "AutotunePass", "FinalizePass",
    "MeasuredCost", "MeasuredCostStore", "device_fingerprint",
    "measure_callable", "measure_kernel", "emit_group", "measure_group",
    "KernelCache", "CacheEntry", "fusion_signature", "FusedComputation",
    "FusionConfig", "FusionPlan", "FusionScorer", "PlannerStats", "deep_fuse",
    "DeviceSpec", "LatencyModel", "instr_flops", "GraphBuilder", "Instruction",
    "Module", "Tensor", "apply_op", "trace", "MemoryInfeasible", "MemoryPlan",
    "plan_memory", "StitchedMemoryPlan", "plan_stitched_memory",
    "CostModel", "PerfLibrary", "TPU_V5E", "TpuSpec",
    "REPLICATED", "Sched", "ScheduleSolution", "Unsatisfiable", "blocks_of",
    "CONSISTENT", "STITCHABLE", "INFEASIBLE", "StitchVerdict",
    "StitchedSolution", "resolve_stitched", "stitchable",
    "candidate_schedules", "chunk_shape", "propagate", "resolve_schedules",
    "compute_spans", "critical_path_length", "layers", "TunedPlan", "tune",
    "xla_baseline_groups", "xla_baseline_kernel_count",
    "Diagnostic", "VerificationError", "RULES", "resolve_verify_mode",
    "verify_module", "verify_state", "verify_execution_plan",
]
