"""Shard layouts: how a module's *local* (per-shard) tensors relate to the
global arrays of a multi-device run.

Shard-aware compilation traces the per-shard computation (the body of a
``shard_map``), so every instruction shape in the module is already the
LOCAL shape — fusion and the latency model score per-shard tiles with no
changes.  What the local shapes cannot express is *placement*: which global
dims are split over which mesh axes, and whether a value is a pending
partial sum (a contraction over a sharded dim that still needs an
``all_reduce``).  This module defines that annotation and propagates it.

A **layout** is a tuple with one entry per dim: ``None`` (not sharded) or a
tuple of mesh axis names the global dim is split over, e.g.
``(("model",), None)`` for a row-sharded matrix.  ``None`` in place of the
whole tuple means *unknown* — propagation lost track (an unmapped reshape),
which is distinct from replicated: unknown layouts are never stamped and
never validated against.

``propagate_layouts`` walks a module once, derives a layout for every
instruction from the parameter layouts, stamps non-trivial results into
``instr.attrs["shard"]`` (and pending partial-sum axes into
``attrs["partial"]``), and validates collectives against the mesh.  The
stamped attrs flow into ``fusion_signature``/``module_signature`` through
``_canon_attrs``, so the kernel cache can never alias a per-shard kernel
with a full-shape one.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .ir import COLLECTIVE_OPCODES, Module

#: one entry per dim: None (unsharded) or a tuple of mesh axis names
Layout = Tuple[Optional[Tuple[str, ...]], ...]


def spec_to_layout(spec, rank: int) -> Layout:
    """PartitionSpec (or any per-dim sequence) -> canonical layout tuple."""
    entries = tuple(spec) if spec is not None else ()
    out: List[Optional[Tuple[str, ...]]] = []
    for i in range(rank):
        e = entries[i] if i < len(entries) else None
        if e is None:
            out.append(None)
        elif isinstance(e, str):
            out.append((e,))
        else:
            out.append(tuple(e) or None)
    return tuple(out)


def names_to_layout(names: Dict[int, Sequence[str]], rank: int) -> Layout:
    """shard_map ``in_names``/``out_names`` dict ({dim: axis names}) -> layout."""
    return tuple(
        tuple(names[d]) if d in names and names[d] else None for d in range(rank)
    )


def layout_to_pspec(layout: Optional[Layout]):
    """Layout -> PartitionSpec for the executor's shard_map replay."""
    from jax.sharding import PartitionSpec as P

    if layout is None:
        return P()
    entries: List = []
    for e in layout:
        if not e:
            entries.append(None)
        elif len(e) == 1:
            entries.append(e[0])
        else:
            entries.append(tuple(e))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def wrap_shard_map(fn, mesh, in_specs, out_specs):
    """``shard_map`` across the installed JAX's API drift: new releases
    expose ``jax.shard_map`` with ``check_vma``, older ones the experimental
    module with ``check_rep``.  Checking is always off — sharded plans carry
    deliberate partial-sum values between kernels and their collectives."""
    import jax

    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = "check_vma"
    else:
        from jax.experimental.shard_map import shard_map as sm
        kw = "check_rep"
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{kw: False})


def mesh_axes_of(mesh) -> Tuple[Tuple[str, int], ...]:
    """Hashable (name, size) description of a Mesh — what salts the kernel
    cache and the measured-cost store (the Mesh object itself never enters a
    fingerprint)."""
    return tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names)


def is_trivial_layout(layout: Optional[Layout]) -> bool:
    return layout is None or all(e is None for e in layout)


def _merge(a, b, where: str):
    """Dim-wise merge of two operand layouts (same local shape)."""
    if a is None or b is None:
        return None
    if len(a) != len(b):
        return None
    out = []
    for da, db in zip(a, b, strict=False):
        if da is None or db is None:
            # replicated op sharded: the sharded interpretation wins (a
            # replicated operand holds the same slice-compatible values on
            # every shard along that dim's axes)
            out.append(da or db)
        elif da != db:
            raise ValueError(
                f"shard layout conflict at {where}: dim sharded over {da} "
                f"on one operand and {db} on another"
            )
        else:
            out.append(da)
    return tuple(out)


def derive_layouts(
    module: Module,
    mesh_axes: Sequence[Tuple[str, int]],
    param_layouts: Optional[Dict[str, Layout]] = None,
) -> Tuple[Dict[int, Optional[Layout]], Dict[int, frozenset], Dict[str, int]]:
    """Derive (without stamping) a shard layout for every instruction.

    The pure half of ``propagate_layouts``: walks the module once and
    returns ``(layouts, partial, counters)`` — instruction id to layout
    (None = unknown), instruction id to pending partial-sum axes (only ids
    with a non-empty set appear), and the ``CompileStats`` counters.  The
    verifier calls this directly so it can compare a fresh derivation
    against the stamped attrs without mutating anything.  Raises
    ``ValueError`` on layout conflicts, collectives over axes the mesh does
    not have, or group sizes that disagree with the mesh.
    """
    axis_size = {name: int(size) for name, size in mesh_axes}
    param_layouts = param_layouts or {}
    layouts: Dict[int, Optional[Layout]] = {}
    partial: Dict[int, frozenset] = {}
    replicated_cache: Dict[int, Layout] = {}

    def _replicated(rank: int) -> Layout:
        if rank not in replicated_cache:
            replicated_cache[rank] = tuple([None] * rank)
        return replicated_cache[rank]

    def _group_size(axes: Tuple[str, ...]) -> int:
        g = 1
        for a in axes:
            g *= axis_size[a]
        return g

    n_sharded = n_collectives = 0
    for instr in module.instructions:
        op = instr.opcode
        ops = instr.operands
        in_partial = frozenset().union(*(partial.get(o.id, frozenset()) for o in ops)) if ops else frozenset()
        lay: Optional[Layout]

        if op in COLLECTIVE_OPCODES:
            n_collectives += 1
            axes = tuple(instr.attrs["axes"])
            for a in axes:
                if a not in axis_size:
                    raise ValueError(
                        f"{instr.name}: collective over axis {a!r} but the "
                        f"mesh has axes {sorted(axis_size)}"
                    )
            src = layouts.get(ops[0].id)
            if op == "all_reduce":
                lay = src
                in_partial = in_partial - set(axes)
            elif op == "all_gather":
                if int(instr.attrs["group_size"]) != _group_size(axes):
                    raise ValueError(
                        f"{instr.name}: group_size "
                        f"{instr.attrs['group_size']} != mesh size "
                        f"{_group_size(axes)} of axes {axes}"
                    )
                if src is None:
                    lay = None
                else:
                    d = instr.attrs["dim"]
                    e = src[d]
                    gathered = tuple(a for a in (e or ()) if a not in axes) or None
                    lay = src[:d] + (gathered,) + src[d + 1:]
            else:  # reduce_scatter
                if int(instr.attrs["group_size"]) != _group_size(axes):
                    raise ValueError(
                        f"{instr.name}: group_size "
                        f"{instr.attrs['group_size']} != mesh size "
                        f"{_group_size(axes)} of axes {axes}"
                    )
                in_partial = in_partial - set(axes)
                if src is None:
                    lay = None
                else:
                    d = instr.attrs["dim"]
                    e = tuple((src[d] or ())) + axes
                    lay = src[:d] + (e,) + src[d + 1:]
        elif op == "parameter":
            lay = param_layouts.get(instr.name, _replicated(instr.ndim))
        elif op in ("constant", "iota"):
            lay = _replicated(instr.ndim)
        elif op in ("elementwise", "select"):
            lay = _replicated(instr.ndim)
            for o in ops:
                lay = _merge(lay, layouts.get(o.id), instr.name)
        elif op in ("reshape", "bitcast"):
            src = layouts.get(ops[0].id)
            if src is not None and is_trivial_layout(src):
                lay = _replicated(instr.ndim)
            elif src is not None and len(src) == instr.ndim and tuple(
                ops[0].shape
            ) == tuple(instr.shape):
                lay = src
            else:
                lay = None  # unmapped reshape of a sharded value: unknown
        elif op == "transpose":
            src = layouts.get(ops[0].id)
            perm = instr.attrs["perm"]
            lay = None if src is None else tuple(src[p] for p in perm)
        elif op == "broadcast":
            src = layouts.get(ops[0].id)
            if src is None:
                lay = None
            else:
                out: List[Optional[Tuple[str, ...]]] = [None] * instr.ndim
                for i, d in enumerate(instr.attrs["dims"]):
                    out[d] = src[i]
                lay = tuple(out)
        elif op == "reduce":
            src = layouts.get(ops[0].id)
            dims = set(instr.attrs["dims"])
            if src is None:
                lay = None
            else:
                lay = tuple(e for i, e in enumerate(src) if i not in dims)
                reduced_axes = set()
                for i in dims:
                    reduced_axes.update(src[i] or ())
                if reduced_axes:
                    # each shard reduced only its local slice: partial sum
                    in_partial = in_partial | reduced_axes
        elif op == "dot":
            lhs, rhs = layouts.get(ops[0].id), layouts.get(ops[1].id)
            if lhs is None or rhs is None:
                lay = None
            else:
                batch = _merge(lhs[:-2], rhs[:-2], instr.name)
                lay = (
                    None
                    if batch is None
                    else batch + (lhs[-2], rhs[-1])
                )
                contracted = set(lhs[-1] or ()) | set(rhs[-2] or ())
                if contracted:
                    in_partial = in_partial | contracted
        elif op == "concat":
            lay = _replicated(instr.ndim)
            d = instr.attrs["dim"]
            for o in ops:
                lay = _merge(lay, layouts.get(o.id), instr.name)
                if lay is None:
                    break
            if lay is not None and lay[d] is not None:
                lay = None  # concat along a sharded dim: unknown
        elif op == "gather":
            t, idx = layouts.get(ops[0].id), layouts.get(ops[1].id)
            lay = None if t is None or idx is None else idx + t[1:]
        else:  # call/get and anything future: layout tracking stops
            lay = None

        layouts[instr.id] = lay
        if in_partial:
            partial[instr.id] = in_partial
        if lay is not None and not is_trivial_layout(lay):
            n_sharded += 1

    counters = {"sharded_instrs": n_sharded, "collective_ops": n_collectives}
    return layouts, partial, counters


def propagate_layouts(
    module: Module,
    mesh_axes: Sequence[Tuple[str, int]],
    param_layouts: Optional[Dict[str, Layout]] = None,
) -> Dict[str, int]:
    """Derive and stamp a shard layout for every instruction.

    ``mesh_axes`` is the (name, size) tuple the plan will run on;
    ``param_layouts`` maps parameter names to layouts (missing = replicated).
    Stamps ``attrs["shard"]`` only when the layout is known and non-trivial
    (unsharded compiles stay byte-identical in every signature), and
    ``attrs["partial"]`` with the mesh axes a value is a pending partial sum
    over; stale stamps from an earlier propagation are cleared, so the attrs
    always mirror THIS derivation (the verifier re-derives and compares).
    Raises ``ValueError`` on layout conflicts, collectives over axes the
    mesh does not have, or group sizes that disagree with the mesh.
    Returns counters for ``CompileStats``.
    """
    layouts, partial, counters = derive_layouts(module, mesh_axes, param_layouts)
    for instr in module.instructions:
        in_partial = partial.get(instr.id)
        if in_partial:
            instr.attrs["partial"] = tuple(sorted(in_partial))
        else:
            instr.attrs.pop("partial", None)
        lay = layouts.get(instr.id)
        if lay is not None and not is_trivial_layout(lay):
            instr.attrs["shard"] = lay
        else:
            instr.attrs.pop("shard", None)
    return counters
