"""Work/Span (critical-path) analysis — paper §3.1.

Each instruction gets a *span*: the root (sink) instructions have span 0 and
any other instruction's span is ``max(span of users) + 1``.  Instructions
sharing a span form a *layer* with no data dependences among them.  The
maximum span is the critical-path length.

Library-call instructions (un-fusable dots — the cuBLAS analogue; on TPU the
XLA-native MXU ``dot_general``) partition the module into segments; fusion
never crosses an LC-layer (§3.2).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List

from .ir import Instruction, Module


def compute_spans(module: Module) -> Dict[int, int]:
    """span[root] = 0; span[i] = max(span(users)) + 1. Reverse-topo pass."""
    span: Dict[int, int] = {}
    for instr in reversed(module.instructions):
        if not instr.users:
            span[instr.id] = 0
        else:
            span[instr.id] = max(span[u.id] for u in instr.users) + 1
    return span


def layers(module: Module, span: Dict[int, int]) -> Dict[int, List[Instruction]]:
    out: Dict[int, List[Instruction]] = defaultdict(list)
    for instr in module.instructions:
        out[span[instr.id]].append(instr)
    return dict(out)


def critical_path_length(module: Module) -> int:
    span = compute_spans(module)
    return max(span.values()) if span else 0


def work(module: Module) -> int:
    """Total work = number of non-parameter/constant instructions."""
    return sum(
        1 for i in module.instructions if i.opcode not in ("parameter", "constant")
    )


def lc_spans(module: Module, span: Dict[int, int]) -> List[int]:
    """Sorted spans that contain at least one library-call instruction."""
    out = sorted({span[i.id] for i in module.instructions if i.is_library_call})
    return out


def roof_for(root_span: int, lcs: List[int], max_span: int) -> int:
    """The next LC-layer strictly above ``root_span`` (or one past the top).

    Algorithm 1 walks layers in ``(root_span, roof)`` — it never fuses an
    instruction on or above the roof.
    """
    for s in lcs:
        if s > root_span:
            return s
    return max_span + 1


def validate_spans(module: Module, span: Dict[int, int]) -> None:
    """Invariant used by property tests: every operand is strictly deeper
    than each of its users, and same-layer nodes are independent."""
    for instr in module.instructions:
        for op in instr.operands:
            if span[op.id] <= span[instr.id]:
                raise AssertionError(
                    f"span({op.name})={span[op.id]} must exceed "
                    f"span({instr.name})={span[instr.id]}"
                )
