"""The unified analytic latency model — ONE place for device constants and
roofline math.

Before this module, cost knowledge was split three ways and drifted
independently: ``core/perf_library.py`` carried a ``TpuSpec`` + per-op
roofline miss handler, ``launch/roofline.py`` re-declared the same peak
FLOPs / HBM / ICI numbers as module constants, and ``launch/costmodel.py``
walked jaxprs with its own byte conventions.  ``DeviceSpec`` is now the
single source of truth for hardware constants (both older sites re-export
it) and ``LatencyModel`` is the one scoring object shared by the fusion
planner, the schedule tuner (through ``PerfLibrary.model``), and the
module-level roofline table.

What the model charges (see README "LatencyModel conventions"):
  * one ``launch_overhead_s`` per kernel plus ``grid_step_overhead_s`` per
    grid program;
  * compute at roofline peak — MXU peak for dots (bf16 vs f32 by dtype),
    VPU-weighted flops for elementwise (``_EW_WEIGHT``) — derated by a
    lane-efficiency penalty when the chunk underfills the (8,128) tile;
  * HBM traffic for kernel inputs and root outputs; a replicated operand
    in a multi-block kernel is re-read per block;
  * VMEM traffic for buffered interior values (reduce / fusable-dot
    results — the same set ``memory.plan_memory`` marks required);
  * replication duplication: a replicated member of a multi-block kernel
    recomputes in every block.

What it approximates:
  * perfect overlap of compute and HBM DMA inside one kernel
    (``max(compute, memory)``, not the sum);
  * non-buffered interior elementwise values are free (thread
    composition re-computes them in registers);
  * no cross-block caching and no occupancy modeling — one TensorCore.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, fields
from typing import Sequence, Tuple

import numpy as np

from .ir import Instruction
from .schedule import (
    REPLICATED,
    Sched,
    ScheduleSolution,
    StitchedSolution,
    blocks_of,
    chunk_shape,
)


@dataclass(frozen=True)
class DeviceSpec:
    """TPU v5e per-chip numbers — the single source of hardware truth.

    ``core/perf_library.py`` re-exports this as ``TpuSpec`` and
    ``launch/roofline.py`` derives its module constants from ``TPU_V5E``;
    neither keeps its own copy anymore.
    """

    peak_flops_bf16: float = 197e12
    peak_flops_f32: float = 98.5e12          # MXU fp32 ~ half bf16
    vpu_flops: float = 3.9e12                # 8x128x8 VPU lanes @ ~0.94 GHz x2
    hbm_bw: float = 819e9
    vmem_bw: float = 3.3e12                  # on-chip scratch, ~4x HBM
    vmem_bytes: int = 16 * 1024 * 1024
    ici_bw: float = 50e9                     # per link
    ici_latency_s: float = 1.0e-6            # per-collective hop/sync latency
    launch_overhead_s: float = 2.0e-6        # kernel dispatch
    grid_step_overhead_s: float = 1.0e-7     # per grid program (pipelined)
    phase_loop_overhead_s: float = 5.0e-7    # per stitched-phase transition
    sublane: int = 8
    lane: int = 128

    def fingerprint(self) -> str:
        """Content hash of the hardware constants.  A measured kernel time is
        only meaningful relative to the device it was taken on, so the
        measured-cost tuning store (``core/measure.py``) keys every record by
        this fingerprint (combined with the runtime backend): a store carried
        to a different device spec degrades to all-misses — the analytic
        model — instead of replaying another chip's timings."""
        feats = tuple((f.name, getattr(self, f.name)) for f in fields(self))
        return hashlib.sha256(repr(feats).encode()).hexdigest()[:16]


TPU_V5E = DeviceSpec()

# VPU op weight: how many vector-op equivalents one element costs.
_EW_WEIGHT = {"add": 1, "sub": 1, "mul": 1, "max": 1, "min": 1, "neg": 1,
              "abs": 1, "sign": 1, "floor": 1, "not": 1, "and": 1, "or": 1,
              "lt": 1, "le": 1, "gt": 1, "ge": 1, "eq": 1, "ne": 1,
              "square": 1, "reciprocal": 4, "div": 4, "sqrt": 4, "rsqrt": 4,
              "exp": 8, "log": 8, "tanh": 12, "sigmoid": 10, "softplus": 12,
              "silu": 12, "gelu": 14, "pow": 16}

# Computationally trivial ops: inlined via thread composition during both
# schedule scoring (tuning.py) and planner scoring — charging them would
# veto good schedules (paper §4.3 optimization).
TRIVIAL_OPCODES = frozenset({"reshape", "bitcast", "broadcast", "constant", "iota"})
_SMALL_TRANSPOSE_ELEMS = 4096


def is_trivial(instr: Instruction) -> bool:
    if instr.opcode in TRIVIAL_OPCODES:
        return True
    if instr.opcode == "transpose" and instr.num_elements <= _SMALL_TRANSPOSE_ELEMS:
        return True
    return False


def instr_flops(instr: Instruction) -> float:
    """Model FLOPs of one instruction (elementwise weighted for the VPU)."""
    op = instr.opcode
    if op == "elementwise":
        w = _EW_WEIGHT.get(instr.attrs.get("fn"), 1)
        return instr.num_elements * w
    if op == "select":
        return instr.num_elements
    if op == "reduce":
        return instr.operands[0].num_elements
    if op == "dot":
        lhs = instr.operands[0]
        k = lhs.shape[-1]
        return 2.0 * instr.num_elements * k
    return 0.0  # shape modulation / data movement only


def instr_hbm_bytes(instr: Instruction) -> float:
    """HBM traffic of one instruction run standalone: read every operand
    once, write the output once."""
    return float(instr.bytesize) + sum(float(o.bytesize) for o in instr.operands)


def _lane_efficiency(chunk: Tuple[int, ...], spec: DeviceSpec) -> float:
    """Penalty for chunks that underfill the (8,128) VPU tile — the TPU
    analogue of the paper's warp-multiple thread-block constraint."""
    if not chunk:
        return 1.0
    lane = chunk[-1]
    sub = chunk[-2] if len(chunk) >= 2 else 1
    eff_l = min(1.0, lane / spec.lane) if lane < spec.lane else 1.0
    eff_s = min(1.0, sub / spec.sublane) if sub < spec.sublane else 1.0
    return max(0.05, eff_l * eff_s)


class LatencyModel:
    """Device spec + per-op / per-fusion / per-module time estimates.

    One instance is shared across the whole compile: the fusion planner
    scores candidate partitions, ``PerfLibrary`` uses ``op_time`` as its
    miss handler, ``tuning.score`` finishes with ``kernel_time``, and
    ``launch/roofline.py`` builds its table from the ``*_time`` roofline
    terms — all against the same ``DeviceSpec``.
    """

    def __init__(self, spec: DeviceSpec = TPU_V5E):
        self.spec = spec

    # ---- per-op (the PerfLibrary miss handler, paper §4.4) ---------------
    def peak_for(self, instr: Instruction) -> float:
        if instr.opcode == "dot":
            return (
                self.spec.peak_flops_bf16
                if np.dtype(instr.dtype).itemsize <= 2
                else self.spec.peak_flops_f32
            )
        return self.spec.vpu_flops

    def op_time(self, instr: Instruction, sched: Sched, launch_blocks: int) -> float:
        """Time for ONE op under ``sched`` inside a kernel with
        ``launch_blocks`` grid steps (seconds)."""
        spec = self.spec
        chunk = chunk_shape(instr.shape, sched)
        replicated = sched.kind == "replicated"
        copies = launch_blocks if replicated else 1
        elems = int(np.prod(chunk, dtype=np.int64)) if chunk else 1
        itemsize = np.dtype(instr.dtype).itemsize
        total_elems = elems * (launch_blocks if not replicated else copies)
        # bytes: write output once per copy + read operands
        bytes_moved = total_elems * itemsize
        for o in instr.operands:
            o_elems = o.num_elements if replicated else o.num_elements / max(
                1, blocks_of(o.shape, sched) if sched.kind == "chunked" else 1
            )
            bytes_moved += o_elems * np.dtype(o.dtype).itemsize * copies
        flops = instr_flops(instr) * (copies if replicated else 1)
        eff = _lane_efficiency(chunk, spec)
        t_compute = flops / (self.peak_for(instr) * eff)
        t_memory = bytes_moved / (spec.hbm_bw * eff)
        return max(t_compute, t_memory)

    def kernel_time(self, num_blocks: int, op_times_sum: float) -> float:
        return (
            self.spec.launch_overhead_s
            + num_blocks * self.spec.grid_step_overhead_s
            + op_times_sum
        )

    # ---- per-kernel estimates (the fusion planner's currency) ------------
    def standalone_time(self, instr: Instruction) -> float:
        """One unfused kernel launch computing ``instr`` whole."""
        if instr.opcode in ("parameter", "constant"):
            return 0.0
        body = 0.0
        if not is_trivial(instr):
            body = max(
                instr_flops(instr) / self.peak_for(instr),
                instr_hbm_bytes(instr) / self.spec.hbm_bw,
            )
        else:
            body = instr_hbm_bytes(instr) / self.spec.hbm_bw
        return (
            self.spec.launch_overhead_s + self.spec.grid_step_overhead_s + body
        )

    def fusion_time(
        self,
        members: Sequence[Instruction],
        roots: Sequence[Instruction],
        solution: ScheduleSolution,
    ) -> float:
        """One stitched kernel running ``members`` under ``solution``.

        Charges launch + grid steps, max(compute, HBM) for the body, VMEM
        traffic for buffered interior values, and replication duplication
        (see module docstring for the full convention list).
        """
        spec = self.spec
        blocks = max(1, solution.blocks)
        member_ids = {m.id for m in members}
        root_ids = {r.id for r in roots}
        compute_s = 0.0
        hbm_bytes = 0.0
        vmem_bytes = 0.0
        seen_inputs = set()
        for m in members:
            sched = solution.assignment.get(m.id, REPLICATED)
            dup = blocks if (blocks > 1 and sched.kind == "replicated") else 1
            if not is_trivial(m):
                eff = _lane_efficiency(chunk_shape(m.shape, sched), spec)
                compute_s += dup * instr_flops(m) / (self.peak_for(m) * eff)
            for o in m.operands:
                if o.id in member_ids or o.id in seen_inputs:
                    continue
                seen_inputs.add(o.id)
                osched = solution.assignment.get(o.id, REPLICATED)
                copies = blocks if (blocks > 1 and osched.kind == "replicated") else 1
                hbm_bytes += copies * o.bytesize
            if m.id in root_ids:
                hbm_bytes += m.bytesize
            elif m.opcode in ("reduce", "dot") and any(
                u.id in member_ids for u in m.users
            ):
                # interior values memory.plan_memory marks as required
                # buffers: they round-trip through VMEM scratch
                vmem_bytes += dup * m.bytesize
        body = max(compute_s, hbm_bytes / spec.hbm_bw) + vmem_bytes / spec.vmem_bw
        return (
            spec.launch_overhead_s
            + blocks * spec.grid_step_overhead_s
            + body
        )

    def stitched_fusion_time(self, stitched: StitchedSolution) -> float:
        """ONE multi-phase stitched kernel (schedule.resolve_stitched).

        Charges a single launch, then per phase: the phase body (same terms
        as ``fusion_time``), the phase's sequential grid-loop steps, and a
        ``phase_loop_overhead_s`` transition.  Interface tensors are charged
        a full write + read round trip through VMEM — the staging traffic
        that replaces an HBM round trip plus a kernel launch under a split.
        Phases are sequential: no overlap is assumed across them.
        """
        spec = self.spec
        group_ids = {m.id for p in stitched.phases for m in p.members}
        total = spec.launch_overhead_s
        seen_inputs = set()
        for p in stitched.phases:
            blocks = max(1, p.solution.blocks)
            phase_ids = {m.id for m in p.members}
            compute_s = 0.0
            hbm_bytes = 0.0
            vmem_bytes = 0.0
            for m in p.members:
                sched = p.solution.assignment.get(m.id, REPLICATED)
                dup = blocks if (blocks > 1 and sched.kind == "replicated") else 1
                if not is_trivial(m):
                    eff = _lane_efficiency(chunk_shape(m.shape, sched), spec)
                    compute_s += dup * instr_flops(m) / (self.peak_for(m) * eff)
                for o in m.operands:
                    if o.id in group_ids or o.id in seen_inputs:
                        continue   # phase-local, staged, or already-read input
                    seen_inputs.add(o.id)
                    # stitched kernels read every input exactly ONCE as a
                    # whole-tensor block (grid is trivial); unlike
                    # fusion_time there is no per-block re-read to charge
                    hbm_bytes += o.bytesize
                if not m.users or any(u.id not in group_ids for u in m.users):
                    hbm_bytes += m.bytesize          # kernel output
                elif m.opcode in ("reduce", "dot") and any(
                    u.id in phase_ids for u in m.users
                ):
                    vmem_bytes += dup * m.bytesize   # phase-interior buffer
            total += (
                max(compute_s, hbm_bytes / spec.hbm_bw)
                + vmem_bytes / spec.vmem_bw
                + blocks * spec.grid_step_overhead_s
                + spec.phase_loop_overhead_s
            )
        # interface staging: one full write by the producer phase, one full
        # re-tiled read by the consumer phase, both through VMEM
        total += 2.0 * stitched.interface_bytes / spec.vmem_bw
        return total

    # ---- module-level roofline terms (launch/roofline.py) ----------------
    def compute_time(self, flops: float, chips: int = 1) -> float:
        return flops / (chips * self.spec.peak_flops_bf16)

    def memory_time(self, nbytes: float, chips: int = 1) -> float:
        return nbytes / (chips * self.spec.hbm_bw)

    def collective_time(self, nbytes: float, chips: int = 1) -> float:
        return nbytes / (chips * self.spec.ici_bw)

    # ---- per-collective-op time (shard-aware plans) ----------------------
    def collective_op_time(self, instr: Instruction, group_size: int) -> float:
        """One collective instruction over a ``group_size``-device axis
        group.  Ring algorithms move ``2*(n-1)/n`` of the payload per device
        for all-reduce and ``(n-1)/n`` for all-gather/reduce-scatter, plus a
        fixed per-collective sync latency.  This is what a collective costs
        the plan — it is a schedule break, never a kernel launch."""
        n = max(1, int(group_size))
        payload = float(instr.bytesize)
        if instr.opcode == "all_reduce":
            wire = 2.0 * (n - 1) / n * payload
        else:  # all_gather / reduce_scatter: payload is the larger tensor
            big = max(payload, float(instr.operands[0].bytesize))
            wire = (n - 1) / n * big
        return self.spec.ici_latency_s + wire / self.spec.ici_bw
