"""Performance library — paper §4.4, adapted for TPU.

The paper keeps a persistent key-value store mapping
``(opcode, shape, split_dim, sword, sched_type, block size, ...)`` to
measured kernel microseconds; on a miss it compiles a CUDA micro-kernel and
``nvprof``s it.  This container has no TPU to profile, so we keep the
**storage and lookup protocol intact** (persistent JSON KV with the same key
features) but replace the miss handler with the shared analytic
``LatencyModel`` (``core/latency.py``) — the substitution the paper itself
anticipates in §4.4 ("build a learning model to predict a performance metric
from features in the key").  On real hardware the miss handler would compile
the schedule into a Pallas micro-kernel and time it; the interface is
identical.

The hardware constants and roofline math used to live here; they moved to
``core/latency.py`` so the fusion planner, the tuner, and the launch-time
roofline table score against ONE device spec.  ``TpuSpec`` and ``CostModel``
remain as aliases for existing callers.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Dict, Optional

import numpy as np

from .ir import Instruction
from .latency import (  # noqa: F401 — compatibility re-exports
    TPU_V5E,
    DeviceSpec,
    LatencyModel,
    instr_flops,
)
from .schedule import Sched

# Backwards-compatible names: the device spec and the per-op roofline model
# are now defined once in core/latency.py.
TpuSpec = DeviceSpec
CostModel = LatencyModel


class JsonStore:
    """Tiny persistent JSON KV store with atomic save — the paper's §4.4
    storage protocol, shared by PerfLibrary and the kernel cache."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._store: Dict[str, object] = {}
        self._lock = threading.Lock()
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    self._store = json.load(f)
            except (json.JSONDecodeError, OSError):
                self._store = {}

    def get(self, key: str, default=None):
        with self._lock:
            return self._store.get(key, default)

    def put(self, key: str, value) -> None:
        with self._lock:
            self._store[key] = value

    def pop(self, key: str, default=None):
        with self._lock:
            return self._store.pop(key, default)

    def save(self) -> None:
        """Atomically persist the store.

        The payload is fully written (and fsync'd) to a *uniquely named*
        temp file in the target directory, then ``os.replace``d over the
        destination.  A crash mid-write — or a concurrent saver from another
        process — can therefore never leave a truncated or interleaved JSON
        file at ``self.path``: readers see either the old complete store or
        the new complete store.  (A fixed ``path + ".tmp"`` scratch name is
        NOT safe: two processes would interleave writes into the same temp
        file and then replace the real store with the torn result.)
        """
        if not self.path:
            return
        directory = os.path.dirname(os.path.abspath(self.path)) or "."
        fd, tmp = tempfile.mkstemp(
            prefix=os.path.basename(self.path) + ".", suffix=".tmp",
            dir=directory,
        )
        try:
            with self._lock:
                with os.fdopen(fd, "w") as f:
                    json.dump(self._store, f)
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            # the destination is untouched; drop our scratch file
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._store

    def __len__(self):
        return len(self._store)


class PerfLibrary(JsonStore):
    """Persistent KV store of per-op schedule timings (paper §4.4)."""

    def __init__(self, path: Optional[str] = None, model: Optional[LatencyModel] = None):
        super().__init__(path)
        self.model = model or LatencyModel()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(instr: Instruction, sched: Sched, launch_blocks: int) -> str:
        feats = (
            instr.opcode,
            instr.attrs.get("fn", instr.attrs.get("kind", "")),
            tuple(instr.shape),
            str(np.dtype(instr.dtype)),
            sched.kind,
            sched.split_dim,
            sched.sword,
            sched.sched_type,
            launch_blocks,
        )
        return repr(feats)

    def lookup(self, instr: Instruction, sched: Sched, launch_blocks: int) -> float:
        k = self.key(instr, sched, launch_blocks)
        with self._lock:
            if k in self._store:
                self.hits += 1
                return self._store[k]
        t = self.model.op_time(instr, sched, launch_blocks)
        with self._lock:
            self.misses += 1
            self._store[k] = t
        return t
