"""Performance library — paper §4.4, adapted for TPU.

The paper keeps a persistent key-value store mapping
``(opcode, shape, split_dim, sword, sched_type, block size, ...)`` to
measured kernel microseconds; on a miss it compiles a CUDA micro-kernel and
``nvprof``s it.  This container has no TPU to profile, so we keep the
**storage and lookup protocol intact** (persistent JSON KV with the same key
features) but replace the miss handler with an **analytic TPU v5e roofline
model** — the substitution the paper itself anticipates in §4.4 ("build a
learning model to predict a performance metric from features in the key").
On real hardware the miss handler would compile the schedule into a Pallas
micro-kernel and time it; the interface is identical.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from .ir import Instruction, EXPENSIVE_ELEMENTWISE
from .schedule import Sched, chunk_shape, blocks_of


@dataclass(frozen=True)
class TpuSpec:
    """TPU v5e per-chip numbers (the assignment's hardware constants)."""

    peak_flops_bf16: float = 197e12
    peak_flops_f32: float = 98.5e12          # MXU fp32 ~ half bf16
    vpu_flops: float = 3.9e12                # 8x128x8 VPU lanes @ ~0.94 GHz x2
    hbm_bw: float = 819e9
    vmem_bytes: int = 16 * 1024 * 1024
    ici_bw: float = 50e9                     # per link
    launch_overhead_s: float = 2.0e-6        # kernel dispatch
    grid_step_overhead_s: float = 1.0e-7     # per grid program (pipelined)
    sublane: int = 8
    lane: int = 128


TPU_V5E = TpuSpec()

# VPU op weight: how many vector-op equivalents one element costs.
_EW_WEIGHT = {"add": 1, "sub": 1, "mul": 1, "max": 1, "min": 1, "neg": 1,
              "abs": 1, "sign": 1, "floor": 1, "not": 1, "and": 1, "or": 1,
              "lt": 1, "le": 1, "gt": 1, "ge": 1, "eq": 1, "ne": 1,
              "square": 1, "reciprocal": 4, "div": 4, "sqrt": 4, "rsqrt": 4,
              "exp": 8, "log": 8, "tanh": 12, "sigmoid": 10, "softplus": 12,
              "silu": 12, "gelu": 14, "pow": 16}


def instr_flops(instr: Instruction) -> float:
    """Model FLOPs of one instruction (elementwise weighted for the VPU)."""
    op = instr.opcode
    if op == "elementwise":
        w = _EW_WEIGHT.get(instr.attrs.get("fn"), 1)
        return instr.num_elements * w
    if op == "select":
        return instr.num_elements
    if op == "reduce":
        return instr.operands[0].num_elements
    if op == "dot":
        lhs = instr.operands[0]
        k = lhs.shape[-1]
        return 2.0 * instr.num_elements * k
    return 0.0  # shape modulation / data movement only


def _lane_efficiency(chunk: Tuple[int, ...], spec: TpuSpec) -> float:
    """Penalty for chunks that underfill the (8,128) VPU tile — the TPU
    analogue of the paper's warp-multiple thread-block constraint."""
    if not chunk:
        return 1.0
    lane = chunk[-1]
    sub = chunk[-2] if len(chunk) >= 2 else 1
    eff_l = min(1.0, lane / spec.lane) if lane < spec.lane else 1.0
    eff_s = min(1.0, sub / spec.sublane) if sub < spec.sublane else 1.0
    return max(0.05, eff_l * eff_s)


class CostModel:
    """Analytic roofline miss-handler (the TPU stand-in for nvprof)."""

    def __init__(self, spec: TpuSpec = TPU_V5E):
        self.spec = spec

    def op_time(self, instr: Instruction, sched: Sched, launch_blocks: int) -> float:
        """Time for ONE op under ``sched`` inside a kernel with
        ``launch_blocks`` grid steps (seconds)."""
        spec = self.spec
        chunk = chunk_shape(instr.shape, sched)
        replicated = sched.kind == "replicated"
        copies = launch_blocks if replicated else 1
        elems = int(np.prod(chunk, dtype=np.int64)) if chunk else 1
        itemsize = np.dtype(instr.dtype).itemsize
        total_elems = elems * (launch_blocks if not replicated else copies)
        # bytes: write output once per copy + read operands
        bytes_moved = total_elems * itemsize
        for o in instr.operands:
            o_elems = o.num_elements if replicated else o.num_elements / max(
                1, blocks_of(o.shape, sched) if sched.kind == "chunked" else 1
            )
            bytes_moved += o_elems * np.dtype(o.dtype).itemsize * copies
        flops = instr_flops(instr) * (copies if replicated else 1)
        if instr.opcode == "dot":
            peak = (
                spec.peak_flops_bf16
                if np.dtype(instr.dtype).itemsize <= 2
                else spec.peak_flops_f32
            )
        else:
            peak = spec.vpu_flops
        eff = _lane_efficiency(chunk, spec)
        t_compute = flops / (peak * eff)
        t_memory = bytes_moved / (spec.hbm_bw * eff)
        return max(t_compute, t_memory)

    def kernel_time(self, num_blocks: int, op_times_sum: float) -> float:
        return (
            self.spec.launch_overhead_s
            + num_blocks * self.spec.grid_step_overhead_s
            + op_times_sum
        )


class JsonStore:
    """Tiny persistent JSON KV store with atomic save — the paper's §4.4
    storage protocol, shared by PerfLibrary and the kernel cache."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._store: Dict[str, object] = {}
        self._lock = threading.Lock()
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    self._store = json.load(f)
            except (json.JSONDecodeError, OSError):
                self._store = {}

    def get(self, key: str, default=None):
        with self._lock:
            return self._store.get(key, default)

    def put(self, key: str, value) -> None:
        with self._lock:
            self._store[key] = value

    def pop(self, key: str, default=None):
        with self._lock:
            return self._store.pop(key, default)

    def save(self) -> None:
        if not self.path:
            return
        tmp = self.path + ".tmp"
        with self._lock:
            with open(tmp, "w") as f:
                json.dump(self._store, f)
        os.replace(tmp, self.path)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._store

    def __len__(self):
        return len(self._store)


class PerfLibrary(JsonStore):
    """Persistent KV store of per-op schedule timings (paper §4.4)."""

    def __init__(self, path: Optional[str] = None, model: Optional[CostModel] = None):
        super().__init__(path)
        self.model = model or CostModel()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(instr: Instruction, sched: Sched, launch_blocks: int) -> str:
        feats = (
            instr.opcode,
            instr.attrs.get("fn", instr.attrs.get("kind", "")),
            tuple(instr.shape),
            str(np.dtype(instr.dtype)),
            sched.kind,
            sched.split_dim,
            sched.sword,
            sched.sched_type,
            launch_blocks,
        )
        return repr(feats)

    def lookup(self, instr: Instruction, sched: Sched, launch_blocks: int) -> float:
        k = self.key(instr, sched, launch_blocks)
        with self._lock:
            if k in self._store:
                self.hits += 1
                return self._store[k]
        t = self.model.op_time(instr, sched, launch_blocks)
        with self._lock:
            self.misses += 1
            self._store[k] = t
        return t
