"""Schedule specification + constraint propagation — paper §4.1/§4.2.

A schedule for one instruction is ``(split_dim, sword, sched_type)`` defined
on its *output* shape: the work space is split into ``blocks`` chunks, one
per grid program (the CTA analogue on TPU).

  Row    : blocks = prod(shape[:split]) * sword.  A block owns a
           ``1/sword`` slice of the split dim and the **full minor dims**
           (everything right of the split).  Row chunks are contiguous in
           row-major order — the layout-friendly direction on TPU.
  Column : blocks = sword * prod(shape[split+1:]).  A block owns the full
           **major dims** and fixed minor coordinates.

Propagation maps a schedule on an instruction's output to schedules on its
operands by the op-specific rules of Table 1.  Two extensions the codegen
needs that the paper leaves implicit:

  * ``Replicated`` — the degenerate schedule where every block sees/computes
    the full tensor (broadcast operands, tiny reduce results).  Bounded by
    ``replicate_limit`` so a fused kernel can never demand an unbounded
    VMEM-resident operand.
  * alignment — all *chunked* instructions in a fusion must agree on the
    launch ``blocks``; propagation fails (or falls back to Replicated) when
    an op's own blocks formula cannot match the launch grid.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple


from .ir import COLLECTIVE_OPCODES, Instruction

ROW = "Row"
COLUMN = "Column"


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@dataclass(frozen=True)
class Sched:
    """Schedule of one instruction's output space."""

    kind: str = "chunked"       # "chunked" | "replicated"
    split_dim: int = 0
    sword: int = 1
    sched_type: str = ROW

    @staticmethod
    def replicated() -> "Sched":
        return Sched(kind="replicated")

    def __repr__(self):
        if self.kind == "replicated":
            return "Sched(repl)"
        return f"Sched({self.sched_type}, split={self.split_dim}, sword={self.sword})"


REPLICATED = Sched.replicated()


def blocks_of(shape: Tuple[int, ...], sched: Sched) -> int:
    if sched.kind == "replicated":
        return 1
    s, w = sched.split_dim, sched.sword
    if sched.sched_type == ROW:
        return _prod(shape[:s]) * w
    return w * _prod(shape[s + 1:])


def chunk_shape(shape: Tuple[int, ...], sched: Sched) -> Tuple[int, ...]:
    if sched.kind == "replicated":
        return tuple(shape)
    s, w = sched.split_dim, sched.sword
    n = len(shape)
    if sched.sched_type == ROW:
        return (1,) * s + (shape[s] // w,) + tuple(shape[s + 1:])
    return tuple(shape[:s]) + (shape[s] // w,) + (1,) * (n - s - 1)


def block_index(shape: Tuple[int, ...], sched: Sched, b):
    """Block-unit multi-index for grid step ``b`` (Pallas index_map body).

    Works with python ints and traced values alike (uses //, %).
    """
    n = len(shape)
    if sched.kind == "replicated":
        return (0,) * n
    s, w = sched.split_dim, sched.sword
    idx = [0] * n
    if sched.sched_type == ROW:
        sub = b % w
        major = b // w
        idx[s] = sub
        for d in range(s - 1, -1, -1):
            idx[d] = major % shape[d]
            major = major // shape[d]
    else:
        minorprod = _prod(shape[s + 1:])
        sub = b // minorprod
        minor = b % minorprod
        idx[s] = sub
        for d in range(n - 1, s, -1):
            idx[d] = minor % shape[d]
            minor = minor // shape[d]
    return tuple(idx)


def _divisors(n: int, cap: int = 24) -> List[int]:
    ds = [d for d in range(1, int(n ** 0.5) + 1) if n % d == 0]
    ds = sorted(set(ds + [n // d for d in ds]))
    if len(ds) > cap:
        # keep a spread: ends + powers-of-two-ish interior
        keep = {ds[0], ds[-1]}
        for d in ds:
            if d & (d - 1) == 0:  # power of two divisor
                keep.add(d)
        ds = sorted(keep)[:cap]
    return ds


def candidate_schedules(shape: Tuple[int, ...], max_blocks: int = 1 << 16) -> List[Sched]:
    """The (small) schedule space of one output shape — paper §4.1."""
    if not shape:
        return [Sched(split_dim=0, sword=1, sched_type=ROW)] if False else [REPLICATED]
    out, seen = [], set()
    for s in range(len(shape)):
        for w in _divisors(shape[s]):
            for t in (ROW, COLUMN):
                sched = Sched("chunked", s, w, t)
                b = blocks_of(shape, sched)
                if b > max_blocks:
                    continue
                key = (b, chunk_shape(shape, sched))
                if key in seen:
                    continue
                seen.add(key)
                out.append(sched)
    return out


# --------------------------------------------------------------------------
# Table-1 propagation rules
# --------------------------------------------------------------------------


class Unsatisfiable(Exception):
    pass


def _map_reduce_out_to_in(split_out: int, reduce_dims: Tuple[int, ...]) -> int:
    """Map an output dim index of a reduce to the input dim index."""
    rd = set(reduce_dims)
    kept = [i for i in range(max(rd) + split_out + 2) if i not in rd]
    return kept[split_out]


def propagate(instr: Instruction, sched: Sched) -> List[Sched]:
    """Given ``sched`` on ``instr``'s output, derive operand schedules.

    Returns one Sched per operand.  Raises Unsatisfiable when Table 1 has no
    rule that passes.
    """
    if sched.kind == "replicated":
        return [REPLICATED] * len(instr.operands)

    op = instr.opcode
    a = instr.attrs
    s, w, t = sched.split_dim, sched.sword, sched.sched_type

    if op in ("elementwise", "select"):
        # Pass Row, Column (Table 1) — scalar/mismatched operands replicate.
        out = []
        for o in instr.operands:
            out.append(sched if tuple(o.shape) == tuple(instr.shape) else REPLICATED)
        return out

    if op == "transpose":
        perm = a["perm"]
        moved = [i for i in range(len(perm)) if perm[i] != i]
        if not moved:
            return [sched]
        if t == ROW and s < min(moved):
            return [sched]       # transpose happens fully inside the block
        if t == COLUMN and s > max(moved):
            return [sched]
        raise Unsatisfiable(f"transpose {perm} split={s} {t}")

    if op == "reduce":
        rdims = tuple(a["dims"])
        s_in = _map_reduce_out_to_in(s, rdims)
        in_shape = instr.operands[0].shape
        if t == ROW and s_in < min(rdims):
            return [Sched("chunked", s_in, w, ROW)]
        if t == COLUMN and s_in > max(rdims):
            return [Sched("chunked", s_in, w, COLUMN)]
        raise Unsatisfiable(f"reduce dims={rdims} split_out={s} {t}")

    if op == "dot":
        n = instr.ndim
        if t == ROW and s < n - 2:
            lhs, rhs = instr.operands
            return [Sched("chunked", s, w, ROW), Sched("chunked", s, w, ROW)]
        raise Unsatisfiable(f"dot split={s} {t}")

    if op in ("reshape", "bitcast"):
        in_shape = tuple(instr.operands[0].shape)
        out_shape = tuple(instr.shape)
        if t == ROW:
            # Row chunks are contiguous row-major runs; reshape preserves
            # linearization.  Find (s', w') with the same run length.
            run = _prod(out_shape[s + 1:]) * (out_shape[s] // w)
            for s2 in range(len(in_shape)):
                suffix = _prod(in_shape[s2 + 1:])
                if run % suffix == 0:
                    c = run // suffix
                    if c >= 1 and in_shape[s2] % c == 0 and c <= in_shape[s2]:
                        return [Sched("chunked", s2, in_shape[s2] // c, ROW)]
            raise Unsatisfiable(f"reshape {in_shape}->{out_shape} run={run}")
        # Column: only safe when the reshape leaves the split dim and all
        # minor dims untouched.
        tail = out_shape[s:]
        for s2 in range(len(in_shape)):
            if tuple(in_shape[s2:]) == tail:
                return [Sched("chunked", s2, w, COLUMN)]
        raise Unsatisfiable(f"reshape-col {in_shape}->{out_shape}")

    if op == "broadcast":
        dims = tuple(a["dims"])
        opnd = instr.operands[0]
        if s in dims:
            i = dims.index(s)
            if opnd.shape[i] == instr.shape[s]:
                # minor/major coverage: operand dims map monotonically
                return [Sched("chunked", i, w, t)]
        return [REPLICATED]

    if op == "concat":
        d = a["dim"]
        if (t == ROW and s < d) or (t == COLUMN and s > d):
            return [sched] * len(instr.operands)
        raise Unsatisfiable(f"concat dim={d} split={s} {t}")

    if op == "gather":
        idx = instr.operands[1]
        if t == ROW and s < idx.ndim:
            return [REPLICATED, Sched("chunked", s, w, ROW)]
        raise Unsatisfiable(f"gather split={s} {t}")

    if op in ("iota", "constant", "parameter"):
        return []

    if op in COLLECTIVE_OPCODES:
        # Collectives synchronize the whole mesh — they can never live
        # inside a kernel, so no block schedule exists for them.  The fusion
        # pass keeps them out (not in FUSABLE_OPCODES); this guard makes a
        # planner bug loud instead of a silent mis-schedule.
        raise Unsatisfiable(f"{op} is a collective: schedule break, not fusable")

    raise Unsatisfiable(f"no propagation rule for {op}")


# --------------------------------------------------------------------------
# Whole-fusion schedule resolution (root -> leaves)
# --------------------------------------------------------------------------


@dataclass
class ScheduleSolution:
    """A satisfiable schedule assignment for a fused computation."""

    blocks: int
    assignment: Dict[int, Sched]          # instr id -> Sched (members + inputs)
    root_scheds: Dict[int, Sched]

    def sched(self, instr: Instruction) -> Sched:
        return self.assignment[instr.id]


def resolve_schedules(
    members: List[Instruction],
    roots: List[Instruction],
    root_scheds: Dict[int, Sched],
    replicate_limit: int = 512 * 1024,
) -> ScheduleSolution:
    """Back-propagate root schedules through the fusion (paper §4.2).

    ``members`` must be topologically ordered.  All chunked instructions are
    checked to agree on the launch ``blocks``.  Conflicting requirements fall
    back to Replicated when the tensor fits ``replicate_limit``.
    """
    member_ids = {m.id for m in members}
    launch_blocks = None
    for r in roots:
        b = blocks_of(r.shape, root_scheds[r.id])
        if launch_blocks is None:
            launch_blocks = b
        elif launch_blocks != b:
            raise Unsatisfiable(
                f"root blocks disagree: {launch_blocks} vs {b} ({r.name})"
            )
    assignment: Dict[int, Sched] = {}

    def assign(instr: Instruction, sched: Sched) -> bool:
        """Record ``sched`` for ``instr``; True if the assignment changed.

        Assignments are monotone: an instruction may only move from
        unassigned -> chunked -> replicated, so a fixpoint exists.
        """
        if sched.kind == "chunked" and blocks_of(instr.shape, sched) != launch_blocks:
            sched = REPLICATED  # cannot align with the launch grid
        prev = assignment.get(instr.id)
        if prev is not None and prev != sched:
            sched = REPLICATED  # conflicting requirements -> whole tensor
        if sched.kind == "replicated" and instr.bytesize > replicate_limit:
            raise Unsatisfiable(
                f"{instr.name}: replicated {instr.bytesize}B > limit"
            )
        if prev == sched:
            return False
        assignment[instr.id] = sched
        return True

    for r in roots:
        assign(r, root_scheds[r.id])

    # Reverse-topo sweeps to fixpoint (downgrades to Replicated can cascade;
    # monotonicity bounds the iteration count).
    for _ in range(len(members) + 1):
        changed = False
        for instr in reversed(members):
            if instr.id not in assignment:
                # member never reached from a root yet — replicate
                changed |= assign(instr, REPLICATED)
            sched = assignment[instr.id]
            for o, osched in zip(instr.operands, propagate(instr, sched), strict=False):
                changed |= assign(o, osched)
        if not changed:
            break

    # Final soundness check: every member's operands must be readable under
    # the member's schedule (equal or replicated).
    for instr in members:
        sched = assignment[instr.id]
        for o, osched in zip(instr.operands, propagate(instr, sched), strict=False):
            got = assignment[o.id]
            if got != osched and got.kind != "replicated":
                raise Unsatisfiable(
                    f"{instr.name}: operand {o.name} has {got}, needs {osched}"
                )

    return ScheduleSolution(launch_blocks, assignment, dict(root_scheds))


def any_satisfiable(
    members: List[Instruction],
    roots: List[Instruction],
    candidates: Optional[List[Sched]] = None,
    replicate_limit: int = 512 * 1024,
    max_blocks: int = 1 << 16,
) -> Optional[ScheduleSolution]:
    """Cheap existence check used by SchdConsistent during fusion."""
    cands = candidates or candidate_schedules(roots[0].shape, max_blocks)
    for sched in cands:
        try:
            b = blocks_of(roots[0].shape, sched)
            rs = {}
            ok = True
            for r in roots:
                if tuple(r.shape) == tuple(roots[0].shape):
                    rs[r.id] = sched
                else:
                    # find a sched for r with the same blocks
                    alt = [
                        c
                        for c in candidate_schedules(r.shape, max_blocks)
                        if blocks_of(r.shape, c) == b
                    ]
                    if not alt:
                        ok = False
                        break
                    rs[r.id] = alt[0]
            if not ok:
                continue
            return resolve_schedules(members, roots, rs, replicate_limit)
        except Unsatisfiable:
            continue
    return None


# --------------------------------------------------------------------------
# Multi-phase stitching across schedule breaks (follow-up work,
# arXiv:1911.11576 / 2009.10924): when no SINGLE block schedule covers a
# group (reduce -> re-tiled broadcast, full transposes past the replicate
# limit), the group may still lower to ONE kernel as a sequence of
# schedule-consistent *phases*.  Every value crossing a phase boundary (an
# "interface" tensor) is materialized WHOLE in a VMEM staging buffer by the
# producer phase and re-tiled by the consumer phase's own schedule.
# --------------------------------------------------------------------------

CONSISTENT = "consistent"      # one schedule covers the whole group
STITCHABLE = "stitchable"      # multi-phase lowering through staged buffers
INFEASIBLE = "infeasible"      # some member has no schedule at all


@dataclass
class PhaseSolution:
    """One schedule-consistent phase of a stitched kernel."""

    members: List[Instruction]           # topological order
    roots: List[Instruction]             # values leaving the phase
    solution: ScheduleSolution

    @property
    def blocks(self) -> int:
        return self.solution.blocks


@dataclass
class StitchedSolution:
    """A feasible multi-phase schedule assignment for one fused group.

    ``interfaces`` are the group-interior values produced in one phase and
    consumed in a later one: they are staged FULLY (untiled) in VMEM, so the
    consumer phase can re-tile them under an arbitrary sub-schedule.
    """

    phases: List[PhaseSolution]
    interfaces: List[Instruction]

    @property
    def num_phases(self) -> int:
        return len(self.phases)

    @property
    def blocks(self) -> int:
        """Total sequential grid steps across all phase loops."""
        return sum(p.blocks for p in self.phases)

    @property
    def phase_sizes(self) -> Tuple[int, ...]:
        return tuple(len(p.members) for p in self.phases)

    @property
    def interface_bytes(self) -> int:
        return sum(i.bytesize for i in self.interfaces)

    def phase_of(self, instr: Instruction) -> int:
        for k, p in enumerate(self.phases):
            if any(m.id == instr.id for m in p.members):
                return k
        raise KeyError(instr.name)


@dataclass
class StitchVerdict:
    """The three-way result of ``stitchable`` — replaces the boolean
    SchdConsistent veto.  Exactly one payload is set per verdict."""

    verdict: str                                   # CONSISTENT | STITCHABLE | INFEASIBLE
    solution: Optional[ScheduleSolution] = None    # CONSISTENT
    stitched: Optional[StitchedSolution] = None    # STITCHABLE

    def __bool__(self) -> bool:
        return self.verdict != INFEASIBLE


def _phase_roots(
    phase_members: List[Instruction], phase_ids: set
) -> List[Instruction]:
    """Values leaving a phase: used by a later phase of the same group or by
    anything outside the group entirely."""
    out = []
    for m in phase_members:
        if not m.users or any(u.id not in phase_ids for u in m.users):
            out.append(m)
    return out


def _phase_solution(
    phase_members: List[Instruction],
    replicate_limit: int,
    max_blocks: int,
    stitch_replicate_limit: int,
) -> Tuple[Optional[ScheduleSolution], int]:
    """A schedule for one phase plus its quality *tier*.

    Tier 0: chunked under the normal replicate limit (the same solution a
    consistent fusion would get).  Tier 1: needs the relaxed stitching limit
    (the phase's working set lives in VMEM staging anyway, so replication is
    bounded by the stitched memory plan, not this check).  Tier 2: the
    degenerate fully-replicated single-block phase ``candidate_schedules``
    never proposes — ops like full transposes have NO chunked schedule, and
    whole-tensor execution inside a staged phase is exactly what stitching
    buys.  The phase partitioner cuts rather than letting growth DOWNGRADE
    an existing phase's tier.
    """
    phase_ids = {m.id for m in phase_members}
    roots = _phase_roots(phase_members, phase_ids)
    if not roots:
        return None, 99
    sol = any_satisfiable(
        phase_members, roots,
        replicate_limit=replicate_limit, max_blocks=max_blocks,
    )
    if sol is not None:
        return sol, 0
    lim = max(stitch_replicate_limit, replicate_limit)
    sol = any_satisfiable(
        phase_members, roots, replicate_limit=lim, max_blocks=max_blocks
    )
    if sol is not None:
        return sol, 1
    try:
        return (
            resolve_schedules(
                phase_members, roots, {r.id: REPLICATED for r in roots}, lim
            ),
            2,
        )
    except Unsatisfiable:
        return None, 99


def resolve_stitched(
    members: List[Instruction],
    roots: List[Instruction],
    replicate_limit: int = 512 * 1024,
    max_blocks: int = 1 << 16,
    stitch_replicate_limit: int = 4 * 1024 * 1024,
    stitch_max_blocks: int = 64,
    max_phases: int = 8,
) -> Optional[StitchedSolution]:
    """Partition ``members`` (topologically ordered) into schedule-consistent
    phases at schedule breaks, greedily: grow the current phase one member at
    a time and cut exactly where ``any_satisfiable`` stops holding.  Phase
    grids are capped at ``stitch_max_blocks`` because each phase lowers as a
    sequential loop over its sub-schedule inside one kernel.

    Returns None when some member has no schedule even in a phase of its own
    (or the phase count explodes) — the group is then truly infeasible.
    """
    group_ids = {m.id for m in members}
    blocks_cap = min(max_blocks, stitch_max_blocks)
    phases: List[PhaseSolution] = []
    cur: List[Instruction] = []
    cur_sol: Optional[ScheduleSolution] = None
    cur_tier = 99
    for m in members:
        trial = cur + [m]
        sol, tier = _phase_solution(
            trial, replicate_limit, blocks_cap, stitch_replicate_limit
        )
        if sol is not None and (not cur or tier <= cur_tier):
            cur, cur_sol, cur_tier = trial, sol, tier
            continue
        if not cur:
            return None                      # m alone has no schedule
        phase_ids = {i.id for i in cur}
        phases.append(
            PhaseSolution(cur, _phase_roots(cur, phase_ids), cur_sol)
        )
        if len(phases) >= max_phases:
            return None
        cur = [m]
        cur_sol, cur_tier = _phase_solution(
            cur, replicate_limit, blocks_cap, stitch_replicate_limit
        )
        if cur_sol is None:
            return None
    if cur:
        phase_ids = {i.id for i in cur}
        phases.append(
            PhaseSolution(cur, _phase_roots(cur, phase_ids), cur_sol)
        )
    # interface tensors: produced in one phase, consumed in a later one
    phase_of: Dict[int, int] = {}
    for k, p in enumerate(phases):
        for i in p.members:
            phase_of[i.id] = k
    interfaces: List[Instruction] = []
    for p in phases:
        for i in p.members:
            if any(
                u.id in group_ids and phase_of[u.id] > phase_of[i.id]
                for u in i.users
            ):
                interfaces.append(i)
    return StitchedSolution(phases, interfaces)


def stitchable(
    roots: List[Instruction],
    members: List[Instruction],
    replicate_limit: int = 512 * 1024,
    max_blocks: int = 1 << 16,
    stitch_replicate_limit: int = 4 * 1024 * 1024,
    stitch_max_blocks: int = 64,
    allow_stitch: bool = True,
) -> StitchVerdict:
    """Three-way schedule-consistency verdict for a tentative fusion group.

    CONSISTENT: one block schedule covers every member (the paper's
    SchdConsistent).  STITCHABLE: no single schedule exists, but the group
    partitions into consistent phases stitched through staged VMEM buffers.
    INFEASIBLE: neither — the fusion pass must not take this enlargement.

    Cost note: an INFEASIBLE verdict pays the full phase-partition attempt
    (O(members) ``any_satisfiable`` solves) on top of the consistent check;
    callers that probe many enlargements should memoize by member set, as
    ``FusionScorer.verdict`` does.
    """
    sol = any_satisfiable(
        members, roots, replicate_limit=replicate_limit, max_blocks=max_blocks
    )
    if sol is not None:
        return StitchVerdict(CONSISTENT, solution=sol)
    if not allow_stitch:
        return StitchVerdict(INFEASIBLE)
    st = resolve_stitched(
        members, roots,
        replicate_limit=replicate_limit,
        max_blocks=max_blocks,
        stitch_replicate_limit=stitch_replicate_limit,
        stitch_max_blocks=stitch_max_blocks,
    )
    if st is None:
        return StitchVerdict(INFEASIBLE)
    # A single relaxed-limit phase is still one schedule — but one that only
    # exists because full replication is allowed; it lowers through the
    # stitched (sequential-loop) path so the memory plan bounds its residency.
    return StitchVerdict(STITCHABLE, stitched=st)
