"""Schedule specification + constraint propagation — paper §4.1/§4.2.

A schedule for one instruction is ``(split_dim, sword, sched_type)`` defined
on its *output* shape: the work space is split into ``blocks`` chunks, one
per grid program (the CTA analogue on TPU).

  Row    : blocks = prod(shape[:split]) * sword.  A block owns a
           ``1/sword`` slice of the split dim and the **full minor dims**
           (everything right of the split).  Row chunks are contiguous in
           row-major order — the layout-friendly direction on TPU.
  Column : blocks = sword * prod(shape[split+1:]).  A block owns the full
           **major dims** and fixed minor coordinates.

Propagation maps a schedule on an instruction's output to schedules on its
operands by the op-specific rules of Table 1.  Two extensions the codegen
needs that the paper leaves implicit:

  * ``Replicated`` — the degenerate schedule where every block sees/computes
    the full tensor (broadcast operands, tiny reduce results).  Bounded by
    ``replicate_limit`` so a fused kernel can never demand an unbounded
    VMEM-resident operand.
  * alignment — all *chunked* instructions in a fusion must agree on the
    launch ``blocks``; propagation fails (or falls back to Replicated) when
    an op's own blocks formula cannot match the launch grid.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .ir import Instruction

ROW = "Row"
COLUMN = "Column"


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@dataclass(frozen=True)
class Sched:
    """Schedule of one instruction's output space."""

    kind: str = "chunked"       # "chunked" | "replicated"
    split_dim: int = 0
    sword: int = 1
    sched_type: str = ROW

    @staticmethod
    def replicated() -> "Sched":
        return Sched(kind="replicated")

    def __repr__(self):
        if self.kind == "replicated":
            return "Sched(repl)"
        return f"Sched({self.sched_type}, split={self.split_dim}, sword={self.sword})"


REPLICATED = Sched.replicated()


def blocks_of(shape: Tuple[int, ...], sched: Sched) -> int:
    if sched.kind == "replicated":
        return 1
    s, w = sched.split_dim, sched.sword
    if sched.sched_type == ROW:
        return _prod(shape[:s]) * w
    return w * _prod(shape[s + 1:])


def chunk_shape(shape: Tuple[int, ...], sched: Sched) -> Tuple[int, ...]:
    if sched.kind == "replicated":
        return tuple(shape)
    s, w = sched.split_dim, sched.sword
    n = len(shape)
    if sched.sched_type == ROW:
        return (1,) * s + (shape[s] // w,) + tuple(shape[s + 1:])
    return tuple(shape[:s]) + (shape[s] // w,) + (1,) * (n - s - 1)


def block_index(shape: Tuple[int, ...], sched: Sched, b):
    """Block-unit multi-index for grid step ``b`` (Pallas index_map body).

    Works with python ints and traced values alike (uses //, %).
    """
    n = len(shape)
    if sched.kind == "replicated":
        return (0,) * n
    s, w = sched.split_dim, sched.sword
    idx = [0] * n
    if sched.sched_type == ROW:
        sub = b % w
        major = b // w
        idx[s] = sub
        for d in range(s - 1, -1, -1):
            idx[d] = major % shape[d]
            major = major // shape[d]
    else:
        minorprod = _prod(shape[s + 1:])
        sub = b // minorprod
        minor = b % minorprod
        idx[s] = sub
        for d in range(n - 1, s, -1):
            idx[d] = minor % shape[d]
            minor = minor // shape[d]
    return tuple(idx)


def _divisors(n: int, cap: int = 24) -> List[int]:
    ds = [d for d in range(1, int(n ** 0.5) + 1) if n % d == 0]
    ds = sorted(set(ds + [n // d for d in ds]))
    if len(ds) > cap:
        # keep a spread: ends + powers-of-two-ish interior
        keep = {ds[0], ds[-1]}
        for d in ds:
            if d & (d - 1) == 0:  # power of two divisor
                keep.add(d)
        ds = sorted(keep)[:cap]
    return ds


def candidate_schedules(shape: Tuple[int, ...], max_blocks: int = 1 << 16) -> List[Sched]:
    """The (small) schedule space of one output shape — paper §4.1."""
    if not shape:
        return [Sched(split_dim=0, sword=1, sched_type=ROW)] if False else [REPLICATED]
    out, seen = [], set()
    for s in range(len(shape)):
        for w in _divisors(shape[s]):
            for t in (ROW, COLUMN):
                sched = Sched("chunked", s, w, t)
                b = blocks_of(shape, sched)
                if b > max_blocks:
                    continue
                key = (b, chunk_shape(shape, sched))
                if key in seen:
                    continue
                seen.add(key)
                out.append(sched)
    return out


# --------------------------------------------------------------------------
# Table-1 propagation rules
# --------------------------------------------------------------------------


class Unsatisfiable(Exception):
    pass


def _map_reduce_out_to_in(split_out: int, reduce_dims: Tuple[int, ...]) -> int:
    """Map an output dim index of a reduce to the input dim index."""
    rd = set(reduce_dims)
    kept = [i for i in range(max(rd) + split_out + 2) if i not in rd]
    return kept[split_out]


def propagate(instr: Instruction, sched: Sched) -> List[Sched]:
    """Given ``sched`` on ``instr``'s output, derive operand schedules.

    Returns one Sched per operand.  Raises Unsatisfiable when Table 1 has no
    rule that passes.
    """
    if sched.kind == "replicated":
        return [REPLICATED] * len(instr.operands)

    op = instr.opcode
    a = instr.attrs
    s, w, t = sched.split_dim, sched.sword, sched.sched_type

    if op in ("elementwise", "select"):
        # Pass Row, Column (Table 1) — scalar/mismatched operands replicate.
        out = []
        for o in instr.operands:
            out.append(sched if tuple(o.shape) == tuple(instr.shape) else REPLICATED)
        return out

    if op == "transpose":
        perm = a["perm"]
        moved = [i for i in range(len(perm)) if perm[i] != i]
        if not moved:
            return [sched]
        if t == ROW and s < min(moved):
            return [sched]       # transpose happens fully inside the block
        if t == COLUMN and s > max(moved):
            return [sched]
        raise Unsatisfiable(f"transpose {perm} split={s} {t}")

    if op == "reduce":
        rdims = tuple(a["dims"])
        s_in = _map_reduce_out_to_in(s, rdims)
        in_shape = instr.operands[0].shape
        if t == ROW and s_in < min(rdims):
            return [Sched("chunked", s_in, w, ROW)]
        if t == COLUMN and s_in > max(rdims):
            return [Sched("chunked", s_in, w, COLUMN)]
        raise Unsatisfiable(f"reduce dims={rdims} split_out={s} {t}")

    if op == "dot":
        n = instr.ndim
        if t == ROW and s < n - 2:
            lhs, rhs = instr.operands
            return [Sched("chunked", s, w, ROW), Sched("chunked", s, w, ROW)]
        raise Unsatisfiable(f"dot split={s} {t}")

    if op in ("reshape", "bitcast"):
        in_shape = tuple(instr.operands[0].shape)
        out_shape = tuple(instr.shape)
        if t == ROW:
            # Row chunks are contiguous row-major runs; reshape preserves
            # linearization.  Find (s', w') with the same run length.
            run = _prod(out_shape[s + 1:]) * (out_shape[s] // w)
            for s2 in range(len(in_shape)):
                suffix = _prod(in_shape[s2 + 1:])
                if run % suffix == 0:
                    c = run // suffix
                    if c >= 1 and in_shape[s2] % c == 0 and c <= in_shape[s2]:
                        return [Sched("chunked", s2, in_shape[s2] // c, ROW)]
            raise Unsatisfiable(f"reshape {in_shape}->{out_shape} run={run}")
        # Column: only safe when the reshape leaves the split dim and all
        # minor dims untouched.
        tail = out_shape[s:]
        for s2 in range(len(in_shape)):
            if tuple(in_shape[s2:]) == tail:
                return [Sched("chunked", s2, w, COLUMN)]
        raise Unsatisfiable(f"reshape-col {in_shape}->{out_shape}")

    if op == "broadcast":
        dims = tuple(a["dims"])
        opnd = instr.operands[0]
        if s in dims:
            i = dims.index(s)
            if opnd.shape[i] == instr.shape[s]:
                # minor/major coverage: operand dims map monotonically
                return [Sched("chunked", i, w, t)]
        return [REPLICATED]

    if op == "concat":
        d = a["dim"]
        if (t == ROW and s < d) or (t == COLUMN and s > d):
            return [sched] * len(instr.operands)
        raise Unsatisfiable(f"concat dim={d} split={s} {t}")

    if op == "gather":
        idx = instr.operands[1]
        if t == ROW and s < idx.ndim:
            return [REPLICATED, Sched("chunked", s, w, ROW)]
        raise Unsatisfiable(f"gather split={s} {t}")

    if op in ("iota", "constant", "parameter"):
        return []

    raise Unsatisfiable(f"no propagation rule for {op}")


# --------------------------------------------------------------------------
# Whole-fusion schedule resolution (root -> leaves)
# --------------------------------------------------------------------------


@dataclass
class ScheduleSolution:
    """A satisfiable schedule assignment for a fused computation."""

    blocks: int
    assignment: Dict[int, Sched]          # instr id -> Sched (members + inputs)
    root_scheds: Dict[int, Sched]

    def sched(self, instr: Instruction) -> Sched:
        return self.assignment[instr.id]


def resolve_schedules(
    members: List[Instruction],
    roots: List[Instruction],
    root_scheds: Dict[int, Sched],
    replicate_limit: int = 512 * 1024,
) -> ScheduleSolution:
    """Back-propagate root schedules through the fusion (paper §4.2).

    ``members`` must be topologically ordered.  All chunked instructions are
    checked to agree on the launch ``blocks``.  Conflicting requirements fall
    back to Replicated when the tensor fits ``replicate_limit``.
    """
    member_ids = {m.id for m in members}
    launch_blocks = None
    for r in roots:
        b = blocks_of(r.shape, root_scheds[r.id])
        if launch_blocks is None:
            launch_blocks = b
        elif launch_blocks != b:
            raise Unsatisfiable(
                f"root blocks disagree: {launch_blocks} vs {b} ({r.name})"
            )
    assignment: Dict[int, Sched] = {}

    def assign(instr: Instruction, sched: Sched) -> bool:
        """Record ``sched`` for ``instr``; True if the assignment changed.

        Assignments are monotone: an instruction may only move from
        unassigned -> chunked -> replicated, so a fixpoint exists.
        """
        if sched.kind == "chunked" and blocks_of(instr.shape, sched) != launch_blocks:
            sched = REPLICATED  # cannot align with the launch grid
        prev = assignment.get(instr.id)
        if prev is not None and prev != sched:
            sched = REPLICATED  # conflicting requirements -> whole tensor
        if sched.kind == "replicated" and instr.bytesize > replicate_limit:
            raise Unsatisfiable(
                f"{instr.name}: replicated {instr.bytesize}B > limit"
            )
        if prev == sched:
            return False
        assignment[instr.id] = sched
        return True

    for r in roots:
        assign(r, root_scheds[r.id])

    # Reverse-topo sweeps to fixpoint (downgrades to Replicated can cascade;
    # monotonicity bounds the iteration count).
    for _ in range(len(members) + 1):
        changed = False
        for instr in reversed(members):
            if instr.id not in assignment:
                # member never reached from a root yet — replicate
                changed |= assign(instr, REPLICATED)
            sched = assignment[instr.id]
            for o, osched in zip(instr.operands, propagate(instr, sched)):
                changed |= assign(o, osched)
        if not changed:
            break

    # Final soundness check: every member's operands must be readable under
    # the member's schedule (equal or replicated).
    for instr in members:
        sched = assignment[instr.id]
        for o, osched in zip(instr.operands, propagate(instr, sched)):
            got = assignment[o.id]
            if got != osched and got.kind != "replicated":
                raise Unsatisfiable(
                    f"{instr.name}: operand {o.name} has {got}, needs {osched}"
                )

    return ScheduleSolution(launch_blocks, assignment, dict(root_scheds))


def any_satisfiable(
    members: List[Instruction],
    roots: List[Instruction],
    candidates: Optional[List[Sched]] = None,
    replicate_limit: int = 512 * 1024,
    max_blocks: int = 1 << 16,
) -> Optional[ScheduleSolution]:
    """Cheap existence check used by SchdConsistent during fusion."""
    cands = candidates or candidate_schedules(roots[0].shape, max_blocks)
    for sched in cands:
        try:
            b = blocks_of(roots[0].shape, sched)
            rs = {}
            ok = True
            for r in roots:
                if tuple(r.shape) == tuple(roots[0].shape):
                    rs[r.id] = sched
                else:
                    # find a sched for r with the same blocks
                    alt = [
                        c
                        for c in candidate_schedules(r.shape, max_blocks)
                        if blocks_of(r.shape, c) == b
                    ]
                    if not alt:
                        ok = False
                        break
                    rs[r.id] = alt[0]
            if not ok:
                continue
            return resolve_schedules(members, roots, rs, replicate_limit)
        except Unsatisfiable:
            continue
    return None
