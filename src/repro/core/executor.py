"""Executors: the pure-jnp reference oracle and the stitched runtime.

``reference_execute`` walks the module with ``apply_op`` — the oracle every
generated kernel is validated against.

``StitchedExecutable`` runs a compile-time **ExecutionPlan** instead of
re-walking the module per call: constant-like chains are folded exactly once
at plan-build time, every value that flows between execution units lives in
a flat buffer table with precomputed last-use release points (intermediate
buffers are dropped eagerly), and each unit is pre-bound to its kernel and
operand slots.  The per-call hot path is a flat loop over pre-bound steps —
no graph traversal, no constant re-evaluation, no dict-keyed lookups.

The eager step loop still pays one Python->XLA dispatch per step — exactly
the launch overhead the compile-time passes fight.  ``jit_execute`` removes
it: the pre-bound loop is inlined **at trace time** into ``jax.jit``
segment callables (kernels, standalone ops, and library dots traced into
one XLA program per segment), so a steady-state call costs one dispatch per
segment instead of ``len(steps)`` — exactly ONE for graphs whose library
dots only consume parameters or earlier-segment outputs.  A library call
whose operand is produced inside the current segment starts a NEW segment:
as a segment leader its operands arrive as jit arguments with canonical
layouts — what the eager dispatch sees — which is what keeps the replay
**bit-identical** to the eager oracle (kept in-program, XLA folds layout
changes such as transposes into the dot operand and alters the
accumulation order).  Intermediate values the eager loop releases at their
last read are expressed to XLA as buffer donation of the corresponding
segment inputs, letting the runtime reuse their memory in place (parameter
and folded-constant buffers are never donated — the caller or the template
still holds them).  The eager loop is kept as the replay oracle, and
``LaunchStats`` counts traced vs eager dispatches.
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .codegen import StitchedKernel
from .fusion import FusionPlan, constant_like
from .ir import Instruction, Module, apply_op


def reference_execute(module: Module, feeds: Dict[str, object]) -> Dict[str, object]:
    vals: Dict[int, object] = {}
    for instr in module.instructions:
        if instr.opcode == "parameter":
            if instr.name not in feeds:
                raise KeyError(f"missing feed for parameter {instr.name}")
            v = jnp.asarray(feeds[instr.name], dtype=instr.dtype)
            assert tuple(v.shape) == tuple(instr.shape), (
                f"{instr.name}: feed shape {v.shape} != {instr.shape}"
            )
            vals[instr.id] = v
        else:
            vals[instr.id] = apply_op(instr, *[vals[o.id] for o in instr.operands])
    return {r.name: vals[r.id] for r in module.roots}


@dataclass
class LaunchStats:
    stitched_kernels: int = 0
    standalone_kernels: int = 0
    library_calls: int = 0
    collective_calls: int = 0        # mesh collectives — ICI steps, not launches
    loop_calls: int = 0              # sub-module loops (``call`` instructions)
    # runtime replay accounting: how calls were dispatched so far
    traced_calls: int = 0            # calls through the jitted replay
    eager_calls: int = 0             # calls through the eager step loop
    jit_traces: int = 0              # segment traces performed so far
    eager_dispatches_per_call: int = 0   # pre-bound steps the eager loop runs
    traced_dispatches_per_call: int = 0  # jitted replay segments
    donated_buffers: int = 0         # dead-after-segment inputs donated to XLA

    @property
    def total_non_library(self) -> int:
        return self.stitched_kernels + self.standalone_kernels


def order_units(plan: FusionPlan) -> List[object]:
    """Topological order over execution units (fusions + standalone).

    Fusion groups interleave in instruction order, so firing a group at its
    last member's position is NOT safe; we order groups by their value
    dependences instead (fusion-time cycle checks guarantee the group graph
    is a DAG).
    """
    units: List[object] = list(plan.fusions) + list(plan.standalone)
    unit_of: Dict[int, int] = {}
    for ui, u in enumerate(units):
        members = [u] if isinstance(u, Instruction) else u.members
        for m in members:
            unit_of[m.id] = ui
    deps: List[set] = [set() for _ in units]
    for ui, u in enumerate(units):
        srcs = u.operands if isinstance(u, Instruction) else u.inputs
        for s in srcs:
            if s.id in unit_of and unit_of[s.id] != ui:
                deps[ui].add(unit_of[s.id])
    # Kahn's algorithm (deque: the sorted-list pop(0) was O(n^2))
    indeg = [len(d) for d in deps]
    rdeps: List[set] = [set() for _ in units]
    for ui, d in enumerate(deps):
        for v in d:
            rdeps[v].add(ui)
    ready = deque(sorted(ui for ui, k in enumerate(indeg) if k == 0))
    order = []
    while ready:
        ui = ready.popleft()
        order.append(ui)
        for v in sorted(rdeps[ui]):
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if len(order) != len(units):
        raise RuntimeError("cyclic fusion plan — fusion cycle check failed")
    return [units[ui] for ui in order]


class _KernelStep:
    """One stitched-kernel launch, pre-bound to its buffer slots."""

    __slots__ = ("kernel", "arg_slots", "out_slots", "release")

    def __init__(self, kernel: StitchedKernel, arg_slots, out_slots):
        self.kernel = kernel
        self.arg_slots = arg_slots
        self.out_slots = out_slots
        self.release: List[int] = []


class _OpStep:
    """One standalone instruction (library dot etc.), pre-bound."""

    __slots__ = ("instr", "arg_slots", "out_slot", "release")

    def __init__(self, instr: Instruction, arg_slots, out_slot):
        self.instr = instr
        self.arg_slots = arg_slots
        self.out_slot = out_slot
        self.release: List[int] = []


def _step_outs(step) -> List[int]:
    """Buffer slots a pre-bound step writes."""
    if type(step) is _OpStep:
        return [step.out_slot]
    return step.out_slots


class _LoopStep:
    """One sub-module loop (``call`` instruction), pre-bound.

    The body is a separately compiled ``ExecutionPlan`` whose step loop is
    inlined AT TRACE TIME via ``ExecutionPlan.trace_steps`` — same kernels,
    same step order, same barriers in every replay mode.  The eager path
    dispatches one jitted body call per iteration (``trip`` dispatches —
    exactly the per-iteration launch overhead the paper's decode loops
    pay); the traced path wraps the same inlined body in one
    ``jax.lax.scan`` under a single jit, so the whole loop costs ONE
    dispatch.  Carries double-buffer through the scan carry; per-iteration
    outputs stack into the planned output slots.
    """

    __slots__ = (
        "instr", "body_plan", "arg_slots", "out_slots", "out_indices",
        "release", "num_consts", "num_carry", "trip", "reverse",
        "out_order", "out_shapes", "out_dtypes", "_iter_fn", "_scan_fn",
    )

    def __init__(self, instr: Instruction, body_plan, arg_slots, out_slots,
                 out_indices):
        a = instr.attrs
        self.instr = instr
        self.body_plan = body_plan
        self.arg_slots = arg_slots
        self.out_slots = out_slots        # one per live ``get`` projection
        self.out_indices = list(out_indices)   # logical output index per slot
        self.release: List[int] = []
        self.num_consts = int(a["num_consts"])
        self.num_carry = int(a["num_carry"])
        self.trip = int(a["trip_count"])
        self.reverse = bool(a.get("reverse", False))
        self.out_order = list(a["out_order"])
        self.out_shapes = [tuple(s) for s in a["out_shapes"]]
        self.out_dtypes = list(a["out_dtypes"])
        self._iter_fn = None              # per-iteration jit (eager replay)
        self._scan_fn = None              # whole-loop jit (traced replay)

    # -- trace-time body --------------------------------------------------
    def _scan(self, args):
        """All logical outputs (final carries + stacked ys), traceable."""
        nc, k = self.num_consts, self.num_carry
        consts = list(args[:nc])
        init = list(args[nc:nc + k])
        xs = list(args[nc + k:])
        plan, order = self.body_plan, self.out_order

        def body(carry, x):
            x_vals = [] if x is None else list(x)
            roots = plan.trace_steps(consts + list(carry) + x_vals)
            ordered = [roots[j] for j in order]
            return tuple(ordered[:k]), tuple(ordered[k:])

        final, ys = jax.lax.scan(
            body,
            tuple(init),
            tuple(xs) if xs else None,
            length=self.trip,
            reverse=self.reverse,
        )
        return list(final) + list(ys)

    def run_nested(self, args):
        """Inline into an enclosing trace (nested loops): the projected
        output values for this step's ``out_slots``."""
        outs = self._scan(list(args))
        return [outs[i] for i in self.out_indices]

    # -- replay modes -----------------------------------------------------
    def run_traced(self, args, counter):
        if self._scan_fn is None:
            def fn(*vals):
                counter()             # runs only while tracing
                return tuple(self.run_nested(list(vals)))

            self._scan_fn = jax.jit(fn)
        return self._scan_fn(*args)

    def run_eager(self, args):
        nc, k = self.num_consts, self.num_carry
        consts = list(args[:nc])
        carry = list(args[nc:nc + k])
        xs = list(args[nc + k:])
        n_y = len(self.out_order) - k
        if self.trip == 0:
            all_outs = carry + [
                jnp.zeros(self.out_shapes[k + j], self.out_dtypes[k + j])
                for j in range(n_y)
            ]
            return [all_outs[i] for i in self.out_indices]
        if self._iter_fn is None:
            plan, order = self.body_plan, self.out_order

            def it(*vals):
                roots = plan.trace_steps(list(vals))
                return tuple(roots[j] for j in order)

            self._iter_fn = jax.jit(it)
        cols: List[List[object]] = [[] for _ in range(n_y)]
        steps = (
            range(self.trip - 1, -1, -1) if self.reverse
            else range(self.trip)
        )
        for t in steps:
            outs = self._iter_fn(*(consts + carry + [x[t] for x in xs]))
            carry = list(outs[:k])
            for j in range(n_y):
                cols[j].append(outs[k + j])
        if self.reverse:
            cols = [list(reversed(c)) for c in cols]
        all_outs = carry + [jnp.stack(c) for c in cols]
        return [all_outs[i] for i in self.out_indices]


class _JitSegment:
    """A run of pre-bound steps traced into one jitted callable.

    ``in_slots`` are buffer-table slots the segment reads but does not
    produce; ``out_slots`` are slots it produces that are still needed
    afterwards (roots, or read by a later segment / library call).
    ``donate`` indexes the ``in_slots`` whose eager-release point falls
    inside this segment — dead after the call, so their buffers are donated
    to XLA.  Only *intermediate* slots (produced by an earlier segment,
    owned by the runtime, fresh every call) are donated: template
    (folded-constant) buffers are shared across calls, and parameter
    buffers may still be held by the caller (``jnp.asarray`` is a no-copy
    passthrough for device-resident feeds — donating those would delete
    arrays the caller reuses on the next call).
    """

    __slots__ = ("steps", "in_slots", "out_slots", "released", "donate", "fn")

    def __init__(self, steps: List[object], keep: set, protected_slots: set):
        self.steps = list(steps)
        written: List[int] = []
        written_set: set = set()
        in_slots: List[int] = []
        in_set: set = set()
        released: set = set()
        for step in self.steps:
            for s in step.arg_slots:
                if s not in written_set and s not in in_set:
                    in_set.add(s)
                    in_slots.append(s)
            outs = (
                step.out_slots if type(step) is _KernelStep else [step.out_slot]
            )
            for s in outs:
                if s not in written_set:
                    written_set.add(s)
                    written.append(s)
            released.update(step.release)
        self.in_slots = in_slots
        self.released = released
        self.out_slots = [
            s for s in written if s in keep or s not in released
        ]
        self.donate = tuple(
            i
            for i, s in enumerate(in_slots)
            if s in released and s not in protected_slots
        )
        self.fn = None               # jax.jit wrapper, built lazily

    def build(self, counter) -> None:
        """Trace-time body: the segment's pre-bound steps inlined into one
        XLA program.  Step outputs pass through ``optimization_barrier`` so
        XLA cannot re-fuse across step boundaries — fusion decisions belong
        to the FusionStitching passes, and the barrier keeps the traced
        program step-for-step equivalent to the eager oracle."""
        steps, in_slots, out_slots = self.steps, self.in_slots, self.out_slots

        def seg(*vals):
            counter()                # runs only while tracing
            local: Dict[int, object] = dict(zip(in_slots, vals, strict=False))
            for step in steps:
                args = [local[s] for s in step.arg_slots]
                if type(step) is _KernelStep:
                    outs = jax.lax.optimization_barrier(step.kernel(*args))
                    for s, o in zip(step.out_slots, outs, strict=False):
                        local[s] = o
                else:
                    local[step.out_slot] = jax.lax.optimization_barrier(
                        apply_op(step.instr, *args)
                    )
            return tuple(local[s] for s in out_slots)

        self.fn = jax.jit(seg, donate_argnums=self.donate)


class ExecutionPlan:
    """Precomputed run recipe for a compiled FusionPlan.

    Built once at compile time:
      * constant-like chains are evaluated here (``fold_evals`` counts the
        evaluations — they never recur at call time);
      * a flat buffer table holds every inter-unit value; slots are released
        (set to None) right after their last consuming step;
      * each step carries its kernel/instruction and operand slot indices.
    """

    def __init__(
        self,
        module: Module,
        plan: FusionPlan,
        kernels: Dict[str, StitchedKernel],
        donate_params=None,
    ):
        member_ids = {m.id for f in plan.fusions for m in f.members}
        covered = member_ids | {s.id for s in plan.standalone}

        units = order_units(plan)

        # ---- which values must live in the buffer table -------------------
        needed: set = {r.id for r in module.roots}
        for u in units:
            if isinstance(u, Instruction):
                needed.update(o.id for o in u.operands)
            else:
                needed.update(i.id for i in kernels[u.name].inputs)

        slot_of: Dict[int, int] = {}

        def new_slot(instr_id: int) -> int:
            slot_of[instr_id] = len(slot_of)
            return slot_of[instr_id]

        # ---- parameters + compile-time constant folding -------------------
        self.fold_evals = 0
        folded_vals: Dict[int, object] = {}

        def fold(instr: Instruction):
            if instr.id in folded_vals:
                return folded_vals[instr.id]
            v = apply_op(instr, *[fold(o) for o in instr.operands])
            self.fold_evals += 1
            folded_vals[instr.id] = v
            return v

        self._param_binds: List[Tuple[str, int, object, Tuple[int, ...]]] = []
        template_fill: List[Tuple[int, object]] = []
        for instr in module.instructions:
            if instr.opcode == "parameter":
                s = new_slot(instr.id)
                self._param_binds.append(
                    (instr.name, s, instr.dtype, tuple(instr.shape))
                )
            elif instr.id not in covered:
                if not (instr.opcode == "constant" or constant_like(instr)):
                    raise RuntimeError(
                        f"{instr.name}: uncovered non-constant instruction"
                    )
                if instr.id in needed:
                    template_fill.append((new_slot(instr.id), fold(instr)))

        # ---- pre-bound steps in unit order ---------------------------------
        self.steps: List[object] = []
        for u in units:
            if isinstance(u, Instruction):
                if u.opcode == "get":
                    continue   # its slot is created by the call's loop step
                arg_slots = [slot_of[o.id] for o in u.operands]
                if u.opcode == "call":
                    gets = sorted(
                        (g for g in u.users if g.opcode == "get"),
                        key=lambda g: g.attrs["index"],
                    )
                    if len(gets) != len(u.users):
                        raise RuntimeError(
                            f"{u.name}: call outputs must be consumed "
                            "through get projections"
                        )
                    cm = u.attrs.get("compiled_body")
                    if cm is None:
                        raise RuntimeError(
                            f"{u.name}: loop body was not compiled — "
                            "SubModulePass must run before plan construction"
                        )
                    self.steps.append(
                        _LoopStep(
                            u,
                            cm.executable.execution_plan,
                            arg_slots,
                            [new_slot(g.id) for g in gets],
                            [int(g.attrs["index"]) for g in gets],
                        )
                    )
                else:
                    self.steps.append(_OpStep(u, arg_slots, new_slot(u.id)))
            else:
                k = kernels[u.name]
                arg_slots = [slot_of[i.id] for i in k.inputs]
                out_slots = [new_slot(r.id) for r in k.outputs]
                self.steps.append(_KernelStep(k, arg_slots, out_slots))

        self.num_slots = len(slot_of)
        self._root_binds: List[Tuple[str, int]] = [
            (r.name, slot_of[r.id]) for r in module.roots
        ]

        # ---- eager-release points: free a slot after its last read ---------
        keep = {s for _, s in self._root_binds}
        last_read: Dict[int, int] = {}
        for si, step in enumerate(self.steps):
            for s in step.arg_slots:
                last_read[s] = si
        for s, si in last_read.items():
            if s not in keep:
                self.steps[si].release.append(s)
        # Dead outputs — multi-output kernel slots (e.g. a fusion root with
        # no remaining consumer) are never in ``last_read``, so without this
        # they would hold their buffer for the whole run.  Release them at
        # the step that produces them.
        for si, step in enumerate(self.steps):
            for s in _step_outs(step):
                if s not in keep and s not in last_read:
                    step.release.append(s)

        template: List[Optional[object]] = [None] * self.num_slots
        for s, v in template_fill:
            template[s] = v
        self._template = template

        # ---- traced replay segments ---------------------------------------
        # The step loop traces into jitted segments.  A library call
        # (cuBLAS/MXU dot) whose operand was produced INSIDE the current
        # segment starts a new one: as a segment leader its operands arrive
        # as fresh jit arguments with canonical layouts — exactly what the
        # eager dispatch sees — whereas in-program XLA folds layout changes
        # (e.g. a transpose) into the dot operand and changes the
        # accumulation order, breaking bit-parity with the eager oracle.
        # Template + parameter slots are protected from donation (shared
        # across calls / possibly still held by the caller) — EXCEPT
        # parameters the caller explicitly donated (``donate_argnums``
        # through the frontend): those buffers belong to the plan after the
        # call, per the jax.jit donation contract.
        donate = frozenset(donate_params or ())
        protected_slots = {s for s, _ in template_fill} | {
            slot for name, slot, _, _ in self._param_binds
            if name not in donate
        }
        self.donated_param_slots = {
            slot for name, slot, _, _ in self._param_binds if name in donate
        }
        self._segments: List[object] = []
        run: List[object] = []
        produced: set = set()
        for step in self.steps:
            if type(step) is _LoopStep:
                # a loop is its own dispatch unit in the traced replay
                if run:
                    self._segments.append(
                        _JitSegment(run, keep, protected_slots)
                    )
                    run, produced = [], set()
                self._segments.append(step)
                continue
            is_lib = type(step) is _OpStep and step.instr.is_library_call
            if is_lib and run and any(s in produced for s in step.arg_slots):
                self._segments.append(_JitSegment(run, keep, protected_slots))
                run, produced = [], set()
            run.append(step)
            produced.update(_step_outs(step))
        if run:
            self._segments.append(_JitSegment(run, keep, protected_slots))
        self.stats = LaunchStats(
            eager_dispatches_per_call=sum(
                s.trip if type(s) is _LoopStep else 1 for s in self.steps
            ),
            traced_dispatches_per_call=len(self._segments),
            donated_buffers=sum(
                len(seg.donate) for seg in self._segments
                if type(seg) is _JitSegment
            ),
            loop_calls=sum(
                1 for s in self.steps if type(s) is _LoopStep
            ),
        )

    @property
    def num_folded(self) -> int:
        return sum(1 for v in self._template if v is not None)

    def trace_steps(self, param_vals) -> List[object]:
        """Trace-time inline of the whole pre-bound step loop, WITHOUT
        segmentation: this is the loop-body building block (``_LoopStep``),
        where the surrounding per-iteration jit / ``lax.scan`` is the
        dispatch unit.  Parameter values pass through
        ``optimization_barrier`` so library dots see canonical operands
        whether the body runs standalone (eager per-iteration jit) or
        inside ``lax.scan`` — XLA cannot fold carried-value or slice
        layouts into the dot and change its accumulation order, which
        keeps the two replay modes bit-identical.  Takes parameter values
        positionally (``_param_binds`` order = parameter creation order =
        call operand order) and returns root values in ``module.roots``
        order."""
        buf: List[Optional[object]] = list(self._template)
        for (name, slot, dtype, shape), v in zip(
            self._param_binds, param_vals
        , strict=False):
            buf[slot] = jax.lax.optimization_barrier(
                jnp.asarray(v, dtype=dtype)
            )
        for step in self.steps:
            args = [buf[s] for s in step.arg_slots]
            if type(step) is _KernelStep:
                outs = jax.lax.optimization_barrier(step.kernel(*args))
                for s, o in zip(step.out_slots, outs, strict=False):
                    buf[s] = o
            elif type(step) is _LoopStep:
                for s, o in zip(step.out_slots, step.run_nested(args), strict=False):
                    buf[s] = o
            else:
                buf[step.out_slot] = jax.lax.optimization_barrier(
                    apply_op(step.instr, *args)
                )
            for s in step.release:
                buf[s] = None
        return [buf[s] for _, s in self._root_binds]

    def _bind_feeds(self, feeds: Dict[str, object]) -> List[object]:
        """Validated parameter values in ``_param_binds`` order."""
        vals = []
        for name, slot, dtype, shape in self._param_binds:
            if name not in feeds:
                raise KeyError(f"missing feed for parameter {name}")
            v = jnp.asarray(feeds[name], dtype=dtype)
            if tuple(v.shape) != shape:
                raise ValueError(f"{name}: feed shape {v.shape} != {shape}")
            vals.append(v)
        return vals

    def execute(self, feeds: Dict[str, object]) -> Dict[str, object]:
        """Eager replay: one Python-dispatched XLA call per step (the
        traced-replay oracle)."""
        buf = list(self._template)
        for (name, slot, dtype, shape), v in zip(
            self._param_binds, self._bind_feeds(feeds)
        , strict=False):
            buf[slot] = v
        for step in self.steps:
            if type(step) is _KernelStep:
                outs = step.kernel(*[buf[s] for s in step.arg_slots])
                for s, o in zip(step.out_slots, outs, strict=False):
                    buf[s] = o
            elif type(step) is _LoopStep:
                outs = step.run_eager([buf[s] for s in step.arg_slots])
                for s, o in zip(step.out_slots, outs, strict=False):
                    buf[s] = o
            else:
                buf[step.out_slot] = apply_op(
                    step.instr, *[buf[s] for s in step.arg_slots]
                )
            for s in step.release:
                buf[s] = None
        self.stats.eager_calls += 1
        return {name: buf[s] for name, s in self._root_binds}

    # ------------------------------------------------------------ traced
    def _count_trace(self):
        self.stats.jit_traces += 1

    def jit_execute(self, feeds: Dict[str, object]) -> Dict[str, object]:
        """Traced replay: the pre-bound loop as a handful of jitted segment
        calls — ``traced_dispatches_per_call`` dispatches instead of one
        per step.

        Bit-identical to ``execute`` (same kernels, same ``apply_op``
        interpreter, same step order, segment boundaries wherever XLA could
        alter library-dot accumulation order).  Only runtime-owned
        intermediate buffers are donated, so caller-held feed arrays (jax
        or numpy) stay valid across calls.
        """
        vals = self._bind_feeds(feeds)
        buf = list(self._template)
        for (name, slot, dtype, shape), v in zip(self._param_binds, vals, strict=False):
            buf[slot] = v
        with warnings.catch_warnings():
            # donation on backends without aliasing support (CPU) only warns
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            for seg in self._segments:
                if type(seg) is _LoopStep:
                    outs = seg.run_traced(
                        [buf[s] for s in seg.arg_slots], self._count_trace
                    )
                    for s, o in zip(seg.out_slots, outs, strict=False):
                        buf[s] = o
                    for s in seg.release:
                        buf[s] = None
                    continue
                if seg.fn is None:
                    seg.build(self._count_trace)
                outs = seg.fn(*[buf[s] for s in seg.in_slots])
                for s, o in zip(seg.out_slots, outs, strict=False):
                    buf[s] = o
                for s in seg.released:
                    buf[s] = None
        self.stats.traced_calls += 1
        return {name: buf[s] for name, s in self._root_binds}


class StitchedExecutable:
    """Runs a compiled FusionPlan through its precomputed ExecutionPlan.

    ``jit_replay=True`` (the default) replays through the single traced
    callable; ``jit_replay=False`` keeps the eager per-step loop — the
    oracle the traced path is validated against.

    A ``mesh`` makes this ONE multi-device plan: the same pre-bound step
    loop is traced once under ``shard_map`` (``trace_steps`` inlined, with
    collective steps lowering to ``lax.psum``-family calls between the
    kernels) and jitted whole.  Feeds and results are then GLOBAL arrays;
    the per-shard view each device runs is exactly the module the compiler
    planned.  Every call — including ``jit_replay=False`` — goes through
    the traced path, because collectives only evaluate where mesh axis
    names are bound.
    """

    def __init__(
        self,
        module: Module,
        plan: FusionPlan,
        kernels: Dict[str, StitchedKernel],  # fusion name -> kernel
        jit_replay: bool = True,
        donate_params=None,
        mesh=None,
        param_layouts=None,
        out_layouts=None,
    ):
        self.module = module
        self.plan = plan
        self.kernels = kernels
        self.jit_replay = jit_replay
        self.execution_plan = ExecutionPlan(
            module, plan, kernels, donate_params=donate_params
        )
        self.mesh = mesh
        self.param_layouts = dict(param_layouts or {})
        self.out_layouts = list(out_layouts) if out_layouts else None
        self._sharded_fn = None
        if mesh is not None:
            self._build_sharded()

    def _build_sharded(self) -> None:
        from .shard import layout_to_pspec, wrap_shard_map

        ep = self.execution_plan
        in_specs = tuple(
            layout_to_pspec(self.param_layouts.get(name))
            for name, _, _, _ in ep._param_binds
        )
        outs = self.out_layouts or [None] * len(ep._root_binds)
        out_specs = tuple(layout_to_pspec(lay) for lay in outs)

        def run(*vals):
            return tuple(ep.trace_steps(list(vals)))

        self._sharded_fn = jax.jit(
            wrap_shard_map(run, self.mesh, in_specs, out_specs)
        )

    def _global_shape(self, name: str, local: Tuple[int, ...]) -> Tuple[int, ...]:
        lay = self.param_layouts.get(name)
        if lay is None:
            return tuple(local)
        sizes = {str(a): int(self.mesh.shape[a]) for a in self.mesh.axis_names}
        out = []
        for d, e in zip(local, lay, strict=False):
            g = 1
            for a in e or ():
                g *= sizes.get(a, 1)
            out.append(d * g)
        return tuple(out)

    def sharded_execute(self, feeds: Dict[str, object]) -> Dict[str, object]:
        """One dispatch of the whole multi-device plan on global feeds."""
        ep = self.execution_plan
        vals = []
        for name, slot, dtype, shape in ep._param_binds:
            if name not in feeds:
                raise KeyError(f"missing feed for parameter {name}")
            v = jnp.asarray(feeds[name], dtype=dtype)
            want = self._global_shape(name, shape)
            if tuple(v.shape) != want:
                raise ValueError(
                    f"{name}: global feed shape {tuple(v.shape)} != {want} "
                    f"(per-shard {tuple(shape)})"
                )
            vals.append(v)
        outs = self._sharded_fn(*vals)
        ep.stats.traced_calls += 1
        return {name: o for (name, _), o in zip(ep._root_binds, outs, strict=False)}

    def launch_stats(self) -> LaunchStats:
        st = LaunchStats()
        st.stitched_kernels = len(self.plan.fusions)
        st.standalone_kernels = sum(
            1 for s in self.plan.standalone
            if not s.is_library_call
            and not s.is_collective
            and s.opcode not in ("call", "get")
        )
        st.library_calls = self.plan.num_library_calls
        st.collective_calls = self.plan.num_collectives
        rt = self.execution_plan.stats
        st.loop_calls = rt.loop_calls
        st.traced_calls = rt.traced_calls
        st.eager_calls = rt.eager_calls
        st.jit_traces = rt.jit_traces
        st.eager_dispatches_per_call = rt.eager_dispatches_per_call
        st.traced_dispatches_per_call = (
            1 if self.mesh is not None else rt.traced_dispatches_per_call
        )
        st.donated_buffers = rt.donated_buffers
        return st

    def execute_eager(self, feeds: Dict[str, object]) -> Dict[str, object]:
        if self.mesh is not None:
            return self.sharded_execute(feeds)
        return self.execution_plan.execute(feeds)

    def jit_execute(self, feeds: Dict[str, object]) -> Dict[str, object]:
        if self.mesh is not None:
            return self.sharded_execute(feeds)
        return self.execution_plan.jit_execute(feeds)

    def __call__(self, feeds: Dict[str, object]) -> Dict[str, object]:
        if self.mesh is not None:
            return self.sharded_execute(feeds)
        if self.jit_replay:
            return self.execution_plan.jit_execute(feeds)
        return self.execution_plan.execute(feeds)
