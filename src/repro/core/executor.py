"""Executors: the pure-jnp reference oracle and the stitched runtime.

``reference_execute`` walks the module with ``apply_op`` — the oracle every
generated kernel is validated against.

``StitchedExecutable`` runs a compile-time **ExecutionPlan** instead of
re-walking the module per call: constant-like chains are folded exactly once
at plan-build time, every value that flows between execution units lives in
a flat buffer table with precomputed last-use release points (intermediate
buffers are dropped eagerly), and each unit is pre-bound to its kernel and
operand slots.  The per-call hot path is a flat loop over pre-bound steps —
no graph traversal, no constant re-evaluation, no dict-keyed lookups.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .codegen import StitchedKernel
from .fusion import FusionPlan, constant_like
from .ir import Instruction, Module, apply_op


def reference_execute(module: Module, feeds: Dict[str, object]) -> Dict[str, object]:
    vals: Dict[int, object] = {}
    for instr in module.instructions:
        if instr.opcode == "parameter":
            if instr.name not in feeds:
                raise KeyError(f"missing feed for parameter {instr.name}")
            v = jnp.asarray(feeds[instr.name], dtype=instr.dtype)
            assert tuple(v.shape) == tuple(instr.shape), (
                f"{instr.name}: feed shape {v.shape} != {instr.shape}"
            )
            vals[instr.id] = v
        else:
            vals[instr.id] = apply_op(instr, *[vals[o.id] for o in instr.operands])
    return {r.name: vals[r.id] for r in module.roots}


@dataclass
class LaunchStats:
    stitched_kernels: int = 0
    standalone_kernels: int = 0
    library_calls: int = 0

    @property
    def total_non_library(self) -> int:
        return self.stitched_kernels + self.standalone_kernels


def order_units(plan: FusionPlan) -> List[object]:
    """Topological order over execution units (fusions + standalone).

    Fusion groups interleave in instruction order, so firing a group at its
    last member's position is NOT safe; we order groups by their value
    dependences instead (fusion-time cycle checks guarantee the group graph
    is a DAG).
    """
    units: List[object] = list(plan.fusions) + list(plan.standalone)
    unit_of: Dict[int, int] = {}
    for ui, u in enumerate(units):
        members = [u] if isinstance(u, Instruction) else u.members
        for m in members:
            unit_of[m.id] = ui
    deps: List[set] = [set() for _ in units]
    for ui, u in enumerate(units):
        srcs = u.operands if isinstance(u, Instruction) else u.inputs
        for s in srcs:
            if s.id in unit_of and unit_of[s.id] != ui:
                deps[ui].add(unit_of[s.id])
    # Kahn's algorithm (deque: the sorted-list pop(0) was O(n^2))
    indeg = [len(d) for d in deps]
    rdeps: List[set] = [set() for _ in units]
    for ui, d in enumerate(deps):
        for v in d:
            rdeps[v].add(ui)
    ready = deque(sorted(ui for ui, k in enumerate(indeg) if k == 0))
    order = []
    while ready:
        ui = ready.popleft()
        order.append(ui)
        for v in sorted(rdeps[ui]):
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if len(order) != len(units):
        raise RuntimeError("cyclic fusion plan — fusion cycle check failed")
    return [units[ui] for ui in order]


class _KernelStep:
    """One stitched-kernel launch, pre-bound to its buffer slots."""

    __slots__ = ("kernel", "arg_slots", "out_slots", "release")

    def __init__(self, kernel: StitchedKernel, arg_slots, out_slots):
        self.kernel = kernel
        self.arg_slots = arg_slots
        self.out_slots = out_slots
        self.release: List[int] = []


class _OpStep:
    """One standalone instruction (library dot etc.), pre-bound."""

    __slots__ = ("instr", "arg_slots", "out_slot", "release")

    def __init__(self, instr: Instruction, arg_slots, out_slot):
        self.instr = instr
        self.arg_slots = arg_slots
        self.out_slot = out_slot
        self.release: List[int] = []


class ExecutionPlan:
    """Precomputed run recipe for a compiled FusionPlan.

    Built once at compile time:
      * constant-like chains are evaluated here (``fold_evals`` counts the
        evaluations — they never recur at call time);
      * a flat buffer table holds every inter-unit value; slots are released
        (set to None) right after their last consuming step;
      * each step carries its kernel/instruction and operand slot indices.
    """

    def __init__(
        self,
        module: Module,
        plan: FusionPlan,
        kernels: Dict[str, StitchedKernel],
    ):
        member_ids = {m.id for f in plan.fusions for m in f.members}
        covered = member_ids | {s.id for s in plan.standalone}

        units = order_units(plan)

        # ---- which values must live in the buffer table -------------------
        needed: set = {r.id for r in module.roots}
        for u in units:
            if isinstance(u, Instruction):
                needed.update(o.id for o in u.operands)
            else:
                needed.update(i.id for i in kernels[u.name].inputs)

        slot_of: Dict[int, int] = {}

        def new_slot(instr_id: int) -> int:
            slot_of[instr_id] = len(slot_of)
            return slot_of[instr_id]

        # ---- parameters + compile-time constant folding -------------------
        self.fold_evals = 0
        folded_vals: Dict[int, object] = {}

        def fold(instr: Instruction):
            if instr.id in folded_vals:
                return folded_vals[instr.id]
            v = apply_op(instr, *[fold(o) for o in instr.operands])
            self.fold_evals += 1
            folded_vals[instr.id] = v
            return v

        self._param_binds: List[Tuple[str, int, object, Tuple[int, ...]]] = []
        template_fill: List[Tuple[int, object]] = []
        for instr in module.instructions:
            if instr.opcode == "parameter":
                s = new_slot(instr.id)
                self._param_binds.append(
                    (instr.name, s, instr.dtype, tuple(instr.shape))
                )
            elif instr.id not in covered:
                if not (instr.opcode == "constant" or constant_like(instr)):
                    raise RuntimeError(
                        f"{instr.name}: uncovered non-constant instruction"
                    )
                if instr.id in needed:
                    template_fill.append((new_slot(instr.id), fold(instr)))

        # ---- pre-bound steps in unit order ---------------------------------
        self.steps: List[object] = []
        for u in units:
            if isinstance(u, Instruction):
                arg_slots = [slot_of[o.id] for o in u.operands]
                self.steps.append(_OpStep(u, arg_slots, new_slot(u.id)))
            else:
                k = kernels[u.name]
                arg_slots = [slot_of[i.id] for i in k.inputs]
                out_slots = [new_slot(r.id) for r in k.outputs]
                self.steps.append(_KernelStep(k, arg_slots, out_slots))

        self.num_slots = len(slot_of)
        self._root_binds: List[Tuple[str, int]] = [
            (r.name, slot_of[r.id]) for r in module.roots
        ]

        # ---- eager-release points: free a slot after its last read ---------
        keep = {s for _, s in self._root_binds}
        last_read: Dict[int, int] = {}
        for si, step in enumerate(self.steps):
            for s in step.arg_slots:
                last_read[s] = si
        for s, si in last_read.items():
            if s not in keep:
                self.steps[si].release.append(s)

        template: List[Optional[object]] = [None] * self.num_slots
        for s, v in template_fill:
            template[s] = v
        self._template = template

    @property
    def num_folded(self) -> int:
        return sum(1 for v in self._template if v is not None)

    def execute(self, feeds: Dict[str, object]) -> Dict[str, object]:
        buf = list(self._template)
        for name, slot, dtype, shape in self._param_binds:
            v = jnp.asarray(feeds[name], dtype=dtype)
            if tuple(v.shape) != shape:
                raise ValueError(f"{name}: feed shape {v.shape} != {shape}")
            buf[slot] = v
        for step in self.steps:
            if type(step) is _KernelStep:
                outs = step.kernel(*[buf[s] for s in step.arg_slots])
                for s, o in zip(step.out_slots, outs):
                    buf[s] = o
            else:
                buf[step.out_slot] = apply_op(
                    step.instr, *[buf[s] for s in step.arg_slots]
                )
            for s in step.release:
                buf[s] = None
        return {name: buf[s] for name, s in self._root_binds}


class StitchedExecutable:
    """Runs a compiled FusionPlan through its precomputed ExecutionPlan."""

    def __init__(
        self,
        module: Module,
        plan: FusionPlan,
        kernels: Dict[str, StitchedKernel],  # fusion name -> kernel
    ):
        self.module = module
        self.plan = plan
        self.kernels = kernels
        self.execution_plan = ExecutionPlan(module, plan, kernels)

    def launch_stats(self) -> LaunchStats:
        st = LaunchStats()
        st.stitched_kernels = len(self.plan.fusions)
        st.standalone_kernels = sum(
            1 for s in self.plan.standalone if not s.is_library_call
        )
        st.library_calls = self.plan.num_library_calls
        return st

    def __call__(self, feeds: Dict[str, object]) -> Dict[str, object]:
        return self.execution_plan.execute(feeds)
