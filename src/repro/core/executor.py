"""Executors: the pure-jnp reference oracle and the stitched runtime.

``reference_execute`` walks the module with ``apply_op`` — the oracle every
generated kernel is validated against.

``StitchedExecutable`` runs the compiled fusion plan: stitched Pallas kernels
for fused computations, direct XLA dispatch for standalone instructions
(library dots).  It counts kernel launches — the paper's Fig-7 metric.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from .codegen import StitchedKernel
from .fusion import FusionPlan
from .ir import Instruction, Module, apply_op


def reference_execute(module: Module, feeds: Dict[str, object]) -> Dict[str, object]:
    vals: Dict[int, object] = {}
    for instr in module.instructions:
        if instr.opcode == "parameter":
            if instr.name not in feeds:
                raise KeyError(f"missing feed for parameter {instr.name}")
            v = jnp.asarray(feeds[instr.name], dtype=instr.dtype)
            assert tuple(v.shape) == tuple(instr.shape), (
                f"{instr.name}: feed shape {v.shape} != {instr.shape}"
            )
            vals[instr.id] = v
        else:
            vals[instr.id] = apply_op(instr, *[vals[o.id] for o in instr.operands])
    return {r.name: vals[r.id] for r in module.roots}


@dataclass
class LaunchStats:
    stitched_kernels: int = 0
    standalone_kernels: int = 0
    library_calls: int = 0

    @property
    def total_non_library(self) -> int:
        return self.stitched_kernels + self.standalone_kernels


class StitchedExecutable:
    """Runs a compiled FusionPlan; one stitched kernel per fusion."""

    def __init__(
        self,
        module: Module,
        plan: FusionPlan,
        kernels: Dict[str, StitchedKernel],  # fusion name -> kernel
    ):
        self.module = module
        self.plan = plan
        self.kernels = kernels
        self._member_ids = {m.id for f in plan.fusions for m in f.members}
        self._schedule = self._build_schedule()

    def _build_schedule(self):
        """Topological order over execution units (fusions + standalone).

        Fusion groups interleave in instruction order, so firing a group at
        its last member's position is NOT safe; we order groups by their
        value dependences instead (fusion-time cycle checks guarantee the
        group graph is a DAG).
        """
        units: List[object] = list(self.plan.fusions) + list(self.plan.standalone)
        unit_of: Dict[int, int] = {}
        for ui, u in enumerate(units):
            members = [u] if isinstance(u, Instruction) else u.members
            for m in members:
                unit_of[m.id] = ui
        deps: List[set] = [set() for _ in units]
        for ui, u in enumerate(units):
            srcs = u.operands if isinstance(u, Instruction) else u.inputs
            for s in srcs:
                if s.id in unit_of and unit_of[s.id] != ui:
                    deps[ui].add(unit_of[s.id])
        # Kahn's algorithm
        indeg = [len(d) for d in deps]
        rdeps: List[set] = [set() for _ in units]
        for ui, d in enumerate(deps):
            for v in d:
                rdeps[v].add(ui)
        ready = sorted(ui for ui, k in enumerate(indeg) if k == 0)
        order = []
        while ready:
            ui = ready.pop(0)
            order.append(ui)
            for v in sorted(rdeps[ui]):
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        if len(order) != len(units):
            raise RuntimeError("cyclic fusion plan — fusion cycle check failed")
        return [units[ui] for ui in order]

    def launch_stats(self) -> LaunchStats:
        st = LaunchStats()
        st.stitched_kernels = len(self.plan.fusions)
        st.standalone_kernels = sum(
            1 for s in self.plan.standalone if not s.is_library_call
        )
        st.library_calls = self.plan.num_library_calls
        return st

    def __call__(self, feeds: Dict[str, object]) -> Dict[str, object]:
        from .fusion import constant_like

        covered = self._member_ids | {s.id for s in self.plan.standalone}
        vals: Dict[int, object] = {}
        for instr in self.module.instructions:
            if instr.opcode == "parameter":
                vals[instr.id] = jnp.asarray(feeds[instr.name], dtype=instr.dtype)
            elif instr.id not in covered and (
                instr.opcode == "constant" or constant_like(instr)
            ):
                # free (compile-time-foldable) chain — no kernel launch
                vals[instr.id] = apply_op(
                    instr, *[vals[o.id] for o in instr.operands]
                )
        for unit in self._schedule:
            if isinstance(unit, Instruction):  # standalone instruction
                vals[unit.id] = apply_op(
                    unit, *[vals[o.id] for o in unit.operands]
                )
            else:                              # fused computation
                kernel = self.kernels[unit.name]
                args = [vals[i.id] for i in kernel.inputs]
                outs = kernel(*args)
                for r, o in zip(kernel.outputs, outs):
                    vals[r.id] = o
        return {r.name: vals[r.id] for r in self.module.roots}
