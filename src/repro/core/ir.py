"""StitchIR — an HloModule-like tensor IR for FusionStitching.

The paper operates on XLA HloModules restricted to four op families:
elementwise, shape modulation (reshape/bitcast/transpose/broadcast),
reduction, and BatchMatMul.  StitchIR mirrors that op set (plus the small
extras the paper's benchmark graphs need: concat, select, gather, iota,
constants) and provides:

  * ``Instruction`` / ``Module``   — the graph.
  * ``GraphBuilder`` + ``Tensor``  — a jnp-like tracing frontend.
  * ``apply_op``                   — one jnp interpreter for a single
    instruction, shared by the reference executor *and* the Pallas kernel
    body emitter, so the oracle and the generated kernels are consistent
    by construction.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Op taxonomy (paper §2.1)
# --------------------------------------------------------------------------

ELEMENTWISE_UNARY: Dict[str, Callable] = {
    "exp": jnp.exp,
    "log": jnp.log,
    "neg": jnp.negative,
    "abs": jnp.abs,
    "tanh": jnp.tanh,
    "sqrt": jnp.sqrt,
    "rsqrt": jax.lax.rsqrt,
    "sigmoid": jax.nn.sigmoid,
    "softplus": jax.nn.softplus,
    "sign": jnp.sign,
    "floor": jnp.floor,
    "not": jnp.logical_not,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "square": jnp.square,
    "reciprocal": lambda x: 1.0 / x,
    "cos": jnp.cos,
    "sin": jnp.sin,
}

ELEMENTWISE_BINARY: Dict[str, Callable] = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "pow": jnp.power,
    "lt": jnp.less,
    "le": jnp.less_equal,
    "gt": jnp.greater,
    "ge": jnp.greater_equal,
    "eq": jnp.equal,
    "ne": jnp.not_equal,
    "and": jnp.logical_and,
    "or": jnp.logical_or,
}

# Ops the paper calls "expensive elementwise" (§5.1.1): transcendental or
# division-class VPU ops whose recomputation (thread composition) is costly.
EXPENSIVE_ELEMENTWISE = frozenset(
    {
        "exp", "log", "div", "tanh", "sqrt", "rsqrt", "sigmoid", "softplus",
        "pow", "silu", "gelu", "reciprocal", "cos", "sin",
    }
)

REDUCE_KINDS: Dict[str, Callable] = {
    "sum": jnp.sum,
    "max": jnp.max,
    "min": jnp.min,
    "prod": jnp.prod,
    "mean": jnp.mean,
}

SHAPE_OPS = frozenset({"reshape", "bitcast", "transpose", "broadcast"})

# Cross-device collectives (shard-aware compilation).  These are real
# instructions — not annotations — because they are schedule breaks: a
# collective synchronizes the mesh, so no kernel may fuse across one.  The
# planner leaves them standalone (they are deliberately NOT in
# ``fusion.FUSABLE_OPCODES``) and the executor replays them as
# ``lax.psum``-family calls inside the plan's ``shard_map`` trace.
COLLECTIVE_OPCODES = frozenset({"all_reduce", "all_gather", "reduce_scatter"})

_COMPARE_FNS = frozenset({"lt", "le", "gt", "ge", "eq", "ne", "and", "or", "not"})


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


# --------------------------------------------------------------------------
# Instruction
# --------------------------------------------------------------------------

_uid = itertools.count()


@dataclass(eq=False)
class Instruction:
    opcode: str
    shape: Tuple[int, ...]
    dtype: Any
    operands: List["Instruction"] = field(default_factory=list)
    attrs: Dict[str, Any] = field(default_factory=dict)
    name: str = ""
    id: int = field(default_factory=lambda: next(_uid))
    users: List["Instruction"] = field(default_factory=list, repr=False)

    def __post_init__(self):
        if not self.name:
            tag = self.attrs.get("fn", self.attrs.get("kind", self.opcode))
            self.name = f"{tag}.{self.id}"
        for op in self.operands:
            op.users.append(self)

    # -- convenience ------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def num_elements(self) -> int:
        return _prod(self.shape)

    @property
    def bytesize(self) -> int:
        return self.num_elements * np.dtype(self.dtype).itemsize

    @property
    def is_elementwise(self) -> bool:
        return self.opcode in ("elementwise", "select")

    @property
    def is_expensive(self) -> bool:
        return (
            self.opcode == "elementwise"
            and self.attrs.get("fn") in EXPENSIVE_ELEMENTWISE
        )

    @property
    def is_library_call(self) -> bool:
        """True for dots the user did NOT mark fusable (cuBLAS analogue)."""
        return self.opcode == "dot" and not self.attrs.get("fusable", False)

    @property
    def is_collective(self) -> bool:
        """True for cross-device collectives (all_reduce & friends) — ICI
        traffic, not kernel launches; never fused, never counted as kernels."""
        return self.opcode in COLLECTIVE_OPCODES

    def footprint_bytes(self) -> int:
        """Memory IO footprint: bytes read + bytes written (paper Fig. 1)."""
        return self.bytesize + sum(o.bytesize for o in self.operands)

    def __hash__(self):
        return self.id

    def __repr__(self):
        ops = ", ".join(o.name for o in self.operands)
        attrs = self.attrs
        if self.opcode == "call":
            # the body Module (and its compiled form) would render multiline
            attrs = {
                "kind": attrs.get("kind"),
                "body": getattr(attrs.get("body"), "name", None),
                "trip_count": attrs.get("trip_count"),
                "num_carry": attrs.get("num_carry"),
                "reverse": attrs.get("reverse"),
            }
        return f"%{self.name}: {np.dtype(self.dtype).name}{list(self.shape)} = {self.opcode}({ops}) {attrs or ''}"


# --------------------------------------------------------------------------
# Module
# --------------------------------------------------------------------------


class Module:
    """A StitchIR computation graph. Instructions are stored topologically
    (creation order — operands always precede users)."""

    def __init__(self, name: str = "module"):
        self.name = name
        self.instructions: List[Instruction] = []
        self.parameters: List[Instruction] = []

    def add(self, instr: Instruction) -> Instruction:
        if instr.opcode == "parameter":
            if any(p.name == instr.name for p in self.parameters):
                raise ValueError(
                    f"duplicate parameter name {instr.name!r} in module "
                    f"{self.name!r} — parameter names key the feed dict, so "
                    "a later parameter would silently shadow the earlier one"
                )
            self.parameters.append(instr)
        self.instructions.append(instr)
        return instr

    @property
    def roots(self) -> List[Instruction]:
        """Sink instructions (no users) — the module outputs."""
        return [i for i in self.instructions if not i.users]

    def verify(self) -> None:
        """Full IR well-formedness check, delegated to the verifier's IR
        family (``core/verify.py``): def-before-use, storage order,
        operand/user back-edge symmetry, unique ids, shape AND dtype
        re-inference, attr-declared contracts.  Raises
        ``VerificationError`` (a ``ValueError``) on the first batch of
        violations."""
        from .verify import VerificationError, verify_module

        diags = [d for d in verify_module(self) if d.severity == "error"]
        if diags:
            raise VerificationError(diags)

    def __repr__(self):
        lines = [f"module {self.name} {{"]
        lines += [f"  {i!r}" for i in self.instructions]
        lines.append("}")
        return "\n".join(lines)


def infer_shape(opcode, operand_shapes, attrs) -> Optional[Tuple[int, ...]]:
    if opcode in ("parameter", "constant", "iota"):
        return None  # shape is intrinsic
    if opcode in ("call", "get"):
        return None  # multi-output loop call / projection: shapes in attrs
    if opcode == "elementwise":
        return tuple(operand_shapes[0])
    if opcode == "select":
        return tuple(operand_shapes[1])
    if opcode in ("reshape", "bitcast"):
        return tuple(attrs["new_shape"])
    if opcode == "transpose":
        perm = attrs["perm"]
        s = operand_shapes[0]
        return tuple(s[p] for p in perm)
    if opcode == "broadcast":
        return tuple(attrs["out_shape"])
    if opcode == "reduce":
        dims = set(attrs["dims"])
        return tuple(d for i, d in enumerate(operand_shapes[0]) if i not in dims)
    if opcode == "dot":
        lhs, rhs = operand_shapes
        assert lhs[:-2] == rhs[:-2], f"batch dims mismatch {lhs} x {rhs}"
        assert lhs[-1] == rhs[-2], f"contract mismatch {lhs} x {rhs}"
        return tuple(lhs[:-1]) + (rhs[-1],)
    if opcode == "concat":
        dim = attrs["dim"]
        out = list(operand_shapes[0])
        out[dim] = sum(s[dim] for s in operand_shapes)
        return tuple(out)
    if opcode == "gather":
        table, idx = operand_shapes
        return tuple(idx) + tuple(table[1:])
    if opcode == "all_reduce":
        return tuple(operand_shapes[0])
    if opcode == "all_gather":
        s = list(operand_shapes[0])
        s[attrs["dim"]] *= int(attrs["group_size"])
        return tuple(s)
    if opcode == "reduce_scatter":
        s = list(operand_shapes[0])
        dim, g = attrs["dim"], int(attrs["group_size"])
        if s[dim] % g:
            raise ValueError(
                f"reduce_scatter dim {dim} of size {s[dim]} not divisible by "
                f"group size {g}"
            )
        s[dim] //= g
        return tuple(s)
    raise ValueError(f"unknown opcode {opcode}")


def infer_dtype(opcode, operand_dtypes, attrs) -> Optional[Any]:
    """The dtype counterpart of ``infer_shape``: what dtype this opcode
    produces from its operands, or None where the dtype is intrinsic or
    attr-declared (parameter/constant/iota, call/get, ``convert`` casts).

    Mirrors the ``GraphBuilder`` conventions: compare fns yield bool,
    ``select`` follows its value operands, ``dot``/``concat``/``gather``
    and every shape op follow their primary operand.
    """
    if opcode in ("parameter", "constant", "iota", "call", "get"):
        return None  # intrinsic / declared in attrs
    if opcode == "elementwise":
        fn = attrs.get("fn")
        if fn in _COMPARE_FNS:
            return np.dtype(bool)
        if fn == "convert":
            return None  # cast target IS the instruction's own dtype
        return np.dtype(operand_dtypes[0])
    if opcode == "select":
        return np.dtype(operand_dtypes[1])
    if not operand_dtypes:
        return None
    # reshape/bitcast/transpose/broadcast/reduce/concat/gather/dot and the
    # collectives all carry their primary operand's dtype through
    return np.dtype(operand_dtypes[0])


# --------------------------------------------------------------------------
# The single-op jnp interpreter (shared oracle <-> codegen)
# --------------------------------------------------------------------------


def apply_op(instr: Instruction, *vals, shape_override: Optional[Tuple[int, ...]] = None):
    """Evaluate one instruction given operand *values* (full arrays in the
    reference executor; VMEM block tiles inside generated Pallas kernels).

    ``shape_override`` lets the codegen evaluate shape-modulating ops on a
    *block* of the output space rather than the whole output.
    """
    op = instr.opcode
    a = instr.attrs
    if op == "elementwise":
        fn = a["fn"]
        if fn == "convert":
            # dtype cast: the target dtype is the instruction's own dtype
            return vals[0].astype(instr.dtype)
        if fn in ELEMENTWISE_UNARY:
            return ELEMENTWISE_UNARY[fn](vals[0])
        out = ELEMENTWISE_BINARY[fn](vals[0], vals[1])
        return out
    if op == "select":
        return jnp.where(vals[0], vals[1], vals[2])
    if op in ("reshape", "bitcast"):
        return jnp.reshape(vals[0], shape_override or a["new_shape"])
    if op == "transpose":
        return jnp.transpose(vals[0], a["perm"])
    if op == "broadcast":
        out_shape = shape_override or a["out_shape"]
        dims = a["dims"]
        # XLA broadcast_in_dim semantics
        return jax.lax.broadcast_in_dim(vals[0], out_shape, dims)
    if op == "reduce":
        kind = a["kind"]
        return REDUCE_KINDS[kind](vals[0], axis=tuple(a["dims"]))
    if op == "dot":
        lhs, rhs = vals
        return jax.lax.dot_general(
            lhs,
            rhs,
            dimension_numbers=(
                ((lhs.ndim - 1,), (rhs.ndim - 2,)),
                (tuple(range(lhs.ndim - 2)), tuple(range(rhs.ndim - 2))),
            ),
            preferred_element_type=jnp.float32
            if np.dtype(instr.dtype) == np.float32
            else None,
        ).astype(instr.dtype)
    if op == "concat":
        return jnp.concatenate(vals, axis=a["dim"])
    if op == "gather":
        return jnp.take(vals[0], vals[1].astype(jnp.int32), axis=0)
    if op == "iota":
        shape = shape_override or instr.shape
        return jax.lax.broadcasted_iota(instr.dtype, shape, a["dim"])
    if op == "constant":
        return jnp.asarray(a["value"], dtype=instr.dtype)
    if op == "call":
        return _apply_call(instr, vals)
    if op == "get":
        return vals[0][a["index"]]
    # Collectives are only evaluable when the plan trace runs under
    # ``shard_map`` (the mesh axes in ``attrs["axes"]`` must be bound).
    if op == "all_reduce":
        return jax.lax.psum(vals[0], a["axes"])
    if op == "all_gather":
        return jax.lax.all_gather(vals[0], a["axes"], axis=a["dim"], tiled=True)
    if op == "reduce_scatter":
        return jax.lax.psum_scatter(
            vals[0], a["axes"], scatter_dimension=a["dim"], tiled=True
        )
    raise ValueError(f"cannot apply {op}")


def _interpret_module(module: "Module", feeds_by_order: Sequence) -> List:
    """Reference walk of a (loop-body) module with parameter values given
    positionally in parameter-creation order; returns root values in
    ``module.roots`` order.  Kept here (not ``executor.reference_execute``)
    so ``apply_op`` stays self-contained for the oracle."""
    vals: Dict[int, object] = {}
    params = iter(feeds_by_order)
    for instr in module.instructions:
        if instr.opcode == "parameter":
            vals[instr.id] = jnp.asarray(next(params), dtype=instr.dtype)
        else:
            vals[instr.id] = apply_op(
                instr, *[vals[o.id] for o in instr.operands]
            )
    return [vals[r.id] for r in module.roots]


def _apply_call(instr: Instruction, vals) -> Tuple:
    """Reference semantics of a ``call`` loop: run the body module
    ``trip_count`` times threading carries, stack the per-iteration outputs.
    Returns ALL logical outputs ``(carries..., stacked ys...)`` — ``get``
    projects one of them."""
    a = instr.attrs
    body: "Module" = a["body"]
    nc, k = int(a["num_consts"]), int(a["num_carry"])
    trip = int(a["trip_count"])
    reverse = bool(a.get("reverse", False))
    out_order = list(a["out_order"])           # logical output -> root pos
    consts = list(vals[:nc])
    carry = list(vals[nc:nc + k])
    xs = list(vals[nc + k:])
    n_y = len(out_order) - k
    ys: List[List] = [[] for _ in range(n_y)]
    steps = range(trip - 1, -1, -1) if reverse else range(trip)
    for t in steps:
        roots = _interpret_module(
            body, consts + carry + [x[t] for x in xs]
        )
        ordered = [roots[j] for j in out_order]
        carry = ordered[:k]
        for j in range(n_y):
            ys[j].append(ordered[k + j])
    if reverse:
        ys = [list(reversed(col)) for col in ys]
    stacked = []
    for j in range(n_y):
        if ys[j]:
            stacked.append(jnp.stack(ys[j]))
        else:  # zero-trip loop: empty stacked output
            shape = tuple(a["out_shapes"][k + j])
            stacked.append(jnp.zeros(shape, dtype=a["out_dtypes"][k + j]))
    return tuple(carry + stacked)


# --------------------------------------------------------------------------
# GraphBuilder + Tensor tracing frontend
# --------------------------------------------------------------------------


class Tensor:
    """A traced handle; supports jnp-style operator overloading."""

    __slots__ = ("builder", "instr")
    __array_priority__ = 100  # beat numpy broadcasting

    def __init__(self, builder: "GraphBuilder", instr: Instruction):
        self.builder = builder
        self.instr = instr

    @property
    def shape(self):
        return self.instr.shape

    @property
    def dtype(self):
        return self.instr.dtype

    @property
    def ndim(self):
        return len(self.instr.shape)

    def _b(self, other, fn, reverse=False):
        other = self.builder.lift(other, like=self)
        lhs, rhs = (other, self) if reverse else (self, other)
        return self.builder.binary(fn, lhs, rhs)

    def __add__(self, o): return self._b(o, "add")
    def __radd__(self, o): return self._b(o, "add", True)
    def __sub__(self, o): return self._b(o, "sub")
    def __rsub__(self, o): return self._b(o, "sub", True)
    def __mul__(self, o): return self._b(o, "mul")
    def __rmul__(self, o): return self._b(o, "mul", True)
    def __truediv__(self, o): return self._b(o, "div")
    def __rtruediv__(self, o): return self._b(o, "div", True)
    def __pow__(self, o): return self._b(o, "pow")
    def __neg__(self): return self.builder.unary("neg", self)
    def __lt__(self, o): return self._b(o, "lt")
    def __le__(self, o): return self._b(o, "le")
    def __gt__(self, o): return self._b(o, "gt")
    def __ge__(self, o): return self._b(o, "ge")

    def reshape(self, *shape):
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        return self.builder.reshape(self, shape)

    def transpose(self, perm):
        return self.builder.transpose(self, perm)

    def sum(self, dims, keepdims=False):
        return self.builder.reduce(self, dims, "sum", keepdims=keepdims)

    def max(self, dims, keepdims=False):
        return self.builder.reduce(self, dims, "max", keepdims=keepdims)

    def __repr__(self):
        return f"Tensor({self.instr.name}: {np.dtype(self.dtype).name}{list(self.shape)})"


class GraphBuilder:
    def __init__(self, name: str = "module"):
        self.module = Module(name)

    # -- creation ---------------------------------------------------------
    def _emit(self, opcode, shape, dtype, operands=(), attrs=None, name="") -> Tensor:
        instr = Instruction(
            opcode,
            tuple(int(s) for s in shape),
            np.dtype(dtype),
            [t.instr for t in operands],
            dict(attrs or {}),
            name=name,
        )
        self.module.add(instr)
        return Tensor(self, instr)

    def parameter(self, name, shape, dtype=jnp.float32) -> Tensor:
        return self._emit("parameter", shape, dtype, name=name)

    def constant(self, value, dtype=None) -> Tensor:
        arr = np.asarray(value, dtype=dtype)
        return self._emit("constant", arr.shape, arr.dtype, attrs={"value": arr})

    def lift(self, value, like: Tensor) -> Tensor:
        """Lift a python scalar / ndarray to a Tensor broadcast to ``like``."""
        if isinstance(value, Tensor):
            if value.shape == like.shape:
                return value
            if value.ndim == 0:
                return self.broadcast(value, like.shape, dims=())
            raise ValueError(f"shape mismatch {value.shape} vs {like.shape}")
        arr = np.asarray(value, dtype=like.dtype)
        c = self.constant(arr)
        if arr.shape == tuple(like.shape):
            return c
        if arr.ndim == 0:
            return self.broadcast(c, like.shape, dims=())
        raise ValueError(f"cannot lift shape {arr.shape} to {like.shape}")

    # -- op builders --------------------------------------------------------
    def unary(self, fn, x: Tensor) -> Tensor:
        dtype = jnp.bool_ if fn in _COMPARE_FNS else x.dtype
        return self._emit("elementwise", x.shape, dtype, [x], {"fn": fn})

    def binary(self, fn, x: Tensor, y: Tensor) -> Tensor:
        assert tuple(x.shape) == tuple(y.shape), f"{fn}: {x.shape} vs {y.shape}"
        dtype = jnp.bool_ if fn in _COMPARE_FNS else x.dtype
        return self._emit("elementwise", x.shape, dtype, [x, y], {"fn": fn})

    def select(self, pred: Tensor, t: Tensor, f: Tensor) -> Tensor:
        return self._emit("select", t.shape, t.dtype, [pred, t, f])

    def convert(self, x: Tensor, dtype) -> Tensor:
        """Elementwise dtype cast (``convert_element_type``); identity when
        the dtype already matches."""
        dtype = np.dtype(dtype)
        if np.dtype(x.dtype) == dtype:
            return x
        return self._emit("elementwise", x.shape, dtype, [x], {"fn": "convert"})

    def reshape(self, x: Tensor, new_shape) -> Tensor:
        new_shape = tuple(int(s) for s in new_shape)
        assert _prod(new_shape) == x.instr.num_elements
        return self._emit("reshape", new_shape, x.dtype, [x], {"new_shape": new_shape})

    def bitcast(self, x: Tensor, new_shape) -> Tensor:
        new_shape = tuple(int(s) for s in new_shape)
        assert _prod(new_shape) == x.instr.num_elements
        return self._emit("bitcast", new_shape, x.dtype, [x], {"new_shape": new_shape})

    def transpose(self, x: Tensor, perm) -> Tensor:
        perm = tuple(perm)
        shape = tuple(x.shape[p] for p in perm)
        return self._emit("transpose", shape, x.dtype, [x], {"perm": perm})

    def broadcast(self, x: Tensor, out_shape, dims) -> Tensor:
        out_shape, dims = tuple(out_shape), tuple(dims)
        for i, d in enumerate(dims):
            assert x.shape[i] in (1, out_shape[d])
        return self._emit(
            "broadcast", out_shape, x.dtype, [x], {"out_shape": out_shape, "dims": dims}
        )

    def broadcast_like(self, x: Tensor, like: Tensor, dims) -> Tensor:
        return self.broadcast(x, like.shape, dims)

    def reduce(self, x: Tensor, dims, kind="sum", keepdims=False) -> Tensor:
        if isinstance(dims, int):
            dims = (dims,)
        dims = tuple(sorted(d % x.ndim for d in dims))
        out_shape = tuple(s for i, s in enumerate(x.shape) if i not in dims)
        r = self._emit("reduce", out_shape, x.dtype, [x], {"dims": dims, "kind": kind})
        if keepdims:
            kept = [i for i in range(x.ndim) if i not in dims]
            r = self.broadcast(r, tuple(s if i not in dims else 1 for i, s in enumerate(x.shape)), tuple(kept))
        return r

    def dot(self, lhs: Tensor, rhs: Tensor, fusable=False) -> Tensor:
        shape = infer_shape("dot", [lhs.shape, rhs.shape], {})
        return self._emit("dot", shape, lhs.dtype, [lhs, rhs], {"fusable": fusable})

    def concat(self, xs: Sequence[Tensor], dim: int) -> Tensor:
        shape = infer_shape("concat", [x.shape for x in xs], {"dim": dim})
        return self._emit("concat", shape, xs[0].dtype, list(xs), {"dim": dim})

    def gather(self, table: Tensor, idx: Tensor) -> Tensor:
        shape = tuple(idx.shape) + tuple(table.shape[1:])
        return self._emit("gather", shape, table.dtype, [table, idx])

    def iota(self, shape, dim=0, dtype=jnp.float32) -> Tensor:
        return self._emit("iota", shape, dtype, [], {"dim": dim})

    # -- collectives (valid only inside a shard_map-replayed module) --------
    def all_reduce(self, x: Tensor, axes) -> Tensor:
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        return self._emit("all_reduce", x.shape, x.dtype, [x], {"axes": axes})

    def all_gather(self, x: Tensor, axes, dim: int, group_size: int) -> Tensor:
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        attrs = {"axes": axes, "dim": int(dim), "group_size": int(group_size)}
        shape = infer_shape("all_gather", [x.shape], attrs)
        return self._emit("all_gather", shape, x.dtype, [x], attrs)

    def reduce_scatter(self, x: Tensor, axes, dim: int, group_size: int) -> Tensor:
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        attrs = {"axes": axes, "dim": int(dim), "group_size": int(group_size)}
        shape = infer_shape("reduce_scatter", [x.shape], attrs)
        return self._emit("reduce_scatter", shape, x.dtype, [x], attrs)

    def call_loop(
        self,
        operands: Sequence[Tensor],
        body: Module,
        *,
        trip_count: int,
        num_consts: int,
        num_carry: int,
        out_order: Sequence[int],
        out_shapes: Sequence[Tuple[int, ...]],
        out_dtypes: Sequence[str],
        reverse: bool = False,
        kind: str = "scan",
    ) -> Tensor:
        """A sub-module loop (``lax.scan`` analogue): run ``body``
        ``trip_count`` times.  Operands are ``consts + init_carries +
        stacked xs`` and bind positionally to the body's parameters (in
        creation order).  The instruction's logical outputs are
        ``(final carries..., stacked ys...)``; ``out_order[j]`` locates
        logical output ``j`` among ``body.roots`` (names never enter the
        contract, so structurally identical bodies share compiled plans).
        Project outputs with ``get``."""
        attrs = {
            "kind": kind,
            "body": body,
            "trip_count": int(trip_count),
            "num_consts": int(num_consts),
            "num_carry": int(num_carry),
            "reverse": bool(reverse),
            "out_order": tuple(int(j) for j in out_order),
            "out_shapes": tuple(tuple(int(s) for s in sh) for sh in out_shapes),
            "out_dtypes": tuple(str(np.dtype(d)) for d in out_dtypes),
        }
        return self._emit(
            "call", attrs["out_shapes"][0], attrs["out_dtypes"][0],
            list(operands), attrs,
        )

    def get(self, call: Tensor, index: int) -> Tensor:
        """Project logical output ``index`` of a ``call`` loop."""
        a = call.instr.attrs
        return self._emit(
            "get", a["out_shapes"][index], a["out_dtypes"][index],
            [call], {"index": int(index)},
        )

    # -- named math sugar ---------------------------------------------------
    def exp(self, x): return self.unary("exp", x)
    def log(self, x): return self.unary("log", x)
    def tanh(self, x): return self.unary("tanh", x)
    def sqrt(self, x): return self.unary("sqrt", x)
    def rsqrt(self, x): return self.unary("rsqrt", x)
    def sigmoid(self, x): return self.unary("sigmoid", x)
    def silu(self, x): return self.unary("silu", x)
    def gelu(self, x): return self.unary("gelu", x)
    def square(self, x): return self.unary("square", x)
    def neg(self, x): return self.unary("neg", x)
    def abs(self, x): return self.unary("abs", x)
    def maximum(self, x, y): return self.binary("max", x, self.lift(y, like=x))
    def minimum(self, x, y): return self.binary("min", x, self.lift(y, like=x))

    def softmax(self, x: Tensor, dim: int = -1) -> Tensor:
        """The paper's Figure-3 pattern: max-sub, exp, reduce, divide."""
        dim = dim % x.ndim
        kept = tuple(i for i in range(x.ndim) if i != dim)
        z = x - self.broadcast(self.reduce(x, (dim,), "max"), x.shape, kept)
        e = self.exp(z)
        s = self.reduce(e, (dim,), "sum")
        return e / self.broadcast(s, x.shape, kept)


def trace(fn: Callable, *specs, name: str = "traced") -> Module:
    """Trace a python function of Tensors into a Module.

    ``specs`` are (name, shape, dtype) triples or jax.ShapeDtypeStruct.
    """
    b = GraphBuilder(name)
    args = []
    for i, spec in enumerate(specs):
        if isinstance(spec, jax.ShapeDtypeStruct):
            args.append(b.parameter(f"p{i}", spec.shape, spec.dtype))
        else:
            pname, shape, dtype = spec
            args.append(b.parameter(pname, shape, dtype))
    out = fn(b, *args)
    b.module.verify()
    return b.module
