"""repro.frontend — capture real JAX functions into StitchIR.

``stitch`` is the jit-shaped public entry point (see ``api``);
``lower_jaxpr`` is the jaxpr -> StitchIR lowering it drives (see
``jaxpr_lower``).
"""
from .api import CostEstimate, Lowered, StitchedFunction, stitch
from .jaxpr_lower import (
    BINARY_PRIMS,
    CALL_PRIMS,
    IDENTITY_PRIMS,
    REDUCE_PRIMS,
    STRUCTURAL_PRIMS,
    SUPPORTED_PRIMITIVES,
    UNARY_PRIMS,
    LoweredJaxpr,
    UnsupportedPrimitiveError,
    lower_jaxpr,
)

__all__ = [
    "StitchedFunction",
    "stitch",
    "Lowered",
    "CostEstimate",
    "LoweredJaxpr",
    "UnsupportedPrimitiveError",
    "lower_jaxpr",
    "SUPPORTED_PRIMITIVES",
    "UNARY_PRIMS",
    "BINARY_PRIMS",
    "REDUCE_PRIMS",
    "STRUCTURAL_PRIMS",
    "IDENTITY_PRIMS",
    "CALL_PRIMS",
]
