"""``repro.stitch`` — a ``jax.jit``-shaped frontend for the compiler.

    from repro import stitch

    @stitch
    def attention(q, k, v):
        s = q @ jnp.swapaxes(k, -1, -2) / q.shape[-1] ** 0.5
        p = jax.nn.softmax(s, axis=-1)
        return p @ v

    out = attention(q, k, v)        # traced, lowered, compiled, executed
    print(attention.report())       # kernels / fusion ratio / VMEM plan

``stitch(fn)`` returns a ``StitchedFunction``: calling it traces ``fn`` with
``jax.make_jaxpr`` on the arguments' shapes/dtypes, lowers the jaxpr into
StitchIR (``jaxpr_lower``), runs the unchanged pass pipeline via
``compile_module``, and executes the planned runtime.  Compiled plans are
cached per input-signature (static-argument values + pytree structure +
leaf shapes/dtypes), so repeated calls at the same shapes never recompile,
and the per-function ``KernelCache`` is shared across signatures so a new
shape reuses tuned kernels where fusion signatures coincide.

``jax.jit`` parity surface:

  * ``static_argnums`` / ``static_argnames`` — arguments treated as
    compile-time constants and keyed (by value) into the plan cache;
  * ``donate_argnums`` — positional arguments whose buffers the caller
    relinquishes; the traced replay donates them to XLA where the backend
    supports aliasing;
  * ``stitched.lower(*args)`` — a ``Lowered`` handle with ``.as_text()``,
    ``.num_kernels`` and ``.cost_estimate()``, mirroring
    ``jax.jit(fn).lower(...)`` introspection.

``compile_module``/``trace`` remain the documented low-level path for
hand-built StitchIR.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from ..core.compiler import (
    CompiledModule,
    CompileStats,
    StitchOptions,
    compile_module,
)
from ..core.ir import Module
from ..core.shard import mesh_axes_of, wrap_shard_map
from ..core.signature import KernelCache
from .jaxpr_lower import (
    LoweredJaxpr,
    LoweredShardedJaxpr,
    UnsupportedPrimitiveError,
    lower_jaxpr,
    lower_sharded_jaxpr,
)

_FALLBACK_MODES = ("error", "fallback")


@dataclass
class _PlanEntry:
    """One compiled (or fallen-back) plan for one input signature."""

    lowered: Optional[LoweredJaxpr]      # None => fallback entry
    compiled: Optional[CompiledModule]
    out_tree: Any

    @property
    def is_fallback(self) -> bool:
        return self.lowered is None


def _leaf_spec(leaf) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(np.shape(leaf), jnp.result_type(leaf))


def _int_tuple(v, label: str) -> Tuple[int, ...]:
    if v is None:
        return ()
    if isinstance(v, int):
        v = (v,)
    out = tuple(v)
    if not all(isinstance(i, int) for i in out):
        raise TypeError(f"{label} must be an int or a sequence of ints: {v!r}")
    return out


def _str_tuple(v, label: str) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        v = (v,)
    out = tuple(v)
    if not all(isinstance(s, str) for s in out):
        raise TypeError(f"{label} must be a str or a sequence of strs: {v!r}")
    return out


def _collect_modules(module: Module, acc: List[Module], seen: set) -> None:
    if id(module) in seen:
        return
    seen.add(id(module))
    acc.append(module)
    for instr in module.instructions:
        if instr.opcode == "call":
            _collect_modules(instr.attrs["body"], acc, seen)


@dataclass(frozen=True)
class CostEstimate:
    """Latency estimate for one compiled plan.

    ``analytic_s`` is the pure roofline-model prediction; ``measured_s``
    substitutes on-device timings for the ``measured_kernels`` stitched
    kernels the tuning store had rows for (None when nothing was measured).
    """

    analytic_s: float
    measured_s: Optional[float]
    measured_kernels: int
    num_kernels: int


class Lowered:
    """``jax.jit``-style lowering handle: the captured StitchIR plus lazy
    compilation for introspection (``.as_text()``, ``.num_kernels``,
    ``.cost_estimate()``).  Unknown attributes delegate to ``.module``, so
    existing ``.parameters`` / ``.instructions`` call sites keep working.
    """

    def __init__(
        self,
        lowered: LoweredJaxpr,
        compile_thunk: Callable[[], CompiledModule],
        compiled: Optional[CompiledModule] = None,
    ):
        self._lowered = lowered
        self._compile_thunk = compile_thunk
        self._compiled = compiled

    @property
    def module(self) -> Module:
        return self._lowered.module

    @property
    def param_names(self) -> List[str]:
        return list(self._lowered.param_names)

    def as_text(self) -> str:
        """The module text, loop-body sub-modules appended."""
        mods: List[Module] = []
        _collect_modules(self.module, mods, set())
        return "\n\n".join(repr(m) for m in mods)

    def compile(self) -> CompiledModule:
        if self._compiled is None:
            self._compiled = self._compile_thunk()
        return self._compiled

    @property
    def num_kernels(self) -> int:
        """Total kernels this plan launches code for: stitched + standalone
        + kernels inside unique loop bodies (library dots excluded, as in
        ``CompileStats``)."""
        s = self.compile().stats
        return s.stitched_kernels + s.standalone_kernels + s.sub_kernels

    def cost_estimate(self) -> CostEstimate:
        s = self.compile().stats
        # remainder = standalone ops, library calls, loop bodies — costs not
        # itemized in per-kernel reports
        remainder = s.predicted_time_s - sum(r.cost_s for r in s.reports)
        analytic = remainder + sum(
            r.model_cost_s if r.model_cost_s is not None else r.cost_s
            for r in s.reports
        )
        n_meas = sum(1 for r in s.reports if r.measured_cost_s is not None)
        measured = None
        if n_meas:
            measured = remainder + sum(
                r.measured_cost_s
                if r.measured_cost_s is not None
                else (r.model_cost_s if r.model_cost_s is not None else r.cost_s)
                for r in s.reports
            )
        return CostEstimate(
            analytic_s=analytic,
            measured_s=measured,
            measured_kernels=n_meas,
            num_kernels=self.num_kernels,
        )

    def __getattr__(self, name):
        return getattr(self._lowered.module, name)

    def __repr__(self):
        return f"Lowered({self.module.name}, {len(self.module.instructions)} instructions)"


class StitchedFunction:
    """A JAX function captured into StitchIR and compiled per input shape.

    Attributes/methods of note:
      * ``.options``       — the ``StitchOptions`` this function compiles under
      * ``.stats``         — ``CompileStats`` of the most recent compile
      * ``.lower(*args)``  — a ``Lowered`` introspection handle (no execute)
      * ``.report()``      — human-readable compile report
      * ``.num_compiles`` / ``.num_fallbacks`` — plan-cache accounting
    """

    def __init__(
        self,
        fn: Callable,
        options: Optional[StitchOptions] = None,
        on_unsupported: str = "error",
        name: Optional[str] = None,
        static_argnums: Union[int, Sequence[int], None] = (),
        static_argnames: Union[str, Sequence[str], None] = (),
        donate_argnums: Union[int, Sequence[int], None] = (),
        mesh=None,
        in_specs=None,
        out_specs=None,
    ):
        if not callable(fn):
            raise TypeError(f"stitch() requires a callable, got {type(fn).__name__}")
        if on_unsupported not in _FALLBACK_MODES:
            raise ValueError(
                f"on_unsupported={on_unsupported!r}; valid modes: "
                f"{', '.join(_FALLBACK_MODES)}"
            )
        self._fn = fn
        self.options = options if options is not None else StitchOptions()
        self.mesh = mesh
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.on_unsupported = on_unsupported
        self.name = name or getattr(fn, "__name__", "stitched")
        self.static_argnums = _int_tuple(static_argnums, "static_argnums")
        self.static_argnames = _str_tuple(static_argnames, "static_argnames")
        self.donate_argnums = _int_tuple(donate_argnums, "donate_argnums")
        overlap = set(self.static_argnums) & set(self.donate_argnums)
        if overlap:
            raise ValueError(
                f"static_argnums and donate_argnums cannot intersect: "
                f"{sorted(overlap)}"
            )
        if mesh is not None:
            if in_specs is None or out_specs is None:
                raise ValueError(
                    "stitch(mesh=...) needs in_specs and out_specs — the "
                    "shard_map placement of every argument and output"
                )
            if self.static_argnums or self.static_argnames or self.donate_argnums:
                raise ValueError(
                    "stitch(mesh=...) does not compose with static_argnums/"
                    "static_argnames/donate_argnums yet"
                )
            if not getattr(self.options, "mesh_axes", None):
                self.options = dataclasses.replace(
                    self.options, mesh_axes=mesh_axes_of(mesh)
                )
        elif in_specs is not None or out_specs is not None:
            raise ValueError("in_specs/out_specs require mesh=...")
        self._plans: Dict[Any, _PlanEntry] = {}
        self._kernel_cache = KernelCache(self.options.kernel_cache_path)
        # Shared across this function's per-shape compiles (like the kernel
        # cache): a kernel measured for one input shape guides the planner
        # on the next shape's compile.  Created lazily — most functions
        # never turn autotuning on.
        self._measured_store = None
        self._fallback_jit: Optional[Callable] = None
        self._last: Optional[_PlanEntry] = None
        self.num_compiles = 0
        self.num_fallbacks = 0
        functools.update_wrapper(self, fn)

    # -- static/dynamic argument split ------------------------------------
    def _resolve_nums(self, nums: Tuple[int, ...], n: int, label: str) -> set:
        out = set()
        for i in nums:
            j = i + n if i < 0 else i
            if not 0 <= j < n:
                raise ValueError(
                    f"{label} index {i} is out of range for a call with "
                    f"{n} positional argument(s)"
                )
            out.add(j)
        return out

    def _split(self, args, kwargs):
        """(statics_key, static_positions, dyn_args, dyn_kwargs)."""
        n = len(args)
        static_pos = self._resolve_nums(self.static_argnums, n, "static_argnums") \
            if self.static_argnums else set()
        static_names = set(self.static_argnames) & set(kwargs)
        statics = tuple(
            [(j, args[j]) for j in sorted(static_pos)]
            + [(k, kwargs[k]) for k in sorted(static_names)]
        )
        try:
            hash(statics)
        except TypeError as e:
            bad = [
                f"{tag}={type(v).__name__}" for tag, v in statics
                if not _hashable(v)
            ]
            raise TypeError(
                "Non-hashable static arguments are not supported: "
                + ", ".join(bad)
            ) from e
        dyn_args = tuple(a for i, a in enumerate(args) if i not in static_pos)
        dyn_kwargs = {k: v for k, v in kwargs.items() if k not in static_names}
        return statics, static_pos, dyn_args, dyn_kwargs

    def _donated_param_names(
        self, n_args: int, static_pos: set, dyn_args
    ) -> Optional[frozenset]:
        """Flattened-leaf parameter names covered by ``donate_argnums``.

        Parameters are named ``arg{i}`` over the flattened ``(dyn_args,
        dyn_kwargs)`` leaves, positional leaves first — so per-argument
        leaf counts locate each donated argument's name range."""
        if not self.donate_argnums:
            return None
        donated = self._resolve_nums(self.donate_argnums, n_args, "donate_argnums")
        if donated & static_pos:
            raise ValueError(
                "donate_argnums resolve onto static arguments: "
                f"{sorted(donated & static_pos)}"
            )
        dyn_positions = [i for i in range(n_args) if i not in static_pos]
        names: List[str] = []
        off = 0
        for dyn_idx, orig in enumerate(dyn_positions):
            cnt = len(jax.tree_util.tree_leaves(dyn_args[dyn_idx]))
            if orig in donated:
                names.extend(f"arg{off + k}" for k in range(cnt))
            off += cnt
        return frozenset(names) if names else None

    # -- plan cache -------------------------------------------------------
    def _signature(self, args, kwargs):
        statics, static_pos, dyn_args, dyn_kwargs = self._split(args, kwargs)
        leaves, treedef = jax.tree_util.tree_flatten((dyn_args, dyn_kwargs))
        key = (
            statics,
            treedef,
            tuple(
                (tuple(np.shape(leaf)), str(jnp.result_type(leaf))) for leaf in leaves
            ),
        )
        return key, leaves, static_pos, dyn_args, dyn_kwargs, len(args)

    def _trace(self, args, static_pos, dyn_args, dyn_kwargs, kwargs):
        """jax.make_jaxpr on the dynamic arguments' shapes; static values
        close over the traced function, so they are compile-time constants
        of the captured jaxpr (recompiled per distinct static value via the
        plan-cache key)."""
        n = len(args)
        static_vals = {i: args[i] for i in static_pos}
        static_kw = {
            k: kwargs[k] for k in self.static_argnames if k in kwargs
        }
        fn = self._fn

        def inner(*dyn, **dyn_kw):
            full = []
            it = iter(dyn)
            for i in range(n):
                full.append(static_vals[i] if i in static_vals else next(it))
            kw = dict(static_kw)
            kw.update(dyn_kw)
            return fn(*full, **kw)

        shaped_args, shaped_kwargs = jax.tree_util.tree_map(
            _leaf_spec, (dyn_args, dyn_kwargs)
        )
        if self.mesh is not None:
            # Trace shard_map(fn) at GLOBAL shapes: jax leaves exactly one
            # shard_map eqn whose inner jaxpr is the per-shard computation —
            # that is what lower_sharded_jaxpr compiles.
            inner = wrap_shard_map(
                inner, self.mesh, self.in_specs, self.out_specs
            )
        closed, out_shape = jax.make_jaxpr(inner, return_shape=True)(
            *shaped_args, **shaped_kwargs
        )
        return closed, jax.tree_util.tree_structure(out_shape)

    def _get_measured_store(self):
        if self._measured_store is None and (
            self.options.autotune or self.options.tuning_store_path
        ):
            from ..core.measure import MeasuredCostStore, device_fingerprint

            self._measured_store = MeasuredCostStore(
                self.options.tuning_store_path,
                device_fp=device_fingerprint(
                    interpret=self.options.interpret
                ),
            )
        return self._measured_store

    def _lower(self, closed) -> LoweredJaxpr:
        if self.mesh is not None:
            return lower_sharded_jaxpr(
                closed, name=self.name, fuse_dot=self.options.fuse_dot
            )
        return lower_jaxpr(
            closed, name=self.name, fuse_dot=self.options.fuse_dot
        )

    def _compile_lowered(
        self, lowered: LoweredJaxpr, donate_params: Optional[frozenset]
    ) -> CompiledModule:
        sharded = isinstance(lowered, LoweredShardedJaxpr)
        return compile_module(
            lowered.module, self.options, kernel_cache=self._kernel_cache,
            measured_store=self._get_measured_store(),
            donate_params=donate_params,
            mesh=lowered.mesh if sharded else None,
            param_layouts=lowered.param_layouts if sharded else None,
            out_layouts=lowered.out_layouts if sharded else None,
        )

    def _fallback(self) -> Callable:
        if self._fallback_jit is None:
            if self.mesh is not None:
                # The sharded oracle: the same shard_map placement, compiled
                # whole by XLA — also the bit-parity reference in benchmarks.
                self._fallback_jit = jax.jit(
                    wrap_shard_map(
                        self._fn, self.mesh, self.in_specs, self.out_specs
                    )
                )
            else:
                self._fallback_jit = jax.jit(
                    self._fn,
                    static_argnums=self.static_argnums,
                    static_argnames=self.static_argnames,
                    donate_argnums=self.donate_argnums,
                )
        return self._fallback_jit

    def _compile(
        self, key, args, kwargs, static_pos, dyn_args, dyn_kwargs, n_args
    ) -> _PlanEntry:
        closed, out_tree = self._trace(
            args, static_pos, dyn_args, dyn_kwargs, kwargs
        )
        try:
            lowered = self._lower(closed)
        except UnsupportedPrimitiveError:
            if self.on_unsupported != "fallback":
                raise
            self._fallback()
            self.num_fallbacks += 1
            entry = _PlanEntry(None, None, out_tree)
            self._plans[key] = entry
            return entry
        compiled = self._compile_lowered(
            lowered,
            self._donated_param_names(n_args, static_pos, dyn_args),
        )
        self.num_compiles += 1
        entry = _PlanEntry(lowered, compiled, out_tree)
        self._plans[key] = entry
        self._last = entry
        return entry

    # -- the jit-shaped surface -------------------------------------------
    def __call__(self, *args, **kwargs):
        key, leaves, static_pos, dyn_args, dyn_kwargs, n_args = (
            self._signature(args, kwargs)
        )
        entry = self._plans.get(key)
        if entry is None:
            entry = self._compile(
                key, args, kwargs, static_pos, dyn_args, dyn_kwargs, n_args
            )
        if entry.is_fallback:
            return self._fallback()(*args, **kwargs)
        feeds = dict(zip(entry.lowered.param_names, leaves, strict=False))
        out = entry.compiled(feeds)
        flat = [out[n] for n in entry.lowered.output_names]
        return jax.tree_util.tree_unflatten(entry.out_tree, flat)

    def lower(self, *args, **kwargs) -> Lowered:
        """A ``Lowered`` introspection handle (``jax.jit(...).lower()``
        analogue): ``.module`` / ``.as_text()`` inspect the captured
        StitchIR without compiling; ``.num_kernels`` / ``.cost_estimate()``
        compile lazily on first use.

        With arguments (arrays or ``ShapeDtypeStruct``s): trace + lower for
        those shapes.  Without arguments: the most recent compiled call.
        """
        if args or kwargs:
            key, _, static_pos, dyn_args, dyn_kwargs, n_args = (
                self._signature(args, kwargs)
            )
            entry = self._plans.get(key)
            if entry is not None and not entry.is_fallback:
                return Lowered(
                    entry.lowered,
                    lambda: entry.compiled,
                    compiled=entry.compiled,
                )
            closed, _ = self._trace(
                args, static_pos, dyn_args, dyn_kwargs, kwargs
            )
            lowered = self._lower(closed)
            donate = self._donated_param_names(n_args, static_pos, dyn_args)
            return Lowered(
                lowered, lambda: self._compile_lowered(lowered, donate)
            )
        if self._last is None:
            raise ValueError(
                f"{self.name} has not been compiled yet — call it (or pass "
                "example arguments to .lower())"
            )
        entry = self._last
        return Lowered(
            entry.lowered, lambda: entry.compiled, compiled=entry.compiled
        )

    @property
    def stats(self) -> CompileStats:
        """CompileStats of the most recent compile."""
        if self._last is None:
            if self.num_fallbacks:
                raise ValueError(
                    f"{self.name} has no compile stats: all "
                    f"{self.num_fallbacks} signature(s) fell back to plain "
                    "jax.jit (on_unsupported='fallback'), so nothing was "
                    "captured into StitchIR"
                )
            raise ValueError(
                f"{self.name} has not been compiled yet — call it first"
            )
        return self._last.compiled.stats

    def report(self) -> str:
        """Human-readable summary of the most recent compile."""
        s = self.stats
        m = self._last.lowered.module
        lines = [
            f"stitched function {self.name}: "
            f"{len(m.instructions)} StitchIR instructions, "
            f"{len(m.parameters)} parameters",
            f"  stitched kernels : {s.stitched_kernels}",
            f"  standalone       : {s.standalone_kernels}",
            f"  library calls    : {s.library_calls}",
            f"  XLA baseline     : {s.xla_baseline_kernels} kernels "
            f"(fusion ratio {s.fusion_ratio:.3f})",
            f"  plan cache       : {len(self._plans)} signature(s), "
            f"{self.num_compiles} compile(s), {self.num_fallbacks} fallback(s)",
        ]
        if s.loop_calls:
            lines.insert(
                5,
                f"  loop calls       : {s.loop_calls} site(s), "
                f"{s.sub_compiles} unique body(ies), "
                f"{s.sub_kernels} body kernel(s)",
            )
        for r in s.reports:
            lines.append(
                f"    kernel {r.name}: {r.num_ops} ops, {r.blocks} blocks, "
                f"{r.scratch_bytes}B VMEM scratch, roots={r.roots}"
            )
        return "\n".join(lines)

    def __repr__(self):
        return (
            f"StitchedFunction({self.name}, planner={self.options.planner!r}, "
            f"{len(self._plans)} cached plan(s))"
        )


def _hashable(v) -> bool:
    try:
        hash(v)
        return True
    except TypeError:
        return False


def stitch(
    fn: Optional[Callable] = None,
    *,
    options: Optional[StitchOptions] = None,
    on_unsupported: str = "error",
    name: Optional[str] = None,
    autotune: Optional[bool] = None,
    static_argnums: Union[int, Sequence[int], None] = (),
    static_argnames: Union[str, Sequence[str], None] = (),
    donate_argnums: Union[int, Sequence[int], None] = (),
    mesh=None,
    in_specs=None,
    out_specs=None,
) -> StitchedFunction:
    """Capture a JAX function into StitchIR and compile it per input shape.

    Usable directly (``stitched = stitch(fn)``) or as a decorator, bare or
    parameterized::

        @stitch
        def f(x): ...

        @stitch(options=StitchOptions(planner="greedy"))
        def g(x): ...

    ``on_unsupported``: ``"error"`` (default) raises
    ``UnsupportedPrimitiveError`` when the function uses a primitive outside
    the supported set; ``"fallback"`` executes the whole function through
    plain ``jax.jit`` instead, so partial coverage never blocks a caller.

    ``static_argnums`` / ``static_argnames`` mirror ``jax.jit``: the named
    arguments are compile-time constants, keyed by value into the plan
    cache (values must be hashable).  ``donate_argnums`` marks positional
    arguments whose buffers the caller gives up — the traced replay donates
    them to XLA on backends with buffer aliasing.

    ``autotune``: convenience override of ``options.autotune`` —
    ``stitch(fn, autotune=True)`` times each unique kernel once on device
    and re-plans later shapes against measured costs (``core/measure.py``).

    ``mesh`` + ``in_specs`` + ``out_specs`` compile ``fn`` as ONE
    multi-device plan: the function is traced under ``shard_map`` with that
    placement, collectives (``lax.psum`` family) lower to StitchIR
    collective instructions (natural fusion breaks), fusion scores
    per-shard tiles, and the whole ExecutionPlan replays under a single
    ``jax.jit(shard_map(...))`` — bit-identical to jitting the shard_map
    directly.  Callers pass GLOBAL arrays, as with ``jax.jit`` over a
    sharded computation.
    """
    if fn is None:
        return functools.partial(
            stitch, options=options, on_unsupported=on_unsupported,
            name=name, autotune=autotune, static_argnums=static_argnums,
            static_argnames=static_argnames, donate_argnums=donate_argnums,
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        )
    if autotune is not None:
        options = dataclasses.replace(
            options if options is not None else StitchOptions(),
            autotune=autotune,
        )
    return StitchedFunction(
        fn, options=options, on_unsupported=on_unsupported, name=name,
        static_argnums=static_argnums, static_argnames=static_argnames,
        donate_argnums=donate_argnums, mesh=mesh, in_specs=in_specs,
        out_specs=out_specs,
    )
