"""``repro.stitch`` — a ``jax.jit``-shaped frontend for the compiler.

    from repro import stitch

    @stitch
    def attention(q, k, v):
        s = q @ jnp.swapaxes(k, -1, -2) / q.shape[-1] ** 0.5
        p = jax.nn.softmax(s, axis=-1)
        return p @ v

    out = attention(q, k, v)        # traced, lowered, compiled, executed
    print(attention.report())       # kernels / fusion ratio / VMEM plan

``stitch(fn)`` returns a ``StitchedFunction``: calling it traces ``fn`` with
``jax.make_jaxpr`` on the arguments' shapes/dtypes, lowers the jaxpr into
StitchIR (``jaxpr_lower``), runs the unchanged pass pipeline via
``compile_module``, and executes the planned runtime.  Compiled plans are
cached per input-signature (pytree structure + leaf shapes/dtypes), so
repeated calls at the same shapes never recompile, and the per-function
``KernelCache`` is shared across signatures so a new shape reuses tuned
kernels where fusion signatures coincide.

``compile_module``/``trace`` remain the documented low-level path for
hand-built StitchIR.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core.compiler import (
    CompiledModule,
    CompileStats,
    StitchOptions,
    compile_module,
)
from ..core.ir import Module
from ..core.signature import KernelCache
from .jaxpr_lower import LoweredJaxpr, UnsupportedPrimitiveError, lower_jaxpr

_FALLBACK_MODES = ("error", "fallback")


@dataclass
class _PlanEntry:
    """One compiled (or fallen-back) plan for one input signature."""

    lowered: Optional[LoweredJaxpr]      # None => fallback entry
    compiled: Optional[CompiledModule]
    out_tree: Any

    @property
    def is_fallback(self) -> bool:
        return self.lowered is None


def _leaf_spec(leaf) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(np.shape(leaf), jnp.result_type(leaf))


class StitchedFunction:
    """A JAX function captured into StitchIR and compiled per input shape.

    Attributes/methods of note:
      * ``.options``       — the ``StitchOptions`` this function compiles under
      * ``.stats``         — ``CompileStats`` of the most recent compile
      * ``.lower(*args)``  — the captured StitchIR ``Module`` (no compile)
      * ``.report()``      — human-readable compile report
      * ``.num_compiles`` / ``.num_fallbacks`` — plan-cache accounting
    """

    def __init__(
        self,
        fn: Callable,
        options: Optional[StitchOptions] = None,
        on_unsupported: str = "error",
        name: Optional[str] = None,
    ):
        if not callable(fn):
            raise TypeError(f"stitch() requires a callable, got {type(fn).__name__}")
        if on_unsupported not in _FALLBACK_MODES:
            raise ValueError(
                f"on_unsupported={on_unsupported!r}; valid modes: "
                f"{', '.join(_FALLBACK_MODES)}"
            )
        self._fn = fn
        self.options = options if options is not None else StitchOptions()
        self.on_unsupported = on_unsupported
        self.name = name or getattr(fn, "__name__", "stitched")
        self._plans: Dict[Any, _PlanEntry] = {}
        self._kernel_cache = KernelCache(self.options.kernel_cache_path)
        # Shared across this function's per-shape compiles (like the kernel
        # cache): a kernel measured for one input shape guides the planner
        # on the next shape's compile.  Created lazily — most functions
        # never turn autotuning on.
        self._measured_store = None
        self._fallback_jit: Optional[Callable] = None
        self._last: Optional[_PlanEntry] = None
        self.num_compiles = 0
        self.num_fallbacks = 0
        functools.update_wrapper(self, fn)

    # -- plan cache -------------------------------------------------------
    def _signature(self, args, kwargs):
        leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
        return (
            treedef,
            tuple(
                (tuple(np.shape(l)), str(jnp.result_type(l))) for l in leaves
            ),
        ), leaves

    def _trace(self, args, kwargs):
        """jax.make_jaxpr on the arguments' shapes (no values traced)."""
        shaped_args, shaped_kwargs = jax.tree_util.tree_map(
            _leaf_spec, (args, kwargs)
        )
        closed, out_shape = jax.make_jaxpr(self._fn, return_shape=True)(
            *shaped_args, **shaped_kwargs
        )
        return closed, jax.tree_util.tree_structure(out_shape)

    def _compile(self, key, args, kwargs) -> _PlanEntry:
        closed, out_tree = self._trace(args, kwargs)
        try:
            lowered = lower_jaxpr(
                closed, name=self.name, fuse_dot=self.options.fuse_dot
            )
        except UnsupportedPrimitiveError:
            if self.on_unsupported != "fallback":
                raise
            if self._fallback_jit is None:
                self._fallback_jit = jax.jit(self._fn)
            self.num_fallbacks += 1
            entry = _PlanEntry(None, None, out_tree)
            self._plans[key] = entry
            return entry
        if self._measured_store is None and (
            self.options.autotune or self.options.tuning_store_path
        ):
            from ..core.measure import MeasuredCostStore, device_fingerprint

            self._measured_store = MeasuredCostStore(
                self.options.tuning_store_path,
                device_fp=device_fingerprint(
                    interpret=self.options.interpret
                ),
            )
        compiled = compile_module(
            lowered.module, self.options, kernel_cache=self._kernel_cache,
            measured_store=self._measured_store,
        )
        self.num_compiles += 1
        entry = _PlanEntry(lowered, compiled, out_tree)
        self._plans[key] = entry
        self._last = entry
        return entry

    # -- the jit-shaped surface -------------------------------------------
    def __call__(self, *args, **kwargs):
        key, leaves = self._signature(args, kwargs)
        entry = self._plans.get(key)
        if entry is None:
            entry = self._compile(key, args, kwargs)
        if entry.is_fallback:
            return self._fallback_jit(*args, **kwargs)
        feeds = dict(zip(entry.lowered.param_names, leaves))
        out = entry.compiled(feeds)
        flat = [out[n] for n in entry.lowered.output_names]
        return jax.tree_util.tree_unflatten(entry.out_tree, flat)

    def lower(self, *args, **kwargs) -> Module:
        """The captured StitchIR ``Module``.

        With arguments (arrays or ``ShapeDtypeStruct``s): trace+lower for
        those shapes without compiling.  Without arguments: the module of
        the most recent compiled call.
        """
        if args or kwargs:
            key, _ = self._signature(args, kwargs)
            entry = self._plans.get(key)
            if entry is not None and not entry.is_fallback:
                return entry.lowered.module
            closed, _ = self._trace(args, kwargs)
            return lower_jaxpr(
                closed, name=self.name, fuse_dot=self.options.fuse_dot
            ).module
        if self._last is None:
            raise ValueError(
                f"{self.name} has not been compiled yet — call it (or pass "
                "example arguments to .lower())"
            )
        return self._last.lowered.module

    @property
    def stats(self) -> CompileStats:
        """CompileStats of the most recent compile."""
        if self._last is None:
            if self.num_fallbacks:
                raise ValueError(
                    f"{self.name} has no compile stats: all "
                    f"{self.num_fallbacks} signature(s) fell back to plain "
                    "jax.jit (on_unsupported='fallback'), so nothing was "
                    "captured into StitchIR"
                )
            raise ValueError(
                f"{self.name} has not been compiled yet — call it first"
            )
        return self._last.compiled.stats

    def report(self) -> str:
        """Human-readable summary of the most recent compile."""
        s = self.stats
        m = self._last.lowered.module
        lines = [
            f"stitched function {self.name}: "
            f"{len(m.instructions)} StitchIR instructions, "
            f"{len(m.parameters)} parameters",
            f"  stitched kernels : {s.stitched_kernels}",
            f"  standalone       : {s.standalone_kernels}",
            f"  library calls    : {s.library_calls}",
            f"  XLA baseline     : {s.xla_baseline_kernels} kernels "
            f"(fusion ratio {s.fusion_ratio:.3f})",
            f"  plan cache       : {len(self._plans)} signature(s), "
            f"{self.num_compiles} compile(s), {self.num_fallbacks} fallback(s)",
        ]
        for r in s.reports:
            lines.append(
                f"    kernel {r.name}: {r.num_ops} ops, {r.blocks} blocks, "
                f"{r.scratch_bytes}B VMEM scratch, roots={r.roots}"
            )
        return "\n".join(lines)

    def __repr__(self):
        return (
            f"StitchedFunction({self.name}, planner={self.options.planner!r}, "
            f"{len(self._plans)} cached plan(s))"
        )


def stitch(
    fn: Optional[Callable] = None,
    *,
    options: Optional[StitchOptions] = None,
    on_unsupported: str = "error",
    name: Optional[str] = None,
    autotune: Optional[bool] = None,
) -> StitchedFunction:
    """Capture a JAX function into StitchIR and compile it per input shape.

    Usable directly (``stitched = stitch(fn)``) or as a decorator, bare or
    parameterized::

        @stitch
        def f(x): ...

        @stitch(options=StitchOptions(planner="greedy"))
        def g(x): ...

    ``on_unsupported``: ``"error"`` (default) raises
    ``UnsupportedPrimitiveError`` when the function uses a primitive outside
    the supported set; ``"fallback"`` executes the whole function through
    plain ``jax.jit`` instead, so partial coverage never blocks a caller.

    ``autotune``: convenience override of ``options.autotune`` —
    ``stitch(fn, autotune=True)`` times each unique kernel once on device
    and re-plans later shapes against measured costs (``core/measure.py``).
    """
    if fn is None:
        return functools.partial(
            stitch, options=options, on_unsupported=on_unsupported,
            name=name, autotune=autotune,
        )
    if autotune is not None:
        options = dataclasses.replace(
            options if options is not None else StitchOptions(),
            autotune=autotune,
        )
    return StitchedFunction(
        fn, options=options, on_unsupported=on_unsupported, name=name
    )
