"""Lower a jaxpr captured from a real JAX function into StitchIR.

The paper's compiler consumes *framework-captured* computations (TF graphs
fed to XLA as HLO), not hand-transcribed IR.  This module closes that gap
for the reproduction: ``lower_jaxpr`` walks a ``ClosedJaxpr`` (produced by
``jax.make_jaxpr`` on shaped arguments) and emits the equivalent StitchIR
``Module`` through the existing ``GraphBuilder``, so the unchanged pass
pipeline (fusion -> schedule -> memory -> codegen) compiles real
``jax.numpy`` programs.

Lowering rules worth knowing:

  * jaxprs broadcast *implicitly* in two places StitchIR does not: scalar
    literals appear directly as elementwise operands (``mul a 0.17``), and
    rank-equal operands may carry degenerate (size-1) dims (``sub f[...,16]
    h[...,1]``).  ``_to_shape`` materializes both as explicit ``broadcast``
    instructions — the same shape ops a hand-built graph writes.
  * ``dot_general`` is canonicalized to StitchIR's batched-matmul ``dot``
    (contract lhs[-1] with rhs[-2], leading batch dims) via transposes and
    reshapes; the common ``q @ k.T`` layouts lower with no extra ops.
  * call-like primitives (``pjit``, ``custom_jvp_call``, ...) are inlined
    recursively, so ``jax.nn`` activations and ``jnp.where`` lower to their
    bodies instead of failing on the wrapper.
  * literals and closure constants fold as IR ``constant``s; the compiler's
    constant folding evaluates them once at plan-build time.

Anything else raises ``UnsupportedPrimitiveError`` naming the primitive and
its eqn (``repro.stitch`` turns that into a plain ``jax.jit`` fallback when
``on_unsupported="fallback"``).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from jax.extend.core import Literal

from ..core.ir import GraphBuilder, Module, Tensor, _prod


# --------------------------------------------------------------------------
# Primitive tables (the README "supported primitives" table is generated
# from these — keep names in sync with jax.lax primitive names)
# --------------------------------------------------------------------------

#: jaxpr unary primitive -> StitchIR elementwise fn
UNARY_PRIMS: Dict[str, str] = {
    "exp": "exp",
    "log": "log",
    "tanh": "tanh",
    "sqrt": "sqrt",
    "rsqrt": "rsqrt",
    "neg": "neg",
    "abs": "abs",
    "sign": "sign",
    "floor": "floor",
    "logistic": "sigmoid",
    "not": "not",
    "cos": "cos",
    "sin": "sin",
}

#: jaxpr binary primitive -> StitchIR elementwise fn
BINARY_PRIMS: Dict[str, str] = {
    "add": "add",
    "add_any": "add",   # transpose-rule accumulation (jax.grad cotangents)
    "sub": "sub",
    "mul": "mul",
    "div": "div",
    "max": "max",
    "min": "min",
    "pow": "pow",
    "lt": "lt",
    "le": "le",
    "gt": "gt",
    "ge": "ge",
    "eq": "eq",
    "ne": "ne",
    "and": "and",
    "or": "or",
}

#: jaxpr reduce primitive -> StitchIR reduce kind
REDUCE_PRIMS: Dict[str, str] = {
    "reduce_sum": "sum",
    "reduce_max": "max",
    "reduce_min": "min",
    "reduce_prod": "prod",
}

#: value-preserving primitives lowered as aliases (no instruction emitted;
#: device placement is meaningless in StitchIR, so device_put aliases too)
IDENTITY_PRIMS = frozenset({"stop_gradient", "copy", "device_put"})

#: call-like primitives whose inner jaxpr is inlined ("remat2" is the
#: primitive jax.checkpoint/jax.remat actually emit)
CALL_PRIMS = frozenset(
    {"pjit", "closed_call", "core_call", "custom_jvp_call", "custom_vjp_call",
     "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr", "remat", "remat2",
     "checkpoint"}
)

#: structural primitives with bespoke lowerings below
STRUCTURAL_PRIMS = frozenset(
    {"dot_general", "broadcast_in_dim", "transpose", "reshape", "squeeze",
     "convert_element_type", "select_n", "integer_pow", "concatenate",
     "iota", "square", "clamp"}
)

#: control-flow primitives: ``scan`` lowers to a sub-module ``call`` loop
#: (``fori_loop`` over static Python-int bounds lowers to ``scan`` inside
#: jax, so it arrives here as one); ``while`` lowers the same way when a
#: static trip count is provable from the canonical counter pattern;
#: ``cond`` inlines both branches behind ``select``.
CONTROL_FLOW_PRIMS = frozenset({"scan", "while", "cond"})

#: collective primitives (appear only in shard_map-traced jaxprs, where an
#: axis env binds the mesh axis names) lowered to StitchIR collective
#: instructions — standalone schedule breaks replayed as lax.psum-family
#: calls, never fused into kernels.
COLLECTIVE_PRIMS = frozenset({"psum", "all_gather", "reduce_scatter"})

#: collectives the frontend recognizes but does not lower yet: named error
#: (with the fallback hint) instead of the generic unknown-primitive one.
UNLOWERED_COLLECTIVE_PRIMS = frozenset(
    {"ppermute", "all_to_all", "pmax", "pmin", "pbroadcast", "pgather",
     "axis_index", "psum_scatter"}
)

SUPPORTED_PRIMITIVES = frozenset(
    set(UNARY_PRIMS) | set(BINARY_PRIMS) | set(REDUCE_PRIMS)
    | IDENTITY_PRIMS | CALL_PRIMS | STRUCTURAL_PRIMS | CONTROL_FLOW_PRIMS
    | COLLECTIVE_PRIMS
)


class UnsupportedPrimitiveError(NotImplementedError):
    """A jaxpr primitive the frontend cannot lower to StitchIR.

    Carries the primitive name (``.primitive``) and the offending eqn
    (``.eqn``) so callers can report exactly what blocked the capture.
    """

    def __init__(self, primitive, eqn=None, reason: str = ""):
        self.primitive = str(primitive)
        self.eqn = eqn
        msg = f"jaxpr primitive '{self.primitive}' is not supported by repro.stitch"
        if reason:
            msg += f" ({reason})"
        if eqn is not None:
            msg += f"\n  in eqn: {eqn}"
        msg += (
            f"\nsupported primitives: {', '.join(sorted(SUPPORTED_PRIMITIVES))}"
            "\nhint: stitch(fn, on_unsupported='fallback') runs the whole "
            "function through plain jax.jit instead of failing."
        )
        super().__init__(msg)


@dataclass
class LoweredJaxpr:
    """A captured function: the StitchIR module plus its calling convention.

    ``param_names`` name the module parameters in flattened-argument order;
    ``output_names`` name one module root per flattened output (outputs that
    alias a parameter/constant or an interior value get a value-preserving
    ``reshape`` sink so the executor materializes them).
    """

    module: Module
    param_names: List[str]
    output_names: List[str]


def _is_dropvar(v) -> bool:
    return type(v).__name__ == "DropVar"


def _live_eqns(eqns, live_outvars):
    """Reverse-liveness DCE over a jaxpr's eqns.

    ``jax.make_jaxpr`` does NOT dead-code-eliminate (jax.jit's DCE happens
    in XLA, after our capture point), so unused intermediates would lower
    to user-less instructions — which the compiler treats as module roots
    and computes on every call.  Keep only eqns whose outputs are
    (transitively) live; call-like eqns are treated atomically, with the
    same pruning applied to their inner jaxpr during inlining.

    Returns ``(kept_eqns, live_vars)`` — ``live_vars`` additionally gates
    constvar materialization (a dead closure constant must not become a
    user-less IR constant, i.e. a module root).

    Side-effecting eqns (``jax.debug.print``, ``io_callback``, ...) are
    always kept even with no live outputs: silently dropping an effect
    would diverge from ``jax.jit``, so they must reach the lowering and
    raise ``UnsupportedPrimitiveError`` (or trigger fallback) instead."""
    live = {v for v in live_outvars if not isinstance(v, Literal)}
    kept = []
    for eqn in reversed(eqns):
        if getattr(eqn, "effects", None) or any(
            not _is_dropvar(v) and v in live for v in eqn.outvars
        ):
            kept.append(eqn)
            live.update(v for v in eqn.invars if not isinstance(v, Literal))
    kept.reverse()
    return kept, live


class _Lowerer:
    def __init__(self, builder: GraphBuilder, fuse_dot: bool):
        self.b = builder
        self.fuse_dot = fuse_dot
        #: live vars of the jaxpr currently being lowered (set by
        #: ``lower_jaxpr`` / saved+restored around inlined sub-jaxprs);
        #: multi-output eqns consult it so dead outputs never become
        #: user-less instructions (= accidental module roots).
        self.live: set = set()

    # -- environment ------------------------------------------------------
    def read(self, env: Dict, atom) -> Tensor:
        if isinstance(atom, Literal):
            val = np.asarray(atom.val, dtype=atom.aval.dtype)
            return self.b.constant(val)
        return env[atom]

    def to_shape(self, t: Tensor, shape: Sequence[int]) -> Tensor:
        """Materialize jaxpr implicit broadcasting (scalars + size-1 dims)."""
        shape = tuple(int(s) for s in shape)
        if tuple(t.shape) == shape:
            return t
        if t.ndim == 0:
            return self.b.broadcast(t, shape, ())
        if t.ndim == len(shape):
            return self.b.broadcast(t, shape, tuple(range(t.ndim)))
        raise ValueError(
            f"cannot broadcast rank-{t.ndim} value {tuple(t.shape)} to {shape}"
        )

    # -- eqn dispatch -----------------------------------------------------
    def lower_eqns(self, env: Dict, eqns) -> None:
        for eqn in eqns:
            self.lower_eqn(env, eqn)

    def lower_eqn(self, env: Dict, eqn) -> None:
        prim = eqn.primitive.name
        if prim in CALL_PRIMS:
            self._inline_call(env, eqn)
            return
        if prim == "scan":
            self._lower_scan(env, eqn)
            return
        if prim == "while":
            self._lower_while(env, eqn)
            return
        if prim == "cond":
            self._lower_cond(env, eqn)
            return
        outs = self._lower_value_eqn(env, eqn)
        for var, t in zip(eqn.outvars, outs, strict=False):
            if not _is_dropvar(var):
                env[var] = t

    def _inline_call(self, env: Dict, eqn) -> None:
        sub = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in eqn.params:
                sub = eqn.params[key]
                break
        if sub is None:
            raise UnsupportedPrimitiveError(
                eqn.primitive.name, eqn, "call primitive with no inner jaxpr"
            )
        inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
        consts = sub.consts if hasattr(sub, "consts") else []
        args = [self.read(env, v) for v in eqn.invars]
        if len(args) != len(inner.invars):
            raise UnsupportedPrimitiveError(
                eqn.primitive.name, eqn,
                f"arity mismatch inlining inner jaxpr "
                f"({len(args)} args vs {len(inner.invars)} invars)",
            )
        live_outs = [
            iv for ov, iv in zip(eqn.outvars, inner.outvars, strict=False)
            if not _is_dropvar(ov) and ov in self.live
        ]
        kept, live = _live_eqns(inner.eqns, live_outs)
        sub_env: Dict = {}
        for var, const in zip(inner.constvars, consts, strict=False):
            if var in live:
                sub_env[var] = self.b.constant(np.asarray(const))
        for var, t in zip(inner.invars, args, strict=False):
            sub_env[var] = t
        saved, self.live = self.live, live
        try:
            self.lower_eqns(sub_env, kept)
        finally:
            self.live = saved
        for outer, inner_out in zip(eqn.outvars, inner.outvars, strict=False):
            if not _is_dropvar(outer) and outer in self.live:
                env[outer] = self.read(sub_env, inner_out)

    def _lower_value_eqn(self, env: Dict, eqn) -> List[Tensor]:
        prim = eqn.primitive.name
        b = self.b
        out_aval = eqn.outvars[0].aval

        if prim in IDENTITY_PRIMS:
            return [self.read(env, eqn.invars[0])]

        if prim in UNARY_PRIMS:
            return [b.unary(UNARY_PRIMS[prim], self.read(env, eqn.invars[0]))]

        if prim in BINARY_PRIMS:
            lhs = self.to_shape(self.read(env, eqn.invars[0]), out_aval.shape)
            rhs = self.to_shape(self.read(env, eqn.invars[1]), out_aval.shape)
            return [b.binary(BINARY_PRIMS[prim], lhs, rhs)]

        if prim in REDUCE_PRIMS:
            x = self.read(env, eqn.invars[0])
            axes = tuple(eqn.params["axes"])
            if not axes:  # reduce over no axes is the identity
                return [x]
            return [b.reduce(x, axes, REDUCE_PRIMS[prim])]

        if prim == "square":
            return [b.square(self.read(env, eqn.invars[0]))]

        if prim == "integer_pow":
            return [self._integer_pow(env, eqn)]

        if prim == "convert_element_type":
            x = self.read(env, eqn.invars[0])
            new = np.dtype(eqn.params["new_dtype"])
            if np.dtype(x.dtype) == new:
                return [x]
            return [b.convert(x, new)]

        if prim == "broadcast_in_dim":
            x = self.read(env, eqn.invars[0])
            shape = tuple(int(s) for s in eqn.params["shape"])
            dims = tuple(eqn.params["broadcast_dimensions"])
            if tuple(x.shape) == shape and dims == tuple(range(x.ndim)):
                return [x]
            return [b.broadcast(x, shape, dims)]

        if prim == "transpose":
            x = self.read(env, eqn.invars[0])
            perm = tuple(eqn.params["permutation"])
            if perm == tuple(range(x.ndim)):
                return [x]
            if (
                perm == (1, 0)
                and x.instr.opcode == "dot"
                and not x.instr.users
                and all(o.ndim == 2 for o in x.instr.operands)
            ):
                # transpose(dot(a, b)) == dot(b^T, a^T).  AD emits this for
                # every weight gradient (dw = (dy^T @ x)^T); commuting keeps
                # the dot's result in the default layout — XLA CPU otherwise
                # folds the result-transpose into a column-major dot output
                # layout its DotThunk refuses to execute.  The original dot
                # is orphaned here; lower_jaxpr's dead-instruction sweep
                # removes it unless a later eqn still reads it.
                return [self._commute_dot_transpose(x.instr)]
            return [b.transpose(x, perm)]

        if prim == "reshape":
            if eqn.params.get("dimensions") is not None:
                raise UnsupportedPrimitiveError(
                    prim, eqn, "reshape with a dimensions permutation"
                )
            x = self.read(env, eqn.invars[0])
            new = tuple(int(s) for s in eqn.params["new_sizes"])
            if tuple(x.shape) == new:
                return [x]
            return [b.reshape(x, new)]

        if prim == "squeeze":
            x = self.read(env, eqn.invars[0])
            return [b.reshape(x, tuple(int(s) for s in out_aval.shape))]

        if prim == "concatenate":
            xs = [self.read(env, v) for v in eqn.invars]
            return [b.concat(xs, int(eqn.params["dimension"]))]

        if prim == "iota":
            shape = tuple(int(s) for s in eqn.params["shape"])
            return [b.iota(shape, int(eqn.params["dimension"]),
                           np.dtype(eqn.params["dtype"]))]

        if prim == "clamp":
            # lax.clamp(lo, x, hi) == min(max(x, lo), hi) elementwise
            lo = self.to_shape(self.read(env, eqn.invars[0]), out_aval.shape)
            x = self.to_shape(self.read(env, eqn.invars[1]), out_aval.shape)
            hi = self.to_shape(self.read(env, eqn.invars[2]), out_aval.shape)
            return [b.binary("min", b.binary("max", x, lo), hi)]

        if prim == "select_n":
            if len(eqn.invars) != 3:
                raise UnsupportedPrimitiveError(
                    prim, eqn, f"{len(eqn.invars) - 1}-case select "
                    "(only boolean 2-case select is supported)"
                )
            pred = self.to_shape(self.read(env, eqn.invars[0]), out_aval.shape)
            if np.dtype(pred.dtype) != np.dtype(np.bool_):
                raise UnsupportedPrimitiveError(
                    prim, eqn, "select_n with a non-boolean selector"
                )
            # select_n(pred, *cases): cases[0] is the False branch
            on_false = self.to_shape(self.read(env, eqn.invars[1]), out_aval.shape)
            on_true = self.to_shape(self.read(env, eqn.invars[2]), out_aval.shape)
            return [b.select(pred, on_true, on_false)]

        if prim == "dot_general":
            return [self._dot_general(env, eqn)]

        if prim in COLLECTIVE_PRIMS:
            return self._lower_collective(env, eqn)

        if prim in UNLOWERED_COLLECTIVE_PRIMS:
            raise UnsupportedPrimitiveError(
                prim, eqn,
                "collective not lowered by the sharded frontend yet; only "
                "psum, all_gather and reduce_scatter compile to StitchIR",
            )

        raise UnsupportedPrimitiveError(prim, eqn)

    def _lower_collective(self, env: Dict, eqn) -> List[Tensor]:
        """psum/all_gather/reduce_scatter -> StitchIR collective instructions.

        These only appear in shard_map-traced jaxprs (an axis env must bind
        the names); the executor replays them as the matching lax call
        inside its own shard_map, so axis semantics round-trip exactly."""
        b = self.b
        prim = eqn.primitive.name
        p = eqn.params
        if p.get("axis_index_groups") is not None:
            raise UnsupportedPrimitiveError(
                prim, eqn, "axis_index_groups subgrouping is not supported"
            )
        raw = p["axes"] if prim == "psum" else p["axis_name"]
        axes = (raw,) if isinstance(raw, str) else tuple(raw)
        if not axes or not all(isinstance(a, str) for a in axes):
            raise UnsupportedPrimitiveError(
                prim, eqn,
                "positional (vmap) axes cannot lower to mesh collectives",
            )
        if prim == "psum":
            # one all_reduce per operand (lax.psum over a tree arrives as a
            # single multi-operand eqn)
            return [b.all_reduce(self.read(env, v), axes) for v in eqn.invars]
        if not p.get("tiled", False):
            raise UnsupportedPrimitiveError(
                prim, eqn,
                "untiled gather/scatter (a fresh leading dim) is not "
                "supported; lax.all_gather(..., tiled=True) and "
                "lax.psum_scatter(..., tiled=True) compile",
            )
        x = self.read(env, eqn.invars[0])
        g = int(p["axis_size"])
        if prim == "all_gather":
            return [b.all_gather(x, axes, int(p["all_gather_dimension"]), g)]
        return [b.reduce_scatter(x, axes, int(p["scatter_dimension"]), g)]

    # -- bespoke lowerings ------------------------------------------------
    def _integer_pow(self, env: Dict, eqn) -> Tensor:
        """x ** n as XLA lowers it: repeated multiplication (never a
        transcendental ``pow``, which diverges on negative bases)."""
        b = self.b
        x = self.read(env, eqn.invars[0])
        n = int(eqn.params["y"])
        if n == 0:
            one = b.constant(np.asarray(1, dtype=x.dtype))
            return self.to_shape(one, x.shape)
        out = x
        if abs(n) == 2:
            out = b.square(x)
        else:
            for _ in range(abs(n) - 1):
                out = b.binary("mul", out, x)
        if n < 0:
            out = b.unary("reciprocal", out)
        return out

    def _dot_general(self, env: Dict, eqn) -> Tensor:
        """Canonicalize an arbitrary dot_general to StitchIR ``dot``:
        (batch..., M, K) x (batch..., K, N) with leading batch dims, via
        transposes/reshapes.  The output dim order of dot_general —
        (batch, lhs free, rhs free) — is exactly what the canonical form
        produces, so a final reshape restores the declared shape."""
        b = self.b
        lhs = self.read(env, eqn.invars[0])
        rhs = self.read(env, eqn.invars[1])
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lc, rc, lb, rb = map(tuple, (lc, rc, lb, rb))
        out_aval = eqn.outvars[0].aval
        lfree = tuple(d for d in range(lhs.ndim) if d not in lc and d not in lb)
        rfree = tuple(d for d in range(rhs.ndim) if d not in rc and d not in rb)

        def permute(t: Tensor, perm: Tuple[int, ...]) -> Tensor:
            if perm == tuple(range(t.ndim)):
                return t
            return b.transpose(t, perm)

        left = permute(lhs, lb + lfree + lc)
        right = permute(rhs, rb + rc + rfree)
        batch = tuple(int(lhs.shape[d]) for d in lb)
        m = _prod([lhs.shape[d] for d in lfree])
        k = _prod([lhs.shape[d] for d in lc])
        n = _prod([rhs.shape[d] for d in rfree])
        if tuple(left.shape) != batch + (m, k):
            left = b.reshape(left, batch + (m, k))
        if tuple(right.shape) != batch + (k, n):
            right = b.reshape(right, batch + (k, n))
        out = b.dot(left, right, fusable=self.fuse_dot)
        out_shape = tuple(int(s) for s in out_aval.shape)
        if tuple(out.shape) != out_shape:
            out = b.reshape(out, out_shape)
        if np.dtype(out.dtype) != np.dtype(out_aval.dtype):
            out = b.convert(out, out_aval.dtype)
        return out

    def _commute_dot_transpose(self, dot_instr) -> Tensor:
        """``dot(a, b)^T`` as ``dot(b^T, a^T)``, cancelling an operand that
        is itself a rank-2 transpose instead of stacking a second one."""
        b = self.b

        def flipped(instr) -> Tensor:
            if instr.opcode == "transpose" and tuple(instr.attrs["perm"]) == (1, 0):
                return Tensor(b, instr.operands[0])
            return b.transpose(Tensor(b, instr), (1, 0))

        lhs, rhs = dot_instr.operands
        return b.dot(
            flipped(rhs), flipped(lhs),
            fusable=bool(dot_instr.attrs.get("fusable", True)),
        )

    # -- control flow ------------------------------------------------------
    def _emit_loop(
        self,
        env: Dict,
        eqn,
        body_closed,
        operands: List[Tensor],
        *,
        num_consts: int,
        num_carry: int,
        trip_count: int,
        reverse: bool,
        kind: str,
    ) -> None:
        """Shared scan/while tail: lower ``body_closed`` as a sub-module,
        emit one ``call`` loop and a ``get`` per live outer output.

        The contract with the executor is fully positional (operand order =
        body parameter-creation order; ``out_order`` maps logical output j
        to its position among the body's roots), so two structurally
        identical bodies — e.g. stacked transformer layers — share one
        compiled sub-module via ``module_signature``."""
        inner = body_closed.jaxpr
        n_x = len(inner.invars) - num_consts - num_carry
        pnames = (
            [f"c{i}" for i in range(num_consts)]
            + [f"h{i}" for i in range(num_carry)]
            + [f"x{i}" for i in range(n_x)]
        )
        sub = lower_jaxpr(
            body_closed,
            name=f"{self.b.module.name}.{kind}_body",
            fuse_dot=self.fuse_dot,
            param_names=pnames,
        )
        root_pos = {r.name: i for i, r in enumerate(sub.module.roots)}
        out_order = [root_pos[n] for n in sub.output_names]
        call = self.b.call_loop(
            operands,
            sub.module,
            trip_count=trip_count,
            num_consts=num_consts,
            num_carry=num_carry,
            out_order=out_order,
            out_shapes=[tuple(int(s) for s in ov.aval.shape)
                        for ov in eqn.outvars],
            out_dtypes=[np.dtype(ov.aval.dtype) for ov in eqn.outvars],
            reverse=reverse,
            kind=kind,
        )
        for j, ov in enumerate(eqn.outvars):
            if not _is_dropvar(ov) and ov in self.live:
                env[ov] = self.b.get(call, j)

    def _lower_scan(self, env: Dict, eqn) -> None:
        """``lax.scan`` -> ``call`` loop.  Carries double-buffer through the
        body plan; per-iteration outputs stack into ``(length, ...)``
        buffers.  ``fori_loop`` over static Python-int bounds arrives here
        too (jax lowers it to scan)."""
        p = eqn.params
        self._emit_loop(
            env, eqn, p["jaxpr"],
            [self.read(env, v) for v in eqn.invars],
            num_consts=int(p["num_consts"]),
            num_carry=int(p["num_carry"]),
            trip_count=int(p["length"]),
            reverse=bool(p["reverse"]),
            kind="scan",
        )

    def _lower_while(self, env: Dict, eqn) -> None:
        """``lax.while_loop`` lowers only when a static trip count is
        provable from the canonical counter pattern jax emits for bounded
        loops: cond = single ``lt(carry[i], LIMIT)`` eqn, body sets
        ``carry[i] + 1``, and both the init and LIMIT are literals (LIMIT
        may also be a cond constant fed by an outer literal)."""
        trip, i = self._while_trip_count(eqn) or (None, None)
        if trip is None:
            raise UnsupportedPrimitiveError(
                "while", eqn,
                "no static trip count: lax.while_loop compiles only when "
                "the condition is the canonical bounded-counter pattern "
                "`carry[i] < LIMIT` with `carry[i] += 1` in the body and "
                "literal init/limit; use lax.scan or lax.fori_loop with "
                "static bounds",
            )
        p = eqn.params
        cn = int(p["cond_nconsts"])
        bn = int(p["body_nconsts"])
        # drop the cond consts: the compiled loop replays the body only
        self._emit_loop(
            env, eqn, p["body_jaxpr"],
            [self.read(env, v) for v in eqn.invars[cn:]],
            num_consts=bn,
            num_carry=len(eqn.outvars),
            trip_count=trip,
            reverse=False,
            kind="while",
        )

    def _while_trip_count(self, eqn) -> Optional[Tuple[int, int]]:
        """``(trip_count, counter_index)`` if the while is a provably
        bounded counter loop, else None."""
        p = eqn.params
        cond = p["cond_jaxpr"].jaxpr
        body = p["body_jaxpr"].jaxpr
        cn, bn = int(p["cond_nconsts"]), int(p["body_nconsts"])
        if len(cond.eqns) != 1 or cond.eqns[0].primitive.name != "lt":
            return None
        lt = cond.eqns[0]
        if not cond.outvars or cond.outvars[0] is not lt.outvars[0]:
            return None
        ctr_atom, limit_atom = lt.invars
        cond_carries = list(cond.invars[cn:])
        if isinstance(ctr_atom, Literal) or ctr_atom not in cond_carries:
            return None
        i = cond_carries.index(ctr_atom)
        if not np.issubdtype(np.dtype(ctr_atom.aval.dtype), np.integer):
            return None
        # LIMIT: a literal, or a cond const whose outer operand is a literal
        if isinstance(limit_atom, Literal):
            limit = int(np.asarray(limit_atom.val).item())
        elif limit_atom in list(cond.invars[:cn]):
            outer = eqn.invars[list(cond.invars[:cn]).index(limit_atom)]
            if not isinstance(outer, Literal):
                return None
            limit = int(np.asarray(outer.val).item())
        else:
            return None
        # body must step the counter by exactly one
        out_i = body.outvars[i]
        if isinstance(out_i, Literal) or _is_dropvar(out_i):
            return None
        step = next(
            (e for e in body.eqns if any(v is out_i for v in e.outvars)), None
        )
        if step is None or step.primitive.name != "add":
            return None

        def _is_one(atom):
            return (
                isinstance(atom, Literal)
                and np.asarray(atom.val).ndim == 0
                and np.asarray(atom.val).item() == 1
            )

        ctr_body = body.invars[bn + i]
        x, y = step.invars
        if not ((x is ctr_body and _is_one(y)) or (y is ctr_body and _is_one(x))):
            return None
        init_atom = eqn.invars[cn + bn + i]
        if not isinstance(init_atom, Literal):
            return None
        init = int(np.asarray(init_atom.val).item())
        return max(0, limit - init), i

    def _lower_cond(self, env: Dict, eqn) -> None:
        """2-branch ``lax.cond`` inlines both branches and selects per
        output (the same thing ``vmap``-of-cond does in jax); branch
        payloads are elementwise towers, so the selects fuse into the
        surrounding kernels instead of forcing a host-side branch."""
        branches = eqn.params["branches"]
        if len(branches) != 2:
            raise UnsupportedPrimitiveError(
                "cond", eqn,
                f"{len(branches)}-way lax.switch "
                "(only 2-branch lax.cond inlines via select)",
            )
        idx = self.read(env, eqn.invars[0])
        args = [self.read(env, v) for v in eqn.invars[1:]]
        wanted = [
            j for j, ov in enumerate(eqn.outvars)
            if not _is_dropvar(ov) and ov in self.live
        ]
        branch_outs: List[Dict[int, Tensor]] = []
        for bi, br in enumerate(branches):
            inner = br.jaxpr
            live_outs = [inner.outvars[j] for j in wanted]
            kept, live = _live_eqns(inner.eqns, live_outs)
            sub_env: Dict = {}
            for var, const in zip(inner.constvars, br.consts, strict=False):
                if var in live:
                    sub_env[var] = self.b.constant(np.asarray(const))
            for var, t in zip(inner.invars, args, strict=False):
                sub_env[var] = t
            saved, self.live = self.live, live
            try:
                self.lower_eqns(sub_env, kept)
            finally:
                self.live = saved
            branch_outs.append(
                {j: self.read(sub_env, inner.outvars[j]) for j in wanted}
            )
        pred = self.b.binary(
            "ne", idx, self.b.constant(np.asarray(0, dtype=idx.dtype))
        )
        for j in wanted:
            ov = eqn.outvars[j]
            shape = tuple(int(s) for s in ov.aval.shape)
            dtype = np.dtype(ov.aval.dtype)
            # branches[0] is the FALSE branch (lax.cond index semantics)
            on_false, on_true = branch_outs[0][j], branch_outs[1][j]
            for bi, t in ((0, on_false), (1, on_true)):
                if tuple(t.shape) != shape or np.dtype(t.dtype) != dtype:
                    raise UnsupportedPrimitiveError(
                        "cond", eqn,
                        f"branch {bi} output {j} lowered to "
                        f"{np.dtype(t.dtype)}{list(t.shape)} but the cond "
                        f"declares {dtype}{list(shape)}",
                    )
            env[ov] = self.b.select(
                self.to_shape(pred, shape),
                self.to_shape(on_true, shape),
                self.to_shape(on_false, shape),
            )


def lower_jaxpr(
    closed_jaxpr,
    *,
    name: str = "stitched",
    fuse_dot: bool = True,
    param_names: Optional[Sequence[str]] = None,
) -> LoweredJaxpr:
    """Lower a ``ClosedJaxpr`` into a StitchIR ``Module``.

    ``param_names`` (optional) names the module parameters, one per jaxpr
    invar; defaults to ``arg0..argN``.  ``fuse_dot`` sets the per-dot
    ``fusable`` attr (the paper's user decision — ``StitchOptions.fuse_dot``
    flows through here from ``repro.stitch``).
    """
    jaxpr = closed_jaxpr.jaxpr
    b = GraphBuilder(name)
    lw = _Lowerer(b, fuse_dot)
    kept_eqns, live = _live_eqns(jaxpr.eqns, jaxpr.outvars)
    lw.live = live
    env: Dict = {}
    for var, const in zip(jaxpr.constvars, closed_jaxpr.consts, strict=False):
        if var in live:
            env[var] = b.constant(np.asarray(const))
    if param_names is None:
        param_names = [f"arg{i}" for i in range(len(jaxpr.invars))]
    if len(param_names) != len(jaxpr.invars):
        raise ValueError(
            f"{len(param_names)} param names for {len(jaxpr.invars)} jaxpr invars"
        )
    # every invar stays a parameter (the feed contract covers unused args)
    for pname, var in zip(param_names, jaxpr.invars, strict=False):
        env[var] = b.parameter(
            pname, tuple(var.aval.shape), np.dtype(var.aval.dtype)
        )
    lw.lower_eqns(env, kept_eqns)
    output_names = _finish_outputs(b, lw, env, jaxpr.outvars)
    return LoweredJaxpr(b.module, list(param_names), output_names)


def _finish_outputs(b: GraphBuilder, lw: _Lowerer, env: Dict, outvars) -> List[str]:
    """Shared lowering tail: root sinks for the outputs + orphan sweep.

    Outputs must be module roots (the executor returns sink values).  An
    output that aliases a parameter/constant, an interior value with other
    users, or a repeated output gets a value-preserving reshape sink.

    The sweep removes instructions orphaned by peepholes (the commuted-dot
    rewrite leaves the original dot user-less when nothing else reads it) —
    a user-less non-output would otherwise become a phantom module root the
    executor computes and returns on every call.  Parameters stay: the feed
    contract covers unused arguments.
    """
    out_tensors = [lw.read(env, ov) for ov in outvars]
    dup = Counter(t.instr.id for t in out_tensors)
    output_names: List[str] = []
    for t in out_tensors:
        instr = t.instr
        if (
            instr.users
            or dup[instr.id] > 1
            or instr.opcode in ("parameter", "constant")
        ):
            t = b.reshape(t, instr.shape)
            instr = t.instr
        output_names.append(instr.name)

    out_names = set(output_names)
    changed = True
    while changed:
        changed = False
        for instr in list(b.module.instructions):
            if (
                not instr.users
                and instr.opcode != "parameter"
                and instr.name not in out_names
            ):
                b.module.instructions.remove(instr)
                for op in instr.operands:
                    op.users.remove(instr)
                changed = True
    b.module.verify()
    return output_names


@dataclass
class LoweredShardedJaxpr(LoweredJaxpr):
    """A shard_map-captured function: the PER-SHARD module plus the mesh
    placement the one multi-device ExecutionPlan replays under.

    ``param_layouts`` maps parameter names to ``core.shard`` layout tuples
    (from the shard_map ``in_names``); ``out_layouts`` is one layout per
    module root, in ``module.roots`` order — exactly what
    ``compile_module(..., mesh=, param_layouts=, out_layouts=)`` takes.
    """

    mesh: object = None
    mesh_axes: Tuple = ()
    param_layouts: Dict[str, Tuple] = None
    out_layouts: List = None


def lower_sharded_jaxpr(
    closed_jaxpr,
    *,
    name: str = "stitched",
    fuse_dot: bool = True,
    param_names: Optional[Sequence[str]] = None,
) -> LoweredShardedJaxpr:
    """Lower a jaxpr whose whole body is ONE ``shard_map`` eqn.

    The caller traces ``shard_map(fn, mesh, in_specs, out_specs)`` at
    GLOBAL shapes (``frontend.api`` does this when ``stitch`` is given a
    mesh); jax leaves a single shard_map eqn whose inner jaxpr is the
    per-shard computation — local shapes, collectives as psum-family eqns.
    That inner jaxpr is what lowers to StitchIR: fusion and the latency
    model then score per-shard tiles with no further changes, and the
    shard_map placement (mesh + in/out names) rides along for the
    ShardingPass and the executor's replay.

    Closure constants are hoisted by jax to the OUTER jaxpr and enter the
    shard_map as extra replicated operands — those materialize as IR
    constants.  A constant operand that shard_map expects SHARDED has no
    global value to slice here and raises ``UnsupportedPrimitiveError``.
    """
    from ..core.shard import mesh_axes_of, names_to_layout

    jaxpr = closed_jaxpr.jaxpr
    sm = [e for e in jaxpr.eqns if e.primitive.name == "shard_map"]
    if len(sm) != 1 or len(jaxpr.eqns) != 1:
        raise UnsupportedPrimitiveError(
            "shard_map", None,
            "sharded capture expects the traced function to be exactly one "
            "shard_map call wrapping the whole computation",
        )
    eqn = sm[0]
    mesh = eqn.params["mesh"]
    inner = eqn.params["jaxpr"]          # raw per-shard Jaxpr (no constvars)
    in_names = eqn.params["in_names"]
    out_names_p = eqn.params["out_names"]

    outer_args = {v: i for i, v in enumerate(jaxpr.invars)}
    consts = dict(zip(jaxpr.constvars, closed_jaxpr.consts, strict=False))
    if param_names is None:
        param_names = [f"arg{i}" for i in range(len(jaxpr.invars))]
    if len(param_names) != len(jaxpr.invars):
        raise ValueError(
            f"{len(param_names)} param names for {len(jaxpr.invars)} jaxpr invars"
        )

    b = GraphBuilder(name)
    lw = _Lowerer(b, fuse_dot)
    kept_eqns, live = _live_eqns(inner.eqns, inner.outvars)
    lw.live = live
    env: Dict = {}
    used_names: List[str] = []
    param_layouts: Dict[str, Tuple] = {}
    # Parameters first, in outer-arg order, so the executor's positional
    # contract matches the user's flattened arguments; constant operands
    # (hoisted closures) fold afterwards.
    binds = sorted(
        range(len(eqn.invars)),
        key=lambda k: (
            outer_args.get(eqn.invars[k], len(outer_args)) if not isinstance(
                eqn.invars[k], Literal) else len(outer_args),
            k,
        ),
    )
    for k in binds:
        atom = eqn.invars[k]
        ivar = inner.invars[k]
        rank = len(ivar.aval.shape)
        layout = names_to_layout(in_names[k], rank)
        if not isinstance(atom, Literal) and atom in outer_args:
            pname = param_names[outer_args[atom]]
            env[ivar] = b.parameter(
                pname, tuple(ivar.aval.shape), np.dtype(ivar.aval.dtype)
            )
            used_names.append(pname)
            param_layouts[pname] = layout
            continue
        if any(e for e in layout):
            raise UnsupportedPrimitiveError(
                "shard_map", eqn,
                "a closure constant enters the shard_map sharded; only "
                "replicated closure constants are supported — pass sharded "
                "values as function arguments",
            )
        val = atom.val if isinstance(atom, Literal) else consts[atom]
        env[ivar] = b.constant(np.asarray(val))
    lw.lower_eqns(env, kept_eqns)
    output_names = _finish_outputs(b, lw, env, inner.outvars)

    out_layout_by_name = {
        oname: names_to_layout(names, len(ov.aval.shape))
        for oname, ov, names in zip(output_names, inner.outvars, out_names_p, strict=False)
    }
    out_layouts = [
        out_layout_by_name.get(r.name) for r in b.module.roots
    ]
    return LoweredShardedJaxpr(
        b.module,
        used_names,
        output_names,
        mesh=mesh,
        mesh_axes=mesh_axes_of(mesh),
        param_layouts=param_layouts,
        out_layouts=out_layouts,
    )
