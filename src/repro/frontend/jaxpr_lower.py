"""Lower a jaxpr captured from a real JAX function into StitchIR.

The paper's compiler consumes *framework-captured* computations (TF graphs
fed to XLA as HLO), not hand-transcribed IR.  This module closes that gap
for the reproduction: ``lower_jaxpr`` walks a ``ClosedJaxpr`` (produced by
``jax.make_jaxpr`` on shaped arguments) and emits the equivalent StitchIR
``Module`` through the existing ``GraphBuilder``, so the unchanged pass
pipeline (fusion -> schedule -> memory -> codegen) compiles real
``jax.numpy`` programs.

Lowering rules worth knowing:

  * jaxprs broadcast *implicitly* in two places StitchIR does not: scalar
    literals appear directly as elementwise operands (``mul a 0.17``), and
    rank-equal operands may carry degenerate (size-1) dims (``sub f[...,16]
    h[...,1]``).  ``_to_shape`` materializes both as explicit ``broadcast``
    instructions — the same shape ops a hand-built graph writes.
  * ``dot_general`` is canonicalized to StitchIR's batched-matmul ``dot``
    (contract lhs[-1] with rhs[-2], leading batch dims) via transposes and
    reshapes; the common ``q @ k.T`` layouts lower with no extra ops.
  * call-like primitives (``pjit``, ``custom_jvp_call``, ...) are inlined
    recursively, so ``jax.nn`` activations and ``jnp.where`` lower to their
    bodies instead of failing on the wrapper.
  * literals and closure constants fold as IR ``constant``s; the compiler's
    constant folding evaluates them once at plan-build time.

Anything else raises ``UnsupportedPrimitiveError`` naming the primitive and
its eqn (``repro.stitch`` turns that into a plain ``jax.jit`` fallback when
``on_unsupported="fallback"``).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from jax.extend.core import Literal

from ..core.ir import GraphBuilder, Module, Tensor, _prod


# --------------------------------------------------------------------------
# Primitive tables (the README "supported primitives" table is generated
# from these — keep names in sync with jax.lax primitive names)
# --------------------------------------------------------------------------

#: jaxpr unary primitive -> StitchIR elementwise fn
UNARY_PRIMS: Dict[str, str] = {
    "exp": "exp",
    "log": "log",
    "tanh": "tanh",
    "sqrt": "sqrt",
    "rsqrt": "rsqrt",
    "neg": "neg",
    "abs": "abs",
    "sign": "sign",
    "floor": "floor",
    "logistic": "sigmoid",
    "not": "not",
}

#: jaxpr binary primitive -> StitchIR elementwise fn
BINARY_PRIMS: Dict[str, str] = {
    "add": "add",
    "sub": "sub",
    "mul": "mul",
    "div": "div",
    "max": "max",
    "min": "min",
    "pow": "pow",
    "lt": "lt",
    "le": "le",
    "gt": "gt",
    "ge": "ge",
    "eq": "eq",
    "ne": "ne",
    "and": "and",
    "or": "or",
}

#: jaxpr reduce primitive -> StitchIR reduce kind
REDUCE_PRIMS: Dict[str, str] = {
    "reduce_sum": "sum",
    "reduce_max": "max",
    "reduce_min": "min",
    "reduce_prod": "prod",
}

#: value-preserving primitives lowered as aliases (no instruction emitted;
#: device placement is meaningless in StitchIR, so device_put aliases too)
IDENTITY_PRIMS = frozenset({"stop_gradient", "copy", "device_put"})

#: call-like primitives whose inner jaxpr is inlined ("remat2" is the
#: primitive jax.checkpoint/jax.remat actually emit)
CALL_PRIMS = frozenset(
    {"pjit", "closed_call", "core_call", "custom_jvp_call", "custom_vjp_call",
     "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr", "remat", "remat2",
     "checkpoint"}
)

#: structural primitives with bespoke lowerings below
STRUCTURAL_PRIMS = frozenset(
    {"dot_general", "broadcast_in_dim", "transpose", "reshape", "squeeze",
     "convert_element_type", "select_n", "integer_pow", "concatenate",
     "iota", "square"}
)

SUPPORTED_PRIMITIVES = frozenset(
    set(UNARY_PRIMS) | set(BINARY_PRIMS) | set(REDUCE_PRIMS)
    | IDENTITY_PRIMS | CALL_PRIMS | STRUCTURAL_PRIMS
)


class UnsupportedPrimitiveError(NotImplementedError):
    """A jaxpr primitive the frontend cannot lower to StitchIR.

    Carries the primitive name (``.primitive``) and the offending eqn
    (``.eqn``) so callers can report exactly what blocked the capture.
    """

    def __init__(self, primitive, eqn=None, reason: str = ""):
        self.primitive = str(primitive)
        self.eqn = eqn
        msg = f"jaxpr primitive '{self.primitive}' is not supported by repro.stitch"
        if reason:
            msg += f" ({reason})"
        if eqn is not None:
            msg += f"\n  in eqn: {eqn}"
        msg += (
            f"\nsupported primitives: {', '.join(sorted(SUPPORTED_PRIMITIVES))}"
            "\nhint: stitch(fn, on_unsupported='fallback') runs the whole "
            "function through plain jax.jit instead of failing."
        )
        super().__init__(msg)


@dataclass
class LoweredJaxpr:
    """A captured function: the StitchIR module plus its calling convention.

    ``param_names`` name the module parameters in flattened-argument order;
    ``output_names`` name one module root per flattened output (outputs that
    alias a parameter/constant or an interior value get a value-preserving
    ``reshape`` sink so the executor materializes them).
    """

    module: Module
    param_names: List[str]
    output_names: List[str]


def _is_dropvar(v) -> bool:
    return type(v).__name__ == "DropVar"


def _live_eqns(eqns, live_outvars):
    """Reverse-liveness DCE over a jaxpr's eqns.

    ``jax.make_jaxpr`` does NOT dead-code-eliminate (jax.jit's DCE happens
    in XLA, after our capture point), so unused intermediates would lower
    to user-less instructions — which the compiler treats as module roots
    and computes on every call.  Keep only eqns whose outputs are
    (transitively) live; call-like eqns are treated atomically, with the
    same pruning applied to their inner jaxpr during inlining.

    Returns ``(kept_eqns, live_vars)`` — ``live_vars`` additionally gates
    constvar materialization (a dead closure constant must not become a
    user-less IR constant, i.e. a module root).

    Side-effecting eqns (``jax.debug.print``, ``io_callback``, ...) are
    always kept even with no live outputs: silently dropping an effect
    would diverge from ``jax.jit``, so they must reach the lowering and
    raise ``UnsupportedPrimitiveError`` (or trigger fallback) instead."""
    live = {v for v in live_outvars if not isinstance(v, Literal)}
    kept = []
    for eqn in reversed(eqns):
        if getattr(eqn, "effects", None) or any(
            not _is_dropvar(v) and v in live for v in eqn.outvars
        ):
            kept.append(eqn)
            live.update(v for v in eqn.invars if not isinstance(v, Literal))
    kept.reverse()
    return kept, live


class _Lowerer:
    def __init__(self, builder: GraphBuilder, fuse_dot: bool):
        self.b = builder
        self.fuse_dot = fuse_dot

    # -- environment ------------------------------------------------------
    def read(self, env: Dict, atom) -> Tensor:
        if isinstance(atom, Literal):
            val = np.asarray(atom.val, dtype=atom.aval.dtype)
            return self.b.constant(val)
        return env[atom]

    def to_shape(self, t: Tensor, shape: Sequence[int]) -> Tensor:
        """Materialize jaxpr implicit broadcasting (scalars + size-1 dims)."""
        shape = tuple(int(s) for s in shape)
        if tuple(t.shape) == shape:
            return t
        if t.ndim == 0:
            return self.b.broadcast(t, shape, ())
        if t.ndim == len(shape):
            return self.b.broadcast(t, shape, tuple(range(t.ndim)))
        raise ValueError(
            f"cannot broadcast rank-{t.ndim} value {tuple(t.shape)} to {shape}"
        )

    # -- eqn dispatch -----------------------------------------------------
    def lower_eqns(self, env: Dict, eqns) -> None:
        for eqn in eqns:
            self.lower_eqn(env, eqn)

    def lower_eqn(self, env: Dict, eqn) -> None:
        prim = eqn.primitive.name
        if prim in CALL_PRIMS:
            self._inline_call(env, eqn)
            return
        outs = self._lower_value_eqn(env, eqn)
        for var, t in zip(eqn.outvars, outs):
            if not _is_dropvar(var):
                env[var] = t

    def _inline_call(self, env: Dict, eqn) -> None:
        sub = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in eqn.params:
                sub = eqn.params[key]
                break
        if sub is None:
            raise UnsupportedPrimitiveError(
                eqn.primitive.name, eqn, "call primitive with no inner jaxpr"
            )
        inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
        consts = sub.consts if hasattr(sub, "consts") else []
        args = [self.read(env, v) for v in eqn.invars]
        if len(args) != len(inner.invars):
            raise UnsupportedPrimitiveError(
                eqn.primitive.name, eqn,
                f"arity mismatch inlining inner jaxpr "
                f"({len(args)} args vs {len(inner.invars)} invars)",
            )
        live_outs = [
            iv for ov, iv in zip(eqn.outvars, inner.outvars)
            if not _is_dropvar(ov)
        ]
        kept, live = _live_eqns(inner.eqns, live_outs)
        sub_env: Dict = {}
        for var, const in zip(inner.constvars, consts):
            if var in live:
                sub_env[var] = self.b.constant(np.asarray(const))
        for var, t in zip(inner.invars, args):
            sub_env[var] = t
        self.lower_eqns(sub_env, kept)
        for outer, inner_out in zip(eqn.outvars, inner.outvars):
            if not _is_dropvar(outer):
                env[outer] = self.read(sub_env, inner_out)

    def _lower_value_eqn(self, env: Dict, eqn) -> List[Tensor]:
        prim = eqn.primitive.name
        b = self.b
        out_aval = eqn.outvars[0].aval

        if prim in IDENTITY_PRIMS:
            return [self.read(env, eqn.invars[0])]

        if prim in UNARY_PRIMS:
            return [b.unary(UNARY_PRIMS[prim], self.read(env, eqn.invars[0]))]

        if prim in BINARY_PRIMS:
            lhs = self.to_shape(self.read(env, eqn.invars[0]), out_aval.shape)
            rhs = self.to_shape(self.read(env, eqn.invars[1]), out_aval.shape)
            return [b.binary(BINARY_PRIMS[prim], lhs, rhs)]

        if prim in REDUCE_PRIMS:
            x = self.read(env, eqn.invars[0])
            axes = tuple(eqn.params["axes"])
            if not axes:  # reduce over no axes is the identity
                return [x]
            return [b.reduce(x, axes, REDUCE_PRIMS[prim])]

        if prim == "square":
            return [b.square(self.read(env, eqn.invars[0]))]

        if prim == "integer_pow":
            return [self._integer_pow(env, eqn)]

        if prim == "convert_element_type":
            x = self.read(env, eqn.invars[0])
            new = np.dtype(eqn.params["new_dtype"])
            if np.dtype(x.dtype) == new:
                return [x]
            return [b.convert(x, new)]

        if prim == "broadcast_in_dim":
            x = self.read(env, eqn.invars[0])
            shape = tuple(int(s) for s in eqn.params["shape"])
            dims = tuple(eqn.params["broadcast_dimensions"])
            if tuple(x.shape) == shape and dims == tuple(range(x.ndim)):
                return [x]
            return [b.broadcast(x, shape, dims)]

        if prim == "transpose":
            x = self.read(env, eqn.invars[0])
            perm = tuple(eqn.params["permutation"])
            if perm == tuple(range(x.ndim)):
                return [x]
            return [b.transpose(x, perm)]

        if prim == "reshape":
            if eqn.params.get("dimensions") is not None:
                raise UnsupportedPrimitiveError(
                    prim, eqn, "reshape with a dimensions permutation"
                )
            x = self.read(env, eqn.invars[0])
            new = tuple(int(s) for s in eqn.params["new_sizes"])
            if tuple(x.shape) == new:
                return [x]
            return [b.reshape(x, new)]

        if prim == "squeeze":
            x = self.read(env, eqn.invars[0])
            return [b.reshape(x, tuple(int(s) for s in out_aval.shape))]

        if prim == "concatenate":
            xs = [self.read(env, v) for v in eqn.invars]
            return [b.concat(xs, int(eqn.params["dimension"]))]

        if prim == "iota":
            shape = tuple(int(s) for s in eqn.params["shape"])
            return [b.iota(shape, int(eqn.params["dimension"]),
                           np.dtype(eqn.params["dtype"]))]

        if prim == "select_n":
            if len(eqn.invars) != 3:
                raise UnsupportedPrimitiveError(
                    prim, eqn, f"{len(eqn.invars) - 1}-case select "
                    "(only boolean 2-case select is supported)"
                )
            pred = self.to_shape(self.read(env, eqn.invars[0]), out_aval.shape)
            if np.dtype(pred.dtype) != np.dtype(np.bool_):
                raise UnsupportedPrimitiveError(
                    prim, eqn, "select_n with a non-boolean selector"
                )
            # select_n(pred, *cases): cases[0] is the False branch
            on_false = self.to_shape(self.read(env, eqn.invars[1]), out_aval.shape)
            on_true = self.to_shape(self.read(env, eqn.invars[2]), out_aval.shape)
            return [b.select(pred, on_true, on_false)]

        if prim == "dot_general":
            return [self._dot_general(env, eqn)]

        raise UnsupportedPrimitiveError(prim, eqn)

    # -- bespoke lowerings ------------------------------------------------
    def _integer_pow(self, env: Dict, eqn) -> Tensor:
        """x ** n as XLA lowers it: repeated multiplication (never a
        transcendental ``pow``, which diverges on negative bases)."""
        b = self.b
        x = self.read(env, eqn.invars[0])
        n = int(eqn.params["y"])
        if n == 0:
            one = b.constant(np.asarray(1, dtype=x.dtype))
            return self.to_shape(one, x.shape)
        out = x
        if abs(n) == 2:
            out = b.square(x)
        else:
            for _ in range(abs(n) - 1):
                out = b.binary("mul", out, x)
        if n < 0:
            out = b.unary("reciprocal", out)
        return out

    def _dot_general(self, env: Dict, eqn) -> Tensor:
        """Canonicalize an arbitrary dot_general to StitchIR ``dot``:
        (batch..., M, K) x (batch..., K, N) with leading batch dims, via
        transposes/reshapes.  The output dim order of dot_general —
        (batch, lhs free, rhs free) — is exactly what the canonical form
        produces, so a final reshape restores the declared shape."""
        b = self.b
        lhs = self.read(env, eqn.invars[0])
        rhs = self.read(env, eqn.invars[1])
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lc, rc, lb, rb = map(tuple, (lc, rc, lb, rb))
        out_aval = eqn.outvars[0].aval
        lfree = tuple(d for d in range(lhs.ndim) if d not in lc and d not in lb)
        rfree = tuple(d for d in range(rhs.ndim) if d not in rc and d not in rb)

        def permute(t: Tensor, perm: Tuple[int, ...]) -> Tensor:
            if perm == tuple(range(t.ndim)):
                return t
            return b.transpose(t, perm)

        left = permute(lhs, lb + lfree + lc)
        right = permute(rhs, rb + rc + rfree)
        batch = tuple(int(lhs.shape[d]) for d in lb)
        m = _prod([lhs.shape[d] for d in lfree])
        k = _prod([lhs.shape[d] for d in lc])
        n = _prod([rhs.shape[d] for d in rfree])
        if tuple(left.shape) != batch + (m, k):
            left = b.reshape(left, batch + (m, k))
        if tuple(right.shape) != batch + (k, n):
            right = b.reshape(right, batch + (k, n))
        out = b.dot(left, right, fusable=self.fuse_dot)
        out_shape = tuple(int(s) for s in out_aval.shape)
        if tuple(out.shape) != out_shape:
            out = b.reshape(out, out_shape)
        if np.dtype(out.dtype) != np.dtype(out_aval.dtype):
            out = b.convert(out, out_aval.dtype)
        return out


def lower_jaxpr(
    closed_jaxpr,
    *,
    name: str = "stitched",
    fuse_dot: bool = True,
    param_names: Optional[Sequence[str]] = None,
) -> LoweredJaxpr:
    """Lower a ``ClosedJaxpr`` into a StitchIR ``Module``.

    ``param_names`` (optional) names the module parameters, one per jaxpr
    invar; defaults to ``arg0..argN``.  ``fuse_dot`` sets the per-dot
    ``fusable`` attr (the paper's user decision — ``StitchOptions.fuse_dot``
    flows through here from ``repro.stitch``).
    """
    jaxpr = closed_jaxpr.jaxpr
    b = GraphBuilder(name)
    lw = _Lowerer(b, fuse_dot)
    kept_eqns, live = _live_eqns(jaxpr.eqns, jaxpr.outvars)
    env: Dict = {}
    for var, const in zip(jaxpr.constvars, closed_jaxpr.consts):
        if var in live:
            env[var] = b.constant(np.asarray(const))
    if param_names is None:
        param_names = [f"arg{i}" for i in range(len(jaxpr.invars))]
    if len(param_names) != len(jaxpr.invars):
        raise ValueError(
            f"{len(param_names)} param names for {len(jaxpr.invars)} jaxpr invars"
        )
    # every invar stays a parameter (the feed contract covers unused args)
    for pname, var in zip(param_names, jaxpr.invars):
        env[var] = b.parameter(
            pname, tuple(var.aval.shape), np.dtype(var.aval.dtype)
        )
    lw.lower_eqns(env, kept_eqns)

    # Outputs must be module roots (the executor returns sink values).  An
    # output that aliases a parameter/constant, an interior value with other
    # users, or a repeated output gets a value-preserving reshape sink.
    out_tensors = [lw.read(env, ov) for ov in jaxpr.outvars]
    dup = Counter(t.instr.id for t in out_tensors)
    output_names: List[str] = []
    for t in out_tensors:
        instr = t.instr
        if (
            instr.users
            or dup[instr.id] > 1
            or instr.opcode in ("parameter", "constant")
        ):
            t = b.reshape(t, instr.shape)
            instr = t.instr
        output_names.append(instr.name)
    b.module.verify()
    return LoweredJaxpr(b.module, list(param_names), output_names)
