"""``python -m repro.lint`` — compile-and-verify smoke linter.

Compiles every benchmark graph (or a named subset) under forced
``verify="strict"`` in both planner modes and prints one row per compile.
Any verifier diagnostic — a broken IR invariant, an illegal fusion, an
unsound schedule, a slot race in the ExecutionPlan — fails the run with
exit status 1 and the full structured diagnostics on stderr.  CI runs this
over all ten bench graphs as a hard gate; it is also the quickest local
answer to "did my pass change break an invariant somewhere?".

Usage::

    python -m repro.lint                       # all graphs, both planners
    python -m repro.lint --graphs LR,NMT       # subset
    python -m repro.lint --planner greedy      # one planner mode
    python -m repro.lint --max-blocks 64

Run from the repository root with ``PYTHONPATH=src`` (the benchmark graph
registry lives in ``benchmarks/``, outside the installed package).
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from repro.core import StitchOptions, VerificationError, compile_module


def _load_graphs():
    try:
        from benchmarks.graphs import ALL_GRAPHS
    except ImportError as e:
        raise SystemExit(
            "repro.lint needs the benchmark graph registry; run from the "
            f"repository root (import failed: {e})"
        ) from e
    return ALL_GRAPHS


def lint_graph(name: str, module, planner: str, max_blocks: int) -> List[str]:
    """Compile one graph under strict verification; return failure lines."""
    opts = StitchOptions(
        max_blocks=max_blocks, planner=planner, verify="strict"
    )
    try:
        cm = compile_module(module, opts)
    except VerificationError as e:
        return [f"{name} [{planner}] {d}" for d in e.diagnostics]
    except Exception as e:  # noqa: BLE001 — a lint driver reports, never hides
        return [f"{name} [{planner}] compile failed: {type(e).__name__}: {e}"]
    s = cm.stats
    print(
        f"  {name:<14} {planner:<7} "
        f"kernels={s.stitched_kernels + s.standalone_kernels:<3} "
        f"boundaries={s.verify_boundaries} warnings={s.verify_warnings} "
        f"verify={s.verify_time_s * 1e3:.1f}ms"
    )
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.lint",
        description="strict-verify compile lint over the benchmark graphs",
    )
    ap.add_argument(
        "--graphs",
        default="",
        help="comma-separated graph names (default: all)",
    )
    ap.add_argument(
        "--planner",
        default="both",
        choices=("cost", "greedy", "both"),
        help="planner mode(s) to lint under",
    )
    ap.add_argument("--max-blocks", type=int, default=64)
    args = ap.parse_args(argv)

    registry = _load_graphs()
    names = (
        [n.strip() for n in args.graphs.split(",") if n.strip()]
        if args.graphs
        else list(registry)
    )
    unknown = [n for n in names if n not in registry]
    if unknown:
        ap.error(f"unknown graph(s) {unknown}; choices: {sorted(registry)}")
    planners = ("cost", "greedy") if args.planner == "both" else (args.planner,)

    print(f"repro.lint: {len(names)} graph(s) x {len(planners)} planner mode(s)")
    failures: List[str] = []
    for name in names:
        module = registry[name]()
        for planner in planners:
            failures.extend(
                lint_graph(name, module, planner, args.max_blocks)
            )
    if failures:
        print(f"\n{len(failures)} diagnostic(s):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("clean: zero diagnostics")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
