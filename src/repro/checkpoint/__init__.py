from .manager import CheckpointManager
