"""Atomic, resumable checkpointing.

Layout: <dir>/step_<n>/ arrays.npz + META with the step; writes go to a
tmp dir and are ``os.replace``d into place (crash-safe — a partially
written checkpoint is never visible).  ``restore_latest`` scans for the
newest complete step.  Keeps the last K checkpoints.

Arrays are stored as full (unsharded) host arrays; restoring onto a
*different* mesh/device-count is therefore trivial (the elastic module
re-shards on load), at the cost of host-side gather — the standard
full-replica checkpoint strategy; per-shard async writes are noted as the
production extension in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..train.optimizer import AdamWState


def _flatten_with_paths(tree) -> dict:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}", node[k])
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = np.asarray(node)

    walk("", tree)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, params, opt_state: AdamWState) -> str:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        host_params = jax.tree.map(np.asarray, params)
        host_m = jax.tree.map(np.asarray, opt_state.m)
        host_v = jax.tree.map(np.asarray, opt_state.v)
        host_step = int(opt_state.step)

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "params.npz"), **_flatten_with_paths(host_params))
            np.savez(os.path.join(tmp, "opt_m.npz"), **_flatten_with_paths(host_m))
            np.savez(os.path.join(tmp, "opt_v.npz"), **_flatten_with_paths(host_v))
            with open(os.path.join(tmp, "META"), "w") as f:
                json.dump({"step": step, "opt_step": host_step}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)          # atomic publish
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()
        return os.path.join(self.dir, f"step_{step}")

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.available_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def available_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "META")
            ):
                out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def restore(self, step: int, like_params, like_opt: AdamWState):
        base = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(base, "META")) as f:
            meta = json.load(f)
        params = _unflatten_like(
            like_params, np.load(os.path.join(base, "params.npz"))
        )
        m = _unflatten_like(like_opt.m, np.load(os.path.join(base, "opt_m.npz")))
        v = _unflatten_like(like_opt.v, np.load(os.path.join(base, "opt_v.npz")))
        opt = AdamWState(jnp.asarray(meta["opt_step"], jnp.int32), m, v)
        return params, opt, meta["step"]

    def restore_latest(self, like_params=None, like_opt=None):
        steps = self.available_steps()
        if not steps:
            return None
        if like_params is None:
            # structure-free load requires templates; the Trainer passes them
            raise ValueError("restore_latest needs template pytrees")
        return self.restore(steps[-1], like_params, like_opt)


def _unflatten_like(template, npz) -> Any:
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}", node[k]) for k in sorted(node)}
        if isinstance(node, (tuple, list)):
            vals = [walk(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(vals) if not hasattr(node, "_fields") else type(node)(*vals)
        arr = npz[prefix]
        return jnp.asarray(arr, dtype=node.dtype if hasattr(node, "dtype") else None)

    return walk("", template)
