"""Stitched Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling).

Each kernel is a productionized output of the FusionStitching machinery:
<name>.py holds the pallas_call + BlockSpecs, ops.py the jit'd public
wrappers, ref.py the pure-jnp oracles the tests sweep against.
"""
from . import ops, ref
from .ops import attention, attention_decode, moe_gate, rmsnorm, softmax
