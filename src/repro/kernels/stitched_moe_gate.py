"""Stitched MoE router gate — softmax + top-k + renormalize in ONE kernel.

The router chain (softmax over experts, k iterated arg-maxes, renormalize)
is exactly the fine-granularity multi-op pattern FusionStitching targets:
XLA's baseline splits it at every reduce.  One Row-schedule grid over token
blocks; the expert dim (small) lives entirely in-block; the top-k loop is
unrolled (k is static and <= 8 for every assigned architecture).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_kernel(top_k, w_ref_dtype, x_ref, w_ref, i_ref):
    x = x_ref[...].astype(jnp.float32)                     # (bt, E)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)             # softmax
    total = jnp.zeros((p.shape[0], 1), jnp.float32)
    picks_w, picks_i = [], []
    cur = p
    for _ in range(top_k):                                 # unrolled top-k
        wi = jnp.max(cur, axis=-1)
        ii = jnp.argmax(cur, axis=-1).astype(jnp.int32)
        picks_w.append(wi)
        picks_i.append(ii)
        total = total + wi[:, None]
        onehot = jax.nn.one_hot(ii, cur.shape[-1], dtype=jnp.float32)
        cur = cur - onehot * 2.0                           # mask out the pick
    w = jnp.stack(picks_w, axis=-1) / total                # renormalize
    i = jnp.stack(picks_i, axis=-1)
    w_ref[...] = w.astype(w_ref.dtype)
    i_ref[...] = i


@functools.partial(
    jax.jit, static_argnames=("top_k", "block_tokens", "interpret")
)
def stitched_moe_gate(
    logits: jax.Array,          # (T, E)
    top_k: int,
    block_tokens: int = 256,
    interpret: bool = True,
):
    T, E = logits.shape
    bt = min(block_tokens, T)
    while T % bt:
        bt -= 1
    w, i = pl.pallas_call(
        functools.partial(_gate_kernel, top_k, jnp.float32),
        grid=(T // bt,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0))],
        out_specs=[
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, top_k), jnp.float32),
            jax.ShapeDtypeStruct((T, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
    return w, i
