"""Stitched softmax — the paper's Figure-3 chain as ONE Pallas kernel.

XLA's baseline emits the max-reduce / exp / sum-reduce / divide chain as up
to four kernels (expensive-op duplication rules, §1).  Block composition
stitches them: each grid program owns a Row-schedule chunk of rows
(split_dim = 0 over the flattened row space — the schedule the core tuner
picks for this pattern) and the reduce intermediaries live in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)        # Reduce.1 (rows in VREGs)
    e = jnp.exp(x - m)                            # Exponential.1
    s = jnp.sum(e, axis=-1, keepdims=True)        # Reduce.2
    o_ref[...] = (e / s).astype(o_ref.dtype)      # Divide.1


def choose_block_rows(rows: int, cols: int, itemsize: int,
                      vmem_budget: int = 4 * 1024 * 1024) -> int:
    """Row-schedule sword selection: as many rows per block as fit the VMEM
    budget (x tile + f32 intermediates), rounded to the (8,) sublane."""
    per_row = cols * (itemsize + 4)
    br = max(1, vmem_budget // max(per_row, 1))
    br = min(br, rows)
    if br >= 8:
        br = (br // 8) * 8
    while rows % br:
        br -= 1
    return max(br, 1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stitched_softmax(
    x: jax.Array,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Softmax over the last dim; leading dims are flattened into rows."""
    orig_shape = x.shape
    cols = orig_shape[-1]
    rows = x.size // cols
    x2 = x.reshape(rows, cols)
    br = block_rows or choose_block_rows(rows, cols, x.dtype.itemsize)
    assert rows % br == 0, f"rows {rows} % block_rows {br} != 0"
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)
