"""Stitched attention — the paper's softmax×BatchDot pattern (Fig. 3) taken
to its TPU-native conclusion.

The motivating example stitches exp/reduce/divide with a BatchMatMul through
shared memory.  On TPU we adapt the insight rather than port the CUDA
schedule: the KV sequence is streamed block-by-block through VMEM while the
softmax intermediaries (running max m, running sum l, f32 accumulator) are
*resident in VMEM scratch across grid steps* — an online-softmax
(flash-style) schedule.  This is block composition where the scratch hand-off
additionally carries state across blocks, which is what the sequential TPU
grid (unlike independent CUDA CTAs) makes possible.

Two kernels:
  * ``flash_attention``  — prefill/training: grid (B, Hq, nq, nkv), causal.
  * ``decode_attention`` — one new token vs a KV cache with per-batch valid
    lengths: grid (B, Hq, nkv).

GQA is handled in the K/V index maps (kv head = q head // group); MQA is the
kv=1 special case.  All arithmetic is f32 in-kernel regardless of I/O dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


# ----------------------------------------------------------------- prefill
def _flash_kernel(scale, causal, bq, bk, nkv, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = True
    if causal:
        # skip fully-masked KV blocks (strictly above the diagonal)
        run = ik * bk <= iq * bq + bq - 1

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                                  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,               # (B, Hq, S, D)
    k: jax.Array,               # (B, Hkv, S, D)
    v: jax.Array,               # (B, Hkv, S, D)
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nkv = S // bq, S // bk

    grid = (B, Hq, nq, nkv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale, causal, bq, bk, nkv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, D), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out


# ----------------------------------------------------------------- decode
def _decode_kernel(scale, bk, nkv, q_ref, k_ref, v_ref, len_ref, o_ref,
                   acc_ref, m_ref, l_ref):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]

    @pl.when(ik * bk < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (1, d)
        kb = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        vb = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # (1, bk)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,               # (B, Hq, D) — one new token per sequence
    k: jax.Array,               # (B, Hkv, S, D) KV cache
    v: jax.Array,               # (B, Hkv, S, D)
    lengths: jax.Array,         # (B,) int32 valid lengths
    scale: float | None = None,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, S)
    assert S % bk == 0
    nkv = S // bk
    q4 = q.reshape(B, Hq, 1, D)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale, bk, nkv),
        grid=(B, Hq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        scratch_shapes=[
            _vmem((1, D), jnp.float32),
            _vmem((1, 1), jnp.float32),
            _vmem((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k, v, lengths.astype(jnp.int32))
    return out.reshape(B, Hq, D)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except ImportError:  # pragma: no cover
        return pl.MemorySpace.ANY  # type: ignore
