"""Public jit'd wrappers for the stitched Pallas kernels.

``interpret`` defaults to True off-TPU (CPU validation per the brief) and
False on TPU, where the kernels compile to real Mosaic.
"""
from __future__ import annotations

import jax

from .ref import (
    attention_ref,
    decode_attention_ref,
    moe_gate_ref,
    rmsnorm_ref,
    softmax_ref,
)
from .stitched_attention import decode_attention, flash_attention
from .stitched_moe_gate import stitched_moe_gate
from .stitched_rmsnorm import stitched_rmsnorm
from .stitched_softmax import stitched_softmax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


def softmax(x, **kw):
    kw.setdefault("interpret", default_interpret())
    return stitched_softmax(x, **kw)


def rmsnorm(x, gamma, eps: float = 1e-6, **kw):
    kw.setdefault("interpret", default_interpret())
    return stitched_rmsnorm(x, gamma, eps=eps, **kw)


def attention(q, k, v, causal: bool = True, **kw):
    kw.setdefault("interpret", default_interpret())
    return flash_attention(q, k, v, causal=causal, **kw)


def attention_decode(q, k, v, lengths, **kw):
    kw.setdefault("interpret", default_interpret())
    return decode_attention(q, k, v, lengths, **kw)


def moe_gate(logits, top_k: int, **kw):
    kw.setdefault("interpret", default_interpret())
    return stitched_moe_gate(logits, top_k, **kw)


__all__ = [
    "softmax", "rmsnorm", "attention", "attention_decode", "moe_gate",
    "softmax_ref", "rmsnorm_ref", "attention_ref", "decode_attention_ref",
    "moe_gate_ref", "flash_attention", "decode_attention",
    "stitched_softmax", "stitched_rmsnorm", "stitched_moe_gate",
    "on_tpu", "default_interpret",
]
