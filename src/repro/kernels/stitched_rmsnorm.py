"""Stitched RMSNorm — square/mean-reduce/rsqrt/mul/mul in one Pallas kernel.

A column-reduce-free Row schedule: rows are split across grid programs, the
mean-square reduce runs entirely inside the block (the paper's constraint
that all reduce dims live in one thread block), and the normalized product
with the gain is emitted in the same kernel — a pattern XLA's baseline splits
at the reduce boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .stitched_softmax import choose_block_rows


def _rmsnorm_kernel(eps, x_ref, g_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)   # Reduce
    inv = jax.lax.rsqrt(ms + eps)                          # expensive ew
    o_ref[...] = (x * inv * g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def stitched_rmsnorm(
    x: jax.Array,
    gamma: jax.Array,
    eps: float = 1e-6,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    assert gamma.shape == (d,)
    rows = x.size // d
    x2 = x.reshape(rows, d)
    br = block_rows or choose_block_rows(rows, d, x.dtype.itemsize)
    assert rows % br == 0
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),      # gain replicated
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out.reshape(orig_shape)
