"""Pure-jnp oracles for every stitched Pallas kernel.

Each kernel in this package is validated against these references over a
sweep of shapes/dtypes (tests/test_kernels_*.py), in interpret mode on CPU
and compiled on real TPU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def softmax_ref(x: jax.Array, axis: int = -1) -> jax.Array:
    """Numerically-stable softmax (the paper's Fig.-3 exp/reduce/div chain)."""
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=axis, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)).astype(
        x.dtype
    )


def attention_ref(
    q: jax.Array,            # (B, Hq, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    causal: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,            # (B, Hq, D)
    k: jax.Array,            # (B, Hkv, S, D)  KV cache
    v: jax.Array,            # (B, Hkv, S, D)
    lengths: jax.Array,      # (B,) int32 valid cache lengths
    scale: Optional[float] = None,
) -> jax.Array:
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)


def moe_gate_ref(
    logits: jax.Array,       # (T, E)
    top_k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Router: softmax over experts, take top-k, renormalize the k weights.

    Returns (weights (T, k) f32, indices (T, k) i32), indices sorted by
    descending weight.
    """
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(p, top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx.astype(jnp.int32)
