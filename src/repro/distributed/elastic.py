"""Elastic scaling: rebuild the mesh from the surviving device count and
re-shard state.

Full-replica checkpoints (checkpoint/manager.py) make re-sharding trivial:
state is loaded as host arrays and ``jax.device_put`` against the NEW mesh's
shardings.  ``choose_mesh_shape`` picks the largest (data, model) grid the
surviving devices support while preserving the model-parallel degree when
possible (TP degree is a property of the weights' divisibility, DP degree is
free to shrink/grow).
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from .sharding import params_shardings


def choose_mesh_shape(
    num_devices: int, prefer_model: int = 16
) -> Tuple[int, int]:
    """(data, model) for the surviving device count."""
    if num_devices < 1:
        raise ValueError(
            f"choose_mesh_shape needs at least one device, got "
            f"num_devices={num_devices}"
        )
    if prefer_model < 1:
        raise ValueError(
            f"prefer_model must be a positive model-parallel degree, got "
            f"{prefer_model}"
        )
    model = min(prefer_model, num_devices)
    while num_devices % model:
        model -= 1
    return num_devices // model, model


def make_elastic_mesh(devices=None, prefer_model: int = 16) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    data, model = choose_mesh_shape(len(devices), prefer_model)
    arr = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def reshard_state(params, opt_state, new_mesh: Mesh):
    """Re-place (host or differently-sharded) state onto a new mesh."""
    from ..train.optimizer import AdamWState
    from .sharding import opt_state_shardings

    pshard = params_shardings(params, new_mesh)
    new_params = jax.tree.map(jax.device_put, params, pshard)
    if opt_state is None:
        return new_params, None
    oshard = opt_state_shardings(opt_state, pshard, new_mesh)
    new_opt = AdamWState(
        step=jax.device_put(opt_state.step, oshard.step),
        m=jax.tree.map(jax.device_put, opt_state.m, oshard.m),
        v=jax.tree.map(jax.device_put, opt_state.v, oshard.v),
    )
    return new_params, new_opt
