"""Collective helpers: bucketed gradient all-reduce with optional
compression, expressed with shard_map + psum (the manual-collective path
used when overlapping cross-pod reduction with compute).

Under plain pjit, XLA inserts gradient all-reduces automatically; these
helpers exist for (a) the compression wire format (bf16/int8 payloads) and
(b) explicit bucketing so DCN transfers pipeline instead of one monolithic
fused all-reduce at the end of the backward pass.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core.shard import wrap_shard_map


def bucket_leaves(tree, bucket_bytes: int = 16 * 1024 * 1024) -> List[List[int]]:
    """Group leaf indices into ~bucket_bytes buckets (reduce-scatter units)."""
    leaves = jax.tree.leaves(tree)
    buckets: List[List[int]] = [[]]
    size = 0
    for i, leaf in enumerate(leaves):
        b = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        if size + b > bucket_bytes and buckets[-1]:
            buckets.append([])
            size = 0
        buckets[-1].append(i)
        size += b
    return buckets


def psum_tree(tree, axis_name: str):
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def bucketed_psum(tree, axis_name: str, bucket_bytes: int = 16 * 1024 * 1024,
                  compress: str = "none"):
    """psum leaf-buckets sequentially; ``compress`` in {none, bf16}.

    Inside shard_map each bucket becomes its own all-reduce op, so XLA's
    scheduler can start early buckets while later grads are still being
    produced (the overlap trick); bf16 halves the wire payload.
    """
    leaves, treedef = jax.tree.flatten(tree)
    buckets = bucket_leaves(tree, bucket_bytes)
    out: List[Any] = [None] * len(leaves)
    for idx in buckets:
        for i in idx:
            x = leaves[i]
            if compress == "bf16":
                r = jax.lax.psum(x.astype(jnp.bfloat16), axis_name)
                out[i] = r.astype(x.dtype)
            else:
                out[i] = jax.lax.psum(x, axis_name)
    return jax.tree.unflatten(treedef, out)


def cross_pod_mean(tree, mesh: Mesh, compress: str = "bf16"):
    """All-reduce-mean a replicated-per-pod gradient pytree across the pod
    axis via shard_map (the explicit cross-DCN reduction)."""
    if "pod" not in mesh.axis_names:
        return tree

    def f(t):
        summed = bucketed_psum(t, "pod", compress=compress)
        n = mesh.shape["pod"]
        return jax.tree.map(lambda x: x / n, summed)

    specs = jax.tree.map(lambda _: P(), tree)
    return wrap_shard_map(f, mesh, (specs,), specs)(tree)
