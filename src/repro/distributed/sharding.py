"""Sharding rules: FSDP(+pod) x TP over the production mesh.

Mesh axes: (``pod``,) ``data``, ``model``.
  * params/optimizer state: the largest shardable dim goes to the fsdp axes
    (pod+data, ZeRO-3 style), a second dim to ``model`` (TP) — divisibility
    checked per-dim with graceful fallback to replication;
  * MoE expert stacks shard the expert dim over ``model`` when divisible
    (expert parallelism), else the ffn dim;
  * activations/batch shard over (pod, data) when the batch divides, else
    over ``data`` alone, else replicate (the long_500k gb=1 cells);
  * vocab-parallel logits: last dim of logits on ``model``.

Everything returns NamedSharding against the passed mesh so the same rules
serve the 16x16 single-pod and 2x16x16 multi-pod dry runs.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               stacked: bool = False) -> P:
    """Sharding spec for one parameter.  ``stacked`` marks a leading
    layer-stack dim (from scan-over-layers) that stays unsharded."""
    fsdp = fsdp_axes(mesh)
    dims: list = [None] * len(shape)
    body = list(range(1, len(shape))) if stacked else list(range(len(shape)))
    if not body:
        return P(*dims)
    # vocab-parallel embedding/unembed: the vocab dim goes to 'model' so the
    # logits come out vocab-sharded (Megatron-style); d to fsdp.
    if ("embed/tok" in path or "embed/unembed" in path) and len(body) == 2:
        a, b = body
        vdim, ddim = (a, b) if shape[a] >= shape[b] else (b, a)
        if _divisible(shape[vdim], mesh, "model"):
            dims[vdim] = "model"
        if _divisible(shape[ddim], mesh, fsdp):
            dims[ddim] = fsdp
        return P(*dims)
    # MoE expert stacks: (L?, E, d, f) — expert dim to model if divisible
    is_expert = "wi" in path or "wg" in path or "wo" in path
    if len(body) == 3 and is_expert:
        e, d, f = body
        if _divisible(shape[e], mesh, "model"):
            dims[e] = "model"
            if _divisible(shape[d], mesh, fsdp):
                dims[d] = fsdp
        else:
            if _divisible(shape[f], mesh, "model"):
                dims[f] = "model"
            if _divisible(shape[d], mesh, fsdp):
                dims[d] = fsdp
        return P(*dims)
    if len(body) >= 2:
        a, b = body[-2], body[-1]
        # 2-D weight (d_in, d_out): fsdp on the bigger dim, model on the other
        big, small = (a, b) if shape[a] >= shape[b] else (b, a)
        if _divisible(shape[big], mesh, fsdp):
            dims[big] = fsdp
        if _divisible(shape[small], mesh, "model"):
            dims[small] = "model"
        elif dims[big] is None and _divisible(shape[small], mesh, fsdp):
            dims[small] = fsdp
        return P(*dims)
    # 1-D params (norm gains, biases): shard over model when large+divisible
    d = body[0]
    if shape[d] >= 4096 and _divisible(shape[d], mesh, "model"):
        dims[d] = "model"
    return P(*dims)


def param_layout(path: str, shape: Tuple[int, ...], mesh: Mesh,
                 stacked: bool = False):
    """The ``core.shard`` layout tuple for one parameter — the same
    placement ``param_spec`` names, in the form ``compile_module(...,
    param_layouts=)`` and the ShardingPass consume (one entry per dim:
    ``None`` or a tuple of mesh axis names)."""
    from ..core.shard import spec_to_layout

    return spec_to_layout(
        param_spec(path, shape, mesh, stacked=stacked), len(shape)
    )


def params_shardings(param_tree, mesh: Mesh, stacked_keys=("layers", "enc_layers")):
    """NamedSharding pytree matching ``param_tree`` (arrays or SDS)."""

    def walk(path, node, stacked):
        if isinstance(node, dict):
            return {
                k: walk(f"{path}/{k}", v, stacked or k in stacked_keys)
                for k, v in node.items()
            }
        if isinstance(node, (tuple, list)):
            vals = [walk(f"{path}/{i}", v, stacked) for i, v in enumerate(node)]
            return type(node)(vals) if not hasattr(node, "_fields") else type(node)(*vals)
        spec = param_spec(path, tuple(node.shape), mesh, stacked=stacked)
        return NamedSharding(mesh, spec)

    return walk("", param_tree, False)


def batch_axes(mesh: Mesh, global_batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    cands = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen: list = []
    for a in cands:
        if global_batch % axis_size(mesh, tuple(chosen + [a])) == 0:
            chosen.append(a)
    return tuple(chosen)


def batch_spec(mesh: Mesh, global_batch: int, rank: int) -> P:
    axes = batch_axes(mesh, global_batch)
    dims: list = [None] * rank
    if axes:
        dims[0] = axes if len(axes) > 1 else axes[0]
    return P(*dims)


def batch_shardings(batch_tree, mesh: Mesh, global_batch: int):
    def one(x):
        return NamedSharding(mesh, batch_spec(mesh, global_batch, len(x.shape)))

    return jax.tree.map(one, batch_tree)


def cache_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               global_batch: int) -> P:
    """KV/SSM cache sharding: (L, B, S|state...) — batch over (pod,data)
    when divisible; KV heads over 'model' when they divide it, else the
    HEAD DIM (always a multiple of 16) — the sequence dim stays unsharded
    so the one-token dynamic_update_slice write never reshards (GSPMD's
    "involuntary full rematerialization" of seq-sharded cache updates would
    replicate the whole cache).  SSM state heads over 'model'."""
    dims: list = [None] * len(shape)
    baxes = batch_axes(mesh, global_batch)
    if len(shape) >= 2 and baxes:
        dims[1] = baxes if len(baxes) > 1 else baxes[0]
    leaf = path.split("/")[-1]
    if leaf in ("k_scale", "v_scale") and len(shape) == 4:
        # (L, B, W, Hkv) int8-cache scale planes: batch + heads when divisible
        if _divisible(shape[3], mesh, "model") and shape[3] >= axis_size(mesh, "model"):
            dims[3] = "model"
        return P(*dims)
    if leaf in ("k", "v", "xk", "xv") and len(shape) == 5:
        # (L, B, S, Hkv, hd)
        if _divisible(shape[3], mesh, "model") and shape[3] >= axis_size(mesh, "model"):
            dims[3] = "model"
        elif _divisible(shape[4], mesh, "model"):
            dims[4] = "model"
    if leaf == "ssm" and len(shape) == 5:
        # (L, B, H, P, N): heads over model
        if _divisible(shape[2], mesh, "model"):
            dims[2] = "model"
    return P(*dims)


def cache_shardings(cache_tree, mesh: Mesh, global_batch: int):
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            vals = [walk(f"{path}/{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return NamedSharding(
            mesh, cache_spec(path, tuple(node.shape), mesh, global_batch)
        )

    return walk("", cache_tree)


def current_mesh() -> Optional[Mesh]:
    """The mesh installed by a ``with mesh:`` context, if any."""
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return m if m.axis_names else None
    except Exception:  # noqa: BLE001
        return None


def constrain_sp(x):
    """Sequence-parallel constraint on a (B, S, d) residual-stream tensor:
    batch over (pod, data) when divisible, SEQUENCE over 'model'.  Shards
    the scan-over-layers remat stash 'model'-ways (Megatron-SP); GSPMD
    inserts the gather/scatter pairs around attention/MLP automatically.
    No-op outside a mesh context or when dims don't divide."""
    mesh = current_mesh()
    if mesh is None or x.ndim < 3 or "model" not in mesh.axis_names:
        return x
    baxes = batch_axes(mesh, x.shape[0])
    seq_ax = "model" if x.shape[1] % mesh.shape["model"] == 0 else None
    spec = [baxes if len(baxes) > 1 else (baxes[0] if baxes else None), seq_ax]
    spec += [None] * (x.ndim - 2)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def opt_state_shardings(opt_specs, params_shard, mesh: Mesh):
    """AdamW m/v mirror the param shardings; step is replicated."""
    from ..train.optimizer import AdamWState

    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=jax.tree.map(lambda s: s, params_shard),
        v=jax.tree.map(lambda s: s, params_shard),
    )
