from ..core.shard import wrap_shard_map
from .collectives import bucketed_psum, cross_pod_mean, psum_tree
from .elastic import choose_mesh_shape, make_elastic_mesh, reshard_state
from .sharding import (
    batch_shardings,
    batch_spec,
    cache_shardings,
    cache_spec,
    opt_state_shardings,
    param_layout,
    param_spec,
    params_shardings,
)
