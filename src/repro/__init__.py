"""repro — FusionStitching (Long et al., 2018) reproduced as a production
JAX/Pallas TPU framework: stitching compiler core, stitched kernels, model
zoo, distributed training/serving substrate, multi-pod launch tooling.

Public surface:

  * ``repro.stitch`` — the jit-shaped frontend: capture a real ``jax.numpy``
    function into StitchIR and compile it through the stitching pipeline
    (``StitchedFunction``, ``UnsupportedPrimitiveError``).
  * ``repro.StitchOptions`` — compile options (planner, budgets, stitching).
  * ``repro.compile_module`` / ``repro.trace`` / ``repro.GraphBuilder`` —
    the documented low-level path for hand-built StitchIR.
"""
__version__ = "1.1.0"

from .core import (  # noqa: F401
    CompiledModule,
    CompileStats,
    GraphBuilder,
    Module,
    StitchOptions,
    compile_module,
    reference_execute,
    trace,
)
from .frontend import (  # noqa: F401
    SUPPORTED_PRIMITIVES,
    StitchedFunction,
    UnsupportedPrimitiveError,
    lower_jaxpr,
    stitch,
)

__all__ = [
    "stitch",
    "StitchOptions",
    "StitchedFunction",
    "UnsupportedPrimitiveError",
    "SUPPORTED_PRIMITIVES",
    "lower_jaxpr",
    "CompiledModule",
    "CompileStats",
    "GraphBuilder",
    "Module",
    "compile_module",
    "reference_execute",
    "trace",
]
