"""repro — FusionStitching (Long et al., 2018) reproduced as a production
JAX/Pallas TPU framework: stitching compiler core, stitched kernels, model
zoo, distributed training/serving substrate, multi-pod launch tooling."""
__version__ = "1.0.0"
