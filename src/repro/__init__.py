"""repro — FusionStitching (Long et al., 2018) reproduced as a production
JAX/Pallas TPU framework: stitching compiler core, stitched kernels, model
zoo, distributed training/serving substrate, multi-pod launch tooling.

Public surface (one coherent top level):

  * ``repro.stitch`` — the jit-shaped frontend: capture a real ``jax.numpy``
    function (control flow and gradients included) into StitchIR and compile
    it through the stitching pipeline.  ``static_argnums`` /
    ``static_argnames`` / ``donate_argnums`` mirror ``jax.jit``; the
    returned ``StitchedFunction`` exposes ``.lower()`` -> ``Lowered`` for
    introspection (``.as_text()``, ``.num_kernels``, ``.cost_estimate()``).
  * ``repro.StitchOptions`` — compile options (planner, budgets, stitching).
  * ``repro.compile_module`` — the documented low-level path for hand-built
    StitchIR modules.
  * ``repro.ServeEngine`` / ``repro.PagedServeEngine`` — continuous-batching
    serve engines behind the shared ``repro.BaseEngine`` protocol
    (``admit`` / ``tick`` / ``run_until_done`` / ``stats``).

Lower-level names (``GraphBuilder``, ``trace``, ``lower_jaxpr``,
``reference_execute``, primitive tables) now live in ``repro.core`` and
``repro.frontend``; importing them from ``repro`` still works but emits a
one-time ``DeprecationWarning`` naming the new home.
"""
import warnings as _warnings

__version__ = "1.2.0"

from .core import (  # noqa: F401
    CompiledModule,
    CompileStats,
    Diagnostic,
    Module,
    StitchOptions,
    VerificationError,
    compile_module,
)
from .frontend import (  # noqa: F401
    CostEstimate,
    Lowered,
    StitchedFunction,
    UnsupportedPrimitiveError,
    stitch,
)
from .serve import (  # noqa: F401
    BaseEngine,
    PagedServeEngine,
    Request,
    ServeEngine,
)

__all__ = [
    # frontend
    "stitch",
    "StitchOptions",
    "StitchedFunction",
    "Lowered",
    "CostEstimate",
    "UnsupportedPrimitiveError",
    # compiler core
    "CompiledModule",
    "CompileStats",
    "Module",
    "compile_module",
    # verification (core/verify.py)
    "Diagnostic",
    "VerificationError",
    # serving
    "BaseEngine",
    "ServeEngine",
    "PagedServeEngine",
    "Request",
]

# ---------------------------------------------------------------------------
# Deprecated re-exports: the pre-1.2 flat surface.  Each name resolves to its
# current home and warns once per process; new code should import from there.
# ---------------------------------------------------------------------------

_DEPRECATED = {
    "GraphBuilder": ("repro.core", "GraphBuilder"),
    "trace": ("repro.core", "trace"),
    "reference_execute": ("repro.core", "reference_execute"),
    "lower_jaxpr": ("repro.frontend", "lower_jaxpr"),
    "SUPPORTED_PRIMITIVES": ("repro.frontend", "SUPPORTED_PRIMITIVES"),
}
_warned: set = set()


def __getattr__(name):
    if name in _DEPRECATED:
        mod_name, attr = _DEPRECATED[name]
        if name not in _warned:
            _warned.add(name)
            _warnings.warn(
                f"importing {name!r} from 'repro' is deprecated; use "
                f"'from {mod_name} import {attr}' instead",
                DeprecationWarning,
                stacklevel=2,
            )
        import importlib

        return getattr(importlib.import_module(mod_name), attr)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | set(_DEPRECATED) | set(globals()))
