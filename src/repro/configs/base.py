"""Architecture config schema + the registry of assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | vlm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_impl: str = "scatter"        # scatter (EP at scale) | dense (smoke)
    moe_capacity_factor: float = 1.25
    # dummy experts appended so the expert dim divides the 'model' axis
    # (true EP instead of a replicated dispatch buffer) — §Perf iteration B2
    moe_pad_experts: int = 0
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    # hybrid
    sliding_window: int = 0          # 0 = full attention
    # vlm
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    num_patches: int = 256           # stub frontend patch count
    # audio (encoder-decoder)
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper 30s @ 50 Hz after conv stub
    # numerics / training
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    # attention chunking for long sequences (jnp online-softmax path)
    q_chunk: int = 512
    kv_chunk: int = 1024
    # sequence positions per chunk in the chunked (vocab-parallel) CE loss
    loss_chunk: int = 512
    # residual-stream activation sharding: "none" | "sp" (sequence-parallel
    # over the 'model' axis, Megatron-SP style — shards the remat stash)
    activation_sharding: str = "none"
    # KV-cache storage: "model" dtype (bf16) | "int8" (per-token-head
    # symmetric quantization with f32 scales — halves the decode memory
    # roofline term; beyond-paper optimization, EXPERIMENTS.md §Perf)
    kv_cache_dtype: str = "model"

    def __post_init__(self):
        if self.head_dim is None:
            self.head_dim = self.d_model // self.num_heads

    @property
    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so vocab-parallel sharding
        divides evenly on the 16-way model axis (Megatron-style padding)."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state or sliding-window attention."""
        return self.family in ("ssm", "hybrid")

    def param_count_estimate(self) -> int:
        """Analytic N for MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads + hd * self.num_heads * d
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            per_layer = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
        elif self.family == "moe":
            per_layer = attn + 3 * d * ff * self.moe_experts
        elif self.family == "hybrid":
            d_in = d
            ssm = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            per_layer = attn + ssm + 3 * d * ff
        else:
            per_layer = attn + 3 * d * ff
        emb = self.padded_vocab * d * 2
        enc = self.encoder_layers * (attn + 2 * d * ff)
        return L * per_layer + emb + enc

    def active_param_count_estimate(self) -> int:
        if self.family != "moe":
            return self.param_count_estimate()
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads + hd * self.num_heads * d
        per_layer = attn + 3 * d * ff * self.moe_top_k
        return L * per_layer + self.padded_vocab * d * 2
