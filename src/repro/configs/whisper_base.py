"""whisper-base [audio] — enc-dec; conv frontend stubbed (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    encoder_layers=6, encoder_seq=1500,
)
